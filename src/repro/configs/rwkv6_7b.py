"""rwkv6-7b — Finch, attention-free SSM with data-dependent decay
[arXiv:2404.05892]. 32L d_model=4096 d_ff=14336 vocab=65536."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm", source="arXiv:2404.05892",
    num_layers=32, d_model=4096, d_ff=14336, vocab_size=65536,
    norm="layernorm", rwkv_head_dim=64, rwkv_lora_dim=32,
    optimizer="adafactor",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=256, d_ff=512, vocab_size=512,
    rwkv_head_dim=64, rwkv_lora_dim=8, remat=False, optimizer="adamw")
