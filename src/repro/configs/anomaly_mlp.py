"""The paper's own model: 3-layer MLP (256,128,64), dropout 0.3.

UNSW-NB15 variant: 49 features, 10 attack classes (+Normal handled as a
class). ROAD variant: CAN-signal window features, binary."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="anomaly-mlp", family="mlp", source="paper §IV-C / Algorithm 1",
    num_layers=3, d_model=256, mlp_hidden=(256, 128, 64),
    num_features=49, num_classes=10, dropout=0.3,
    dtype="float32", remat=False,
)

ROAD_CONFIG = CONFIG.replace(name="anomaly-mlp-road", num_features=32,
                             num_classes=2)

SMOKE = CONFIG.replace(mlp_hidden=(32, 16), num_features=16, num_classes=4)
