"""Assigned input shapes.

  train_4k     — training step (fl_train_step: per-client grads + masked agg)
  prefill_32k  — inference prefill (logits + cache build)
  decode_32k   — ONE new token against a 32k KV/state cache
  long_500k    — ONE new token against a 512k context; sub-quadratic archs
                 run natively, dense archs run the sliding-window variant
                 (window 4096) — see DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# smoke-scale counterparts (same kind, tiny dims) used by CPU tests
SMOKE_SHAPES = {
    "train_4k": InputShape("train_4k", 64, 8, "train"),
    "prefill_32k": InputShape("prefill_32k", 96, 2, "prefill"),
    "decode_32k": InputShape("decode_32k", 96, 4, "decode"),
    "long_500k": InputShape("long_500k", 256, 1, "decode"),
}
