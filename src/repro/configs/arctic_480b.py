"""arctic-480b — MoE 128 experts top-2 + dense residual FFN
[hf:Snowflake/snowflake-arctic-base].
35L d_model=7168 56H (GQA kv=8) d_ff=4864/expert vocab=32000.

Distribution: expert_parallel=True — the 468B expert pool cannot be
replicated per FL client; expert tensors shard over ("data","model")
jointly and FL clients live on the "pod" axis only (DESIGN.md §6)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe", source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000, num_experts=128, top_k=2,
    moe_dense_residual=True, expert_parallel=True,
    client_axes=("pod",), optimizer="adafactor",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=64, vocab_size=512, num_experts=4, top_k=2,
    expert_parallel=False, client_axes=("pod", "data"),
    remat=False, optimizer="adamw")
