"""Architecture configuration dataclasses.

Every assigned architecture is expressed as an ``ArchConfig``. Families:
  dense   — llama-style decoder (GQA + RoPE + SwiGLU or variants)
  moe     — dense skeleton with mixture-of-experts FFN (top-k routing)
  ssm     — RWKV6 "Finch" (attention-free, data-dependent decay)
  hybrid  — Hymba (parallel attention + mamba heads per layer)
  audio   — Whisper encoder-decoder backbone (conv frontend stubbed)
  vlm     — InternVL2 (InternLM2 decoder consuming stubbed patch embeds)
  mlp     — the paper's own 256-128-64 anomaly-detection MLP
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm|mlp
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                # 0 -> d_model // num_heads
    source: str = ""                 # citation for the config

    # attention / norm variants -------------------------------------------
    qkv_bias: bool = False
    attention_impl: str = "full"     # full | blockwise (flash-oracle path)
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    mlp_act: str = "swiglu"          # swiglu | gelu
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0       # partial rotary (stablelm uses 0.25)
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None   # set for long_500k dense variant

    # moe ------------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False       # arctic: dense FFN in parallel
    moe_dispatch: str = "gather"           # gather | scatter (§Perf iter D)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01        # load-balance loss weight

    # ssm / hybrid -----------------------------------------------------------
    ssm_state: int = 0               # mamba state size (hymba) / 0
    rwkv_head_dim: int = 64          # RWKV6 WKV head size
    rwkv_lora_dim: int = 32          # ddlerp / decay LoRA rank

    # audio / vlm stubs ------------------------------------------------------
    encoder_layers: int = 0          # whisper encoder depth
    encoder_seq: int = 1500          # whisper: 30 s -> 1500 frames
    num_patches: int = 256           # vlm: stubbed patch embeddings

    # mlp detector -----------------------------------------------------------
    mlp_hidden: Tuple[int, ...] = ()
    num_features: int = 0
    num_classes: int = 0
    dropout: float = 0.0

    # numerics / training ------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    optimizer: str = "adamw"         # adamw | adafactor (large archs)

    # distribution ---------------------------------------------------------
    expert_parallel: bool = False    # shard expert dim over client ("data") axis
    client_axes: Tuple[str, ...] = ("pod", "data")  # mesh axes hosting FL clients

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 (§Perf iteration D:
        unshardable vocab dims force a full-logits all-reduce — 12.9 GB/
        layer-use for granite-moe — so embed/lm_head use the padded size;
        labels never reference pad ids)."""
        v = self.vocab_size
        return v if v % 256 == 0 else (v // 256 + 1) * 256

    @property
    def q_groups(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # --- parameter counting (for roofline MODEL_FLOPS = 6 N D) -----------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count; active_only counts top-k experts only."""
        d, ff, L, V = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        hd, H, K = self.hd, self.num_heads, self.num_kv_heads
        if self.family == "mlp":
            dims = (self.num_features,) + tuple(self.mlp_hidden) + (self.num_classes,)
            return sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        attn = d * H * hd + 2 * d * K * hd + H * hd * d
        if self.qkv_bias:
            attn += (H + 2 * K) * hd
        if self.mlp_act == "swiglu":
            ffn = 3 * d * ff
        else:
            ffn = 2 * d * ff + ff + d
        norms = 2 * d
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # rwkv6
            heads = d // self.rwkv_head_dim
            lora = self.rwkv_lora_dim
            tmix = 4 * d * d + d  # r,k,v,g,o projections (g folded) approx
            tmix += 5 * (d * lora + lora * d) + 6 * d  # ddlerp loras + mus
            tmix += d * lora + lora * d + d + heads * self.rwkv_head_dim  # decay lora + u
            cmix = d * ff + ff * d + 2 * d
            per_layer = tmix + cmix + norms
            return L * per_layer + emb + d
        if self.family == "hybrid":
            dd = d  # mamba inner dim == d_model (parallel-heads design)
            mamba = d * 2 * dd + dd * (2 * self.ssm_state + dd // 16) \
                + dd * self.ssm_state + dd + dd * d + 4 * dd
            per_layer = attn + mamba + ffn + 3 * d
            return L * per_layer + emb + d
        if self.family in ("moe",):
            e_ffn = self.num_experts * 3 * d * ff
            a_ffn = (self.top_k if active_only else self.num_experts) * 3 * d * ff
            router = d * self.num_experts
            dense_res = 3 * d * ff if self.moe_dense_residual else 0
            per_layer = attn + (a_ffn if active_only else e_ffn) + router + dense_res + norms
            return L * per_layer + emb + d
        if self.family == "audio":
            enc = self.encoder_layers * (attn + ffn + norms)
            dec = L * (2 * attn + ffn + 3 * d)  # self + cross attention
            return enc + dec + emb + 2 * d
        if self.family == "vlm":
            return L * (attn + ffn + norms) + emb + d + self.d_model * d  # projector stub
        # dense
        return L * (attn + ffn + norms) + emb + d
