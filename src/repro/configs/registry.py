"""Architecture registry: ``--arch <id>`` resolution for launchers."""
from __future__ import annotations

from repro.configs import (anomaly_mlp, arctic_480b, granite_34b,
                           granite_moe_1b, hymba_1_5b, internvl2_2b,
                           phi3_mini_3_8b, qwen2_1_5b, rwkv6_7b,
                           stablelm_1_6b, whisper_tiny)
from repro.configs.base import ArchConfig

_MODULES = {
    "rwkv6-7b": rwkv6_7b,
    "hymba-1.5b": hymba_1_5b,
    "granite-34b": granite_34b,
    "whisper-tiny": whisper_tiny,
    "granite-moe-1b-a400m": granite_moe_1b,
    "internvl2-2b": internvl2_2b,
    "qwen2-1.5b": qwen2_1_5b,
    "stablelm-1.6b": stablelm_1_6b,
    "arctic-480b": arctic_480b,
    "phi3-mini-3.8b": phi3_mini_3_8b,
    "anomaly-mlp": anomaly_mlp,
}

ASSIGNED_ARCHS = [k for k in _MODULES if k != "anomaly-mlp"]


def list_archs():
    """Sorted public list of registered ``--arch`` ids — the supported
    way for launchers/CLIs to enumerate architectures (do not reach
    into ``_MODULES``)."""
    return sorted(_MODULES)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = _MODULES[name]
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False):
    return {name: get_config(name, smoke) for name in ASSIGNED_ARCHS}


# long_500k applicability (DESIGN.md §5): SSM/hybrid run natively; dense /
# moe / vlm run the sliding-window variant; whisper (audio enc-dec) skips.
LONG_CTX_NATIVE = {"rwkv6-7b", "hymba-1.5b"}
LONG_CTX_SKIP = {"whisper-tiny"}
SLIDING_WINDOW = 4096


def config_for_shape(name: str, shape_name: str, smoke: bool = False) -> ArchConfig:
    """Resolve the (possibly sliding-window) config variant for a shape."""
    cfg = get_config(name, smoke)
    if shape_name == "long_500k":
        if name in LONG_CTX_SKIP:
            raise ValueError(f"{name} skips long_500k (DESIGN.md §5)")
        if name not in LONG_CTX_NATIVE and cfg.family != "ssm":
            w = 256 if smoke else SLIDING_WINDOW
            cfg = cfg.replace(sliding_window=w)
        if cfg.family == "hybrid":
            w = 256 if smoke else SLIDING_WINDOW
            cfg = cfg.replace(sliding_window=w)
    return cfg
