"""phi3-mini-3.8b — dense RoPE SwiGLU [arXiv:2404.14219].
32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b", family="dense", source="arXiv:2404.14219",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, vocab_size=512, remat=False)
