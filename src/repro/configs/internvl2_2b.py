"""internvl2-2b — VLM: InternViT (stub) + InternLM2 decoder
[arXiv:2404.16821]. 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553. Vision encoder + projector are STUBBED: input_specs
supplies (B, 256, 2048) patch embeddings prepended to the token stream."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm", source="arXiv:2404.16821",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553, num_patches=256,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, num_patches=8, remat=False)
