"""hymba-1.5b — hybrid: parallel attention + mamba heads per layer
[arXiv:2411.13676]. 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid", source="arXiv:2411.13676",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    head_dim=64, d_ff=5504, vocab_size=32001, ssm_state=16,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=320, num_heads=5, num_kv_heads=1, head_dim=64,
    d_ff=512, vocab_size=512, ssm_state=8, remat=False)
