"""whisper-tiny — encoder-decoder audio backbone [arXiv:2212.04356].
4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865. Conv/mel frontend is a
STUB: input_specs supplies (B, 1500, 384) frame embeddings.

long_500k is SKIPPED for this arch (pure full-attention enc-dec; a 512k
decoder sequence has no audio semantics — see DESIGN.md §5)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio", source="arXiv:2212.04356",
    num_layers=4, encoder_layers=4, d_model=384, num_heads=6,
    num_kv_heads=6, d_ff=1536, vocab_size=51865, encoder_seq=1500,
    norm="layernorm", mlp_act="gelu", qkv_bias=True, tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    num_layers=2, encoder_layers=2, d_model=128, num_heads=2,
    num_kv_heads=2, d_ff=256, vocab_size=512, encoder_seq=64, remat=False)
