"""stablelm-1.6b — dense MHA [hf:stabilityai/stablelm-2-1_6b].
24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352.
StableLM-2 details kept: LayerNorm + 25% partial rotary."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b", family="dense", source="hf:stabilityai/stablelm-2-1_6b",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=5632, vocab_size=100352, norm="layernorm", rope_fraction=0.25,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, vocab_size=512, remat=False)
