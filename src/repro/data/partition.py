"""Non-IID client partitioning (paper §II-B: non-IID across clients)."""
from __future__ import annotations

import numpy as np

_MASK64 = (1 << 64) - 1


def client_seed(seed: int, cid: int) -> int:
    """Deterministic per-client synthesis seed: splitmix64 of (seed, cid).

    The lazy cohort materializer seeds EVERY client's shard and loader
    stream from this hash alone, so which cohorts a round happens to
    select can never perturb any other client's draws — the property
    the resident path gets for free from materializing everything up
    front. Plain ``seed + cid`` would collide across experiment seeds
    (seed=0,cid=5 == seed=5,cid=0); the mix keeps the 64-bit streams
    disjoint."""
    x = (int(seed) * 0x9E3779B97F4A7C15
         + (int(cid) + 1) * 0xBF58476D1CE4E5B9) & _MASK64
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & _MASK64
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & _MASK64
    x ^= x >> 31
    return x


class LazyPartition:
    """Per-client shard descriptors WITHOUT a global index table.

    The eager partitioners above return ``num_clients`` index arrays
    into one materialized dataset — O(population) host memory before
    training starts. A ``LazyPartition`` holds only ``(num_clients,
    samples_per_client, seed)`` and answers ``shard(cid) -> (seed_c,
    size)``: the per-client synthesis seed (``client_seed``) and fixed
    shard size the materializer feeds to the seeded generators. Host
    memory for the partition itself is O(1); the cohort materializer
    (api/world.py) bounds data memory by cohort size."""

    def __init__(self, num_clients: int, samples_per_client: int,
                 seed: int = 0):
        if num_clients < 1 or samples_per_client < 1:
            raise ValueError("LazyPartition needs num_clients >= 1 and "
                             "samples_per_client >= 1")
        self.num_clients = int(num_clients)
        self.samples_per_client = int(samples_per_client)
        self.seed = int(seed)

    def __len__(self) -> int:
        return self.num_clients

    def shard(self, cid: int):
        if not 0 <= cid < self.num_clients:
            raise IndexError(f"client {cid} outside population "
                             f"[0, {self.num_clients})")
        return client_seed(self.seed, cid), self.samples_per_client


def dirichlet_partition(labels: np.ndarray, num_clients: int,
                        alpha: float = 0.5, seed: int = 0,
                        min_per_client: int = 8):
    """Label-Dirichlet split. Lower alpha -> more skew. Returns index lists."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    idx_by_class = [np.nonzero(labels == c)[0] for c in classes]
    for idx in idx_by_class:
        rng.shuffle(idx)
    shares = rng.dirichlet([alpha] * num_clients, size=len(classes))
    client_idx = [[] for _ in range(num_clients)]
    for ci, idx in enumerate(idx_by_class):
        cuts = (np.cumsum(shares[ci])[:-1] * len(idx)).astype(int)
        for k, part in enumerate(np.split(idx, cuts)):
            client_idx[k].append(part)
    out = [np.concatenate(parts) for parts in client_idx]
    # guarantee a floor so every client can form a batch
    pool = np.concatenate(out)
    for k in range(num_clients):
        if len(out[k]) < min_per_client:
            extra = rng.choice(pool, size=min_per_client - len(out[k]))
            out[k] = np.concatenate([out[k], extra])
        rng.shuffle(out[k])
    return out


def iid_partition(n: int, num_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return np.array_split(idx, num_clients)
