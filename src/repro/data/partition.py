"""Non-IID client partitioning (paper §II-B: non-IID across clients)."""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, num_clients: int,
                        alpha: float = 0.5, seed: int = 0,
                        min_per_client: int = 8):
    """Label-Dirichlet split. Lower alpha -> more skew. Returns index lists."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    idx_by_class = [np.nonzero(labels == c)[0] for c in classes]
    for idx in idx_by_class:
        rng.shuffle(idx)
    shares = rng.dirichlet([alpha] * num_clients, size=len(classes))
    client_idx = [[] for _ in range(num_clients)]
    for ci, idx in enumerate(idx_by_class):
        cuts = (np.cumsum(shares[ci])[:-1] * len(idx)).astype(int)
        for k, part in enumerate(np.split(idx, cuts)):
            client_idx[k].append(part)
    out = [np.concatenate(parts) for parts in client_idx]
    # guarantee a floor so every client can form a batch
    pool = np.concatenate(out)
    for k in range(num_clients):
        if len(out[k]) < min_per_client:
            extra = rng.choice(pool, size=min_per_client - len(out[k]))
            out[k] = np.concatenate([out[k], extra])
        rng.shuffle(out[k])
    return out


def iid_partition(n: int, num_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return np.array_split(idx, num_clients)
