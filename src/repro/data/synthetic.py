"""Synthetic dataset surrogates (offline container — see DESIGN.md §10).

``make_unsw_like``  — 49-feature network-flow records, 10 classes
   (class 0 = Normal majority, 9 imbalanced attack categories), built as a
   class-conditional Gaussian mixture over correlated continuous features
   plus one-hot-ish categorical blocks — statistically analogous to
   UNSW-NB15 after the paper's feature scaling + one-hot encoding.

``make_road_like``  — automotive CAN wheel-speed windows: normal traffic is
   smooth correlated sinusoids + sensor noise; the "correlated signal
   masquerade" attack injects a constant/offset wheel-speed segment that
   breaks cross-wheel correlation (the ROAD scenario the paper evaluates).

``make_lm_tokens``  — Zipf-distributed token streams with a first-order
   Markov flavour, for the federated LM example and smoke tests.
"""
from __future__ import annotations

import numpy as np

# class priors loosely matching UNSW-NB15's imbalance (Normal-heavy)
_UNSW_PRIORS = np.array(
    [0.55, 0.12, 0.09, 0.07, 0.05, 0.04, 0.03, 0.025, 0.02, 0.015])


def make_unsw_like(seed: int, n: int, num_features: int = 49,
                   num_classes: int = 10, universe_seed: int = 1234):
    """seed draws the SAMPLES; universe_seed fixes the class-conditional
    distribution (basis + means), so different seeds give train/eval splits
    of the SAME population — not different populations."""
    rng = np.random.default_rng(seed)
    rng_u = np.random.default_rng(universe_seed)
    priors = _UNSW_PRIORS[:num_classes] / _UNSW_PRIORS[:num_classes].sum()
    y = rng.choice(num_classes, size=n, p=priors)
    # shared correlated basis + class-specific means (harder than iid blobs)
    basis = rng_u.normal(size=(num_features, num_features)) / np.sqrt(num_features)
    means = rng_u.normal(scale=0.9, size=(num_classes, num_features))
    z = rng.normal(size=(n, num_features))
    x = (z @ basis) + means[y]
    # categorical-ish block: quantize last 9 features (proto/service/state)
    x[:, -9:] = np.sign(x[:, -9:])
    x = (x - x.mean(0)) / (x.std(0) + 1e-6)   # paper's feature scaling
    # ~5% label noise caps attainable accuracy near the paper's ~95% regime
    flip = rng.random(n) < 0.05
    y = np.where(flip, rng.choice(num_classes, size=n, p=priors), y)
    return x.astype(np.float32), y.astype(np.int32)


def make_road_like(seed: int, n: int, window: int = 32,
                   attack_frac: float = 0.25):
    rng = np.random.default_rng(seed)
    y = (rng.random(n) < attack_frac).astype(np.int32)
    t = np.arange(window) / window
    base_speed = rng.uniform(0.2, 1.0, size=(n, 1))
    phase = rng.uniform(0, 2 * np.pi, size=(n, 1))
    sig = base_speed * (1.0 + 0.1 * np.sin(2 * np.pi * t[None] * 2 + phase))
    sig += rng.normal(scale=0.01, size=(n, window))
    # masquerade: overwrite a segment with a flat injected wheel speed
    inj_start = rng.integers(4, window - 8, size=n)
    inj_val = rng.uniform(0.0, 1.2, size=n)
    for i in np.nonzero(y)[0]:
        sig[i, inj_start[i]:inj_start[i] + 8] = inj_val[i]
    x = (sig - sig.mean(0)) / (sig.std(0) + 1e-6)
    return x.astype(np.float32), y


def make_lm_tokens(seed: int, n_seq: int, seq_len: int, vocab: int):
    rng = np.random.default_rng(seed)
    # zipfian unigram + local repetition structure
    ranks = np.arange(1, vocab + 1)
    p = 1.0 / ranks ** 1.1
    p /= p.sum()
    toks = rng.choice(vocab, size=(n_seq, seq_len + 1), p=p)
    rep = rng.random((n_seq, seq_len + 1)) < 0.3
    for j in range(1, seq_len + 1):
        toks[:, j] = np.where(rep[:, j], toks[:, j - 1], toks[:, j])
    return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)
