"""Minimal batch iterators (per-client, reshuffled each epoch)."""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable

import numpy as np


class ArrayLoader:
    """Iterates {x,y} (or {tokens,labels}) batches of a fixed size."""

    def __init__(self, arrays: dict, batch_size: int, seed: int = 0,
                 drop_last: bool = True):
        self.arrays = arrays
        self.n = len(next(iter(arrays.values())))
        self.batch_size = min(batch_size, self.n)
        self.rng = np.random.default_rng(seed)
        self.drop_last = drop_last

    def set_batch_size(self, bs: int):
        """Dynamic batch-size adjustment hook (paper §IV-A)."""
        self.batch_size = max(1, min(bs, self.n))

    def epoch(self):
        order = self.rng.permutation(self.n)
        stop = self.n - (self.n % self.batch_size) if self.drop_last else self.n
        if stop == 0:
            stop = self.n
        for s in range(0, stop, self.batch_size):
            sel = order[s:s + self.batch_size]
            yield {k: v[sel] for k, v in self.arrays.items()}

    def sample(self):
        sel = self.rng.integers(0, self.n, size=self.batch_size)
        return {k: v[sel] for k, v in self.arrays.items()}


class LoaderPool:
    """Lazy, LRU-bounded sequence of per-client :class:`ArrayLoader`.

    Drop-in for the eager ``loaders`` list of the simulation engine when
    the client world is non-resident: ``pool[cid]`` synthesizes client
    ``cid``'s arrays on first touch (``data[cid]`` — a lazy sequence)
    and keeps at most ``capacity`` loaders materialized, so host memory
    is bounded by cohort size, not population. Eviction retains each
    loader's ``(batch_size, rng state)``; re-materialization restores
    both, so the per-client batch stream is bit-identical to the eager
    list no matter which cohorts were selected in between.
    """

    lazy = True

    def __init__(self, data, batch_size_fn: Callable[[int], int],
                 seed: int = 0, capacity: int = 512):
        self._data = data
        self._bs_fn = batch_size_fn
        self._seed = int(seed)
        self.capacity = max(1, int(capacity))
        self._pool: "OrderedDict[int, ArrayLoader]" = OrderedDict()
        self._retained: dict = {}       # cid -> (batch_size, rng state)

    def __len__(self) -> int:
        return len(self._data)

    @property
    def resident(self) -> int:
        """Currently-materialized loader count (the memory bound)."""
        return len(self._pool)

    def __getitem__(self, cid: int) -> ArrayLoader:
        cid = int(cid)
        l = self._pool.get(cid)
        if l is not None:
            self._pool.move_to_end(cid)
            return l
        l = ArrayLoader(self._data[cid], self._bs_fn(cid),
                        seed=self._seed + cid)
        if cid in self._retained:
            bs, rng_state = self._retained.pop(cid)
            l.set_batch_size(bs)
            l.rng.bit_generator.state = rng_state
        self._pool[cid] = l
        while len(self._pool) > self.capacity:
            old_cid, old = self._pool.popitem(last=False)
            self._retained[old_cid] = (old.batch_size,
                                       old.rng.bit_generator.state)
        return l

    def state_dict(self) -> dict:
        """Only clients whose streams ever advanced (resident or
        retained) — every other client is still at its seeded origin."""
        states = {cid: (l.batch_size, l.rng.bit_generator.state)
                  for cid, l in self._pool.items()}
        states.update(self._retained)
        return {"lazy": True,
                "states": {cid: {"batch_size": bs, "rng": rs}
                           for cid, (bs, rs) in states.items()}}

    def load_state_dict(self, state: dict) -> None:
        self._pool.clear()
        self._retained = {int(cid): (s["batch_size"], s["rng"])
                          for cid, s in state["states"].items()}
