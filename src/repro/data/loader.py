"""Minimal batch iterators (per-client, reshuffled each epoch)."""
from __future__ import annotations

import numpy as np


class ArrayLoader:
    """Iterates {x,y} (or {tokens,labels}) batches of a fixed size."""

    def __init__(self, arrays: dict, batch_size: int, seed: int = 0,
                 drop_last: bool = True):
        self.arrays = arrays
        self.n = len(next(iter(arrays.values())))
        self.batch_size = min(batch_size, self.n)
        self.rng = np.random.default_rng(seed)
        self.drop_last = drop_last

    def set_batch_size(self, bs: int):
        """Dynamic batch-size adjustment hook (paper §IV-A)."""
        self.batch_size = max(1, min(bs, self.n))

    def epoch(self):
        order = self.rng.permutation(self.n)
        stop = self.n - (self.n % self.batch_size) if self.drop_last else self.n
        if stop == 0:
            stop = self.n
        for s in range(0, stop, self.batch_size):
            sel = order[s:s + self.batch_size]
            yield {k: v[sel] for k, v in self.arrays.items()}

    def sample(self):
        sel = self.rng.integers(0, self.n, size=self.batch_size)
        return {k: v[sel] for k, v in self.arrays.items()}
