"""Global model aggregation (paper §IV-C).

``masked_mean``         — w_g = 1/|S| Σ_{i∈S} w_i over the accepted set S
                          (all-ones mask == plain FedAvg, tested invariant).
``staleness_weight``    — async aggregation weight α(τ) = (1+τ)^-0.5
                          (polynomial staleness discount; τ = server_step −
                          client_snapshot_step).
``apply_async_update``  — server-side continuous aggregation:
                          w_g ← (1−α)·w_g + α·w_i.

If NO client passes the filter the global state must remain unchanged —
``masked_mean`` returns a zero update in that case and ``fl_step`` keeps
w_g (tested invariant).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def masked_mean(client_trees, mask: jnp.ndarray, weights=None,
                reduce_dtype=jnp.float32):
    """client_trees: leading client dim C; mask: (C,). Returns mean tree.

    weights (C,) optionally scales clients (e.g. by sample counts);
    normalization is by the masked weight sum, with a zero-safe floor.
    ``reduce_dtype=bf16`` halves the cross-client all-reduce bytes on the
    production mesh (§Perf iteration E); results are returned in fp32.
    """
    w = mask if weights is None else mask * weights
    denom = jnp.maximum(w.sum(), 1e-9).astype(jnp.float32)

    def agg(x):
        wf = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(reduce_dtype)
        s = (x.astype(reduce_dtype) * wf).sum(0)
        return s.astype(jnp.float32) / denom

    return jax.tree.map(agg, client_trees)


def fedavg(client_trees, weights=None):
    C = jax.tree.leaves(client_trees)[0].shape[0]
    return masked_mean(client_trees, jnp.ones((C,), jnp.float32), weights)


def staleness_weight(tau, alpha0: float = 0.6):
    """Polynomial staleness discount α(τ) = α₀·(1+τ)^-0.5 — the ONE
    implementation both engines use (the event-driven simulator, the
    scanned megastep and the spmd path all call this; regression-pinned
    over τ ∈ {0..8} in tests/test_control.py). Accepts scalars or
    arrays; all arithmetic in f32."""
    return (jnp.float32(alpha0)
            * (1.0 + jnp.asarray(tau, jnp.float32)) ** jnp.float32(-0.5))


def staleness_weights_np(taus, alpha0: float = 0.6) -> np.ndarray:
    """Host-side vectorized view of :func:`staleness_weight` — ONE device
    round-trip for a whole round's arrival order (the per-arrival
    ``float()`` sync this replaces was a dispatch per sender)."""
    return np.asarray(staleness_weight(np.asarray(taus), alpha0))


def staleness_weight_host(tau, alpha0: float = 0.6) -> float:
    """Deprecated scalar shim kept for API compatibility — delegates to
    the unified :func:`staleness_weight`."""
    return float(staleness_weight(tau, alpha0))


def apply_async_update(global_tree, client_tree, alpha):
    return jax.tree.map(
        lambda g, c: ((1.0 - alpha) * g.astype(jnp.float32)
                      + alpha * c.astype(jnp.float32)).astype(g.dtype),
        global_tree, client_tree)


def buffered_async_update(anchor_tree, arrivals):
    """FedBuff-style buffered aggregation: apply the MEAN of staleness-
    discounted client deltas relative to the round anchor —
        w_g ← w_a + (1/N) Σ_i α(τ_i) · (w_i − w_a).
    With all τ=0 this is exactly FedAvg over the senders (tested), unlike
    sequential convex mixing which over-weights the last arrival (see
    EXPERIMENTS.md §Sim). ``arrivals``: list of (alpha, client_tree)."""
    if not arrivals:
        return anchor_tree
    n = float(len(arrivals))

    def combine(a, *clients):
        af = a.astype(jnp.float32)
        delta = sum(alpha * (c.astype(jnp.float32) - af)
                    for (alpha, _), c in zip(arrivals, clients))
        return (af + delta / n).astype(a.dtype)

    return jax.tree.map(combine, anchor_tree,
                        *[c for _, c in arrivals])
