"""ScheduleSpec — the server-coordination axis as an explicit spec.

The paper's central experimental contrast (§IV-B, Fig. 2) is *when the
server aggregates*: synchronously at a barrier, or continuously with
staleness discounting. Historically that axis lived inside
``StrategyConfig.mode`` — a string entangled with the strategy presets,
which made "fedavg but asynchronous" or "ours but with a staleness
cutoff" impossible to spell. ``ScheduleSpec`` lifts it out:

  kind="sync"        barrier aggregation — the round completes when the
                     slowest participating client arrives; barrier idle
                     time is tracked explicitly.
  kind="async"       continuous aggregation — the round clock advances at
                     a QUORUM of arrivals; stragglers' updates are still
                     applied, discounted by α(τ)=α₀(1+τ)^-0.5.
  kind="semi-async"  the middle ground (Marfo et al. 2025, §IV-B): the
                     quorum clock of async, but updates staler than
                     ``max_staleness`` quorum ranks are DROPPED rather
                     than discounted — bounded-staleness aggregation.

Both simulation paths (host loop/megastep and the scanned device control
plane) consume the same ScheduleSpec; ``StrategyConfig.mode`` keeps
working through :meth:`ScheduleSpec.from_strategy` (the deprecation
shim — see the CHANGES.md migration table).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

SCHEDULE_KINDS = ("sync", "async", "semi-async")


@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    kind: str = "sync"                    # sync | async | semi-async
    quorum: float = 0.5                   # async/semi-async: round clock
                                          # advances at this arrival frac
    max_staleness: Optional[int] = None   # semi-async only: drop updates
                                          # with quorum rank τ beyond this
    alpha0: float = 1.0                   # fresh-update weight in the
                                          # staleness discount α(τ)

    # ------------------------------------------------------------------
    @property
    def is_sync(self) -> bool:
        return self.kind == "sync"

    def issues(self) -> List[Tuple[str, object, str]]:
        """(field, value, hint) triples for every violation — feeds the
        multi-error ``SpecError`` report instead of failing field-first."""
        out = []
        if self.kind not in SCHEDULE_KINDS:
            out.append(("schedule.kind", self.kind,
                        f"expected one of {SCHEDULE_KINDS}"))
        if not (0.0 < self.quorum <= 1.0):
            out.append(("schedule.quorum", self.quorum,
                        "quorum must be in (0, 1]"))
        if self.alpha0 <= 0.0:
            out.append(("schedule.alpha0", self.alpha0,
                        "alpha0 must be > 0"))
        if self.kind == "semi-async" and self.max_staleness is None:
            out.append(("schedule.max_staleness", None,
                        "semi-async is defined by its staleness bound; "
                        "set max_staleness >= 0 (or use kind='async' for "
                        "unbounded discounted staleness)"))
        if self.max_staleness is not None:
            if self.kind == "sync":
                out.append(("schedule.max_staleness", self.max_staleness,
                            "max_staleness is an async-family knob; a "
                            "sync barrier has no stale arrivals"))
            elif self.max_staleness < 0:
                out.append(("schedule.max_staleness", self.max_staleness,
                            "max_staleness must be >= 0"))
        return out

    def validate(self) -> "ScheduleSpec":
        issues = self.issues()
        if issues:
            raise ValueError(
                "invalid ScheduleSpec: "
                + "; ".join(f"{f}={v!r}: {h}" for f, v, h in issues))
        return self

    # ------------------------------------------------------------------
    # deprecation shim: the legacy StrategyConfig.mode spelling
    # ------------------------------------------------------------------
    @classmethod
    def from_strategy(cls, strategy) -> "ScheduleSpec":
        """Derive the schedule a legacy ``StrategyConfig`` implies.

        ``mode``/``quorum``/``alpha0`` on StrategyConfig are the old
        spelling of this axis; every preset and call-site that still
        sets them keeps working through this shim (migration:
        ``StrategyConfig.mode`` → ``ExperimentSpec.schedule``).
        """
        return cls(kind=getattr(strategy, "mode", "sync"),
                   quorum=getattr(strategy, "quorum", 0.5),
                   alpha0=getattr(strategy, "alpha0", 1.0))


def resolve_schedule(schedule, strategy) -> ScheduleSpec:
    """Normalize the spec-level ``schedule`` axis.

    ``None``          -> derived from the strategy (legacy shim);
    ``str``           -> that kind over the strategy's quorum/alpha0;
    ``ScheduleSpec``  -> taken as-is (overrides the strategy's mode).
    """
    base = ScheduleSpec.from_strategy(strategy)
    if schedule is None:
        return base
    if isinstance(schedule, str):
        return dataclasses.replace(base, kind=schedule)
    if isinstance(schedule, ScheduleSpec):
        return schedule
    raise TypeError(f"cannot resolve schedule from {type(schedule)}; "
                    "expected None, a kind string or a ScheduleSpec")
