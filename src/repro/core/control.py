"""Device-resident server control plane (paper §IV-A, §V-C).

The paper's three control mechanisms — adaptive client selection,
dynamic batch sizing and staleness-aware aggregation — are pure score
arithmetic over per-client statistics (the same formulation as the
companion works arXiv:2501.15038 / arXiv:2502.00036). Host-side they
lived in ``core/selection.AdaptiveClientSelector`` (numpy EMAs),
``core/batchsize.BatchSizeController`` (dicts) and per-arrival staleness
weights, which forced a device→host sync between every simulated round
and capped the cohort megastep at one dispatch *per round*.

``ControlState`` keeps every statistic the server reads or writes as
``(num_clients,)``-shaped device arrays, and the transitions below are
pure jnp functions usable inside ``jit``/``lax.scan``:

  ``observe``               — availability / pass-rate / round-time EMAs
                              (the selector's §V-C reliability history)
  ``score``                 — reliability × timeliness selection score
  ``select_topk_epsilon``   — stable top-k + ε-greedy pool swaps, the
                              exact decision function of
                              ``AdaptiveClientSelector.select`` given the
                              same uniform draws
  ``batch_feedback``        — straggler demote / fast-client promote over
                              power-of-two batch assignments (§IV-A)
  ``local_steps``           — device twin of
                              ``async_engine.local_step_count``
  ``lr_scale_update``       — FedL2P-style per-client LR adaptation
  ``staleness / grad-norm`` — per-client counters and EMAs

The host classes stay as the seeded oracles: ``tests/test_control.py``
pins every transition to them (same observation stream → same EMA /
score / assignment trajectories, f32 vs f64 tolerance only).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import selection

_POW2_MIN, _POW2_MAX = 64, 1024


class ControlState(NamedTuple):
    """Per-client control-plane statistics, all device-resident.

    Every field is ``(num_clients,)``-shaped except ``ef``, the batched
    error-feedback arena for int8 wire compression — ``(num_clients + 1,
    rows, lane)`` f32 (the +1 dummy row absorbs residuals of cohort
    padding), or a ``(0,)`` placeholder when compression is off.
    """
    avail: jnp.ndarray        # f32 availability EMA (init 1)
    pass_rate: jnp.ndarray    # f32 θ-filter pass-rate EMA (init 1)
    round_time: jnp.ndarray   # f32 round-time EMA (init 1)
    batch: jnp.ndarray        # i32 power-of-two batch assignment
    lr_scale: jnp.ndarray     # f32 per-client LR scale (FedL2P)
    grad_norm: jnp.ndarray    # f32 update-norm EMA (ACFL proxy)
    staleness: jnp.ndarray    # i32 rounds since last transmitted update
    has_ckpt: jnp.ndarray     # bool local checkpoint exists (§IV-C)
    ef: jnp.ndarray           # f32 error-feedback arena (quantize only)


def init_control(num_clients: int, batch_sizes=None, lr_scale=None,
                 arena=None, quantize: bool = False) -> ControlState:
    """Initial state matching the host classes' defaults (all EMAs 1)."""
    n = int(num_clients)
    ones = jnp.ones((n,), jnp.float32)
    if batch_sizes is None:
        batch = jnp.full((n,), _POW2_MIN, jnp.int32)
    else:
        batch = jnp.asarray(batch_sizes, jnp.int32)
    if quantize:
        assert arena is not None, "quantize=True needs the ParamArena"
        ef = jnp.zeros((n + 1, arena.rows, arena.lane), jnp.float32)
    else:
        ef = jnp.zeros((0,), jnp.float32)
    return ControlState(
        avail=ones, pass_rate=ones, round_time=ones, batch=batch,
        lr_scale=(ones if lr_scale is None
                  else jnp.asarray(lr_scale, jnp.float32)),
        grad_norm=ones, staleness=jnp.zeros((n,), jnp.int32),
        has_ckpt=jnp.zeros((n,), bool), ef=ef)


# ---------------------------------------------------------------------------
# selection statistics (oracle: core.selection.AdaptiveClientSelector)
# ---------------------------------------------------------------------------

def observe_ema(avail_c: jnp.ndarray, pass_c: jnp.ndarray,
                rt_c: jnp.ndarray, mask: jnp.ndarray,
                delivered: jnp.ndarray, passed: jnp.ndarray,
                round_time: jnp.ndarray, ema: float):
    """The EMA arithmetic of one observation batch on GATHERED values.

    Factored out of ``observe`` so the shard-local population kernels
    (core/population.py) run the IDENTICAL float ops on their local
    gathers — bit-identity between the sharded and the single-device
    control plane hinges on sharing this function."""
    e = jnp.float32(ema)
    new_avail = e * avail_c + (1.0 - e) * delivered.astype(jnp.float32)
    new_avail = jnp.where(mask, new_avail, avail_c)
    upd = mask & delivered
    new_pass = jnp.where(upd,
                         e * pass_c + (1.0 - e) * passed.astype(jnp.float32),
                         pass_c)
    new_rt = jnp.where(upd, e * rt_c + (1.0 - e) * round_time, rt_c)
    return new_avail, new_pass, new_rt


def observe(state: ControlState, cohort: jnp.ndarray, mask: jnp.ndarray,
            delivered: jnp.ndarray, passed: jnp.ndarray,
            round_time: jnp.ndarray, ema: float = 0.8) -> ControlState:
    """Scatter one batch of observations into the EMAs.

    cohort: (K,) client ids; mask: (K,) bool — which slots are observed
    at all (unmasked slots keep their statistics); delivered/passed:
    (K,) bool; round_time: (K,) f32. The EMA arithmetic is the oracle's:
    availability moves toward ``delivered``; pass-rate and round-time
    move only when the client delivered.
    """
    new_avail, new_pass, new_rt = observe_ema(
        state.avail[cohort], state.pass_rate[cohort],
        state.round_time[cohort], mask, delivered, passed, round_time, ema)
    return state._replace(
        avail=state.avail.at[cohort].set(new_avail),
        pass_rate=state.pass_rate.at[cohort].set(new_pass),
        round_time=state.round_time.at[cohort].set(new_rt))


def observe_round(state: ControlState, cohort: jnp.ndarray,
                  failed: jnp.ndarray, active: jnp.ndarray,
                  passed: jnp.ndarray, round_time: jnp.ndarray,
                  ema: float = 0.8) -> ControlState:
    """One simulated round's observations for a (K,)-cohort, matching
    the host engine's two-phase order: every client whose dropout draw
    fired is observed ``delivered=False`` first; every client that ended
    up participating (never failed, or failed but checkpoint-recovered)
    is then observed ``delivered=True`` with its θ verdict and round
    time. A failed-then-recovered client receives BOTH observations,
    exactly like the host loop."""
    false = jnp.zeros_like(failed)
    state = observe(state, cohort, mask=failed, delivered=false,
                    passed=false, round_time=round_time, ema=ema)
    return observe(state, cohort, mask=active, delivered=active,
                   passed=passed, round_time=round_time, ema=ema)


def score(state: ControlState) -> jnp.ndarray:
    """(N,) selection scores: availability × (0.5+0.5·pass) × 1/(1+t)."""
    timeliness = 1.0 / (1.0 + state.round_time)
    return state.avail * (0.5 + 0.5 * state.pass_rate) * timeliness


def select_topk_epsilon(scores: jnp.ndarray, k: int,
                        epsilon: float = 0.0,
                        eps_u: Optional[jnp.ndarray] = None,
                        pick_u: Optional[jnp.ndarray] = None,
                        live: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """(k,) selected client ids — the oracle's decision function.

    Stable descending-score top-k, then ε-greedy exploration: slot i is
    swapped (prob ε, via ``eps_u[i]``) for a uniformly-drawn member of
    the shrinking not-chosen pool (``pick_u[i]`` mapped to a pool index,
    the picked client popped). With ``epsilon=0`` (or no draws) this is
    exactly ``AdaptiveClientSelector.select``'s top-k; with draws it is
    the same algorithm with the randomness injected explicitly.

    ``live`` (optional (n,) bool, scenario churn) restricts the
    EXPLORATION POOL to live clients — the caller already masks dead
    scores to -inf for the top-k, and the host oracle's pool is its
    live not-chosen cids (``AdaptiveClientSelector.select(k, live=...)``),
    so without this mask an ε-swap could pull a churned-out client into
    the cohort on the device paths only.
    """
    n = scores.shape[0]
    k = int(k)
    order = jnp.argsort(-scores, stable=True)
    chosen = order[:k]
    if epsilon <= 0.0 or eps_u is None or pick_u is None or k >= n:
        return chosen
    # pool = (live) not-chosen cids in ascending order (stable sort of
    # the exclusion mask: zeros/False — the pool members — come first)
    in_chosen = jnp.zeros((n,), bool).at[chosen].set(True)
    if live is None:
        excluded = in_chosen
        m0 = jnp.int32(n - k)
    else:
        excluded = in_chosen | ~live
        m0 = (~excluded).sum().astype(jnp.int32)
    pool = jnp.argsort(excluded, stable=True)
    idx = jnp.arange(n)

    def body(i, carry):
        chosen, pool, m = carry
        explore = (eps_u[i] < epsilon) & (m > 0)
        j = jnp.minimum((pick_u[i] * m.astype(jnp.float32))
                        .astype(jnp.int32), m - 1)
        pick = pool[j]
        chosen = chosen.at[i].set(jnp.where(explore, pick, chosen[i]))
        shifted = jnp.take(pool, jnp.minimum(idx + 1, n - 1))
        pool = jnp.where(explore & (idx >= j), shifted, pool)
        m = m - explore.astype(jnp.int32)
        return chosen, pool, m

    chosen, _, _ = jax.lax.fori_loop(
        0, k, body, (chosen, pool, m0))
    return chosen


def select_topk(scores: jnp.ndarray, k: int, key=None,
                epsilon: float = 0.0,
                live: Optional[jnp.ndarray] = None,
                candidate_frac: Optional[float] = None,
                candidate_shards: int = 8) -> jnp.ndarray:
    """Convenience wrapper drawing the exploration uniforms from a PRNG
    key (one ``(k,)`` draw per decision, mirroring the oracle's one
    ``rng.random()`` + one ``rng.integers()`` per slot). Routes through
    ``two_stage_select`` so callers can attach the candidate
    pre-filter; ``candidate_frac=None`` keeps the legacy single-stage
    decision untouched."""
    if key is None or epsilon <= 0.0:
        return two_stage_select(scores, k, candidate_frac=candidate_frac,
                                candidate_shards=candidate_shards,
                                live=live)
    ke, kp = jax.random.split(key)
    return two_stage_select(
        scores, k, candidate_frac=candidate_frac,
        candidate_shards=candidate_shards, epsilon=epsilon,
        eps_u=jax.random.uniform(ke, (int(k),)),
        pick_u=jax.random.uniform(kp, (int(k),)), live=live)


# ---------------------------------------------------------------------------
# two-stage selection (oracle: core.selection.candidate_mask_np)
# ---------------------------------------------------------------------------

def candidate_mask(scores: jnp.ndarray, k: int, frac: float,
                   shards: int) -> jnp.ndarray:
    """(N,) bool — stage 1 of two-stage selection: the sharded candidate
    pre-filter.

    The score vector is viewed as ``shards`` contiguous logical shards
    (last one -inf-padded) and each shard keeps only its top-``quota``
    entries (``selection.candidate_quota``; ties -> lower index, the
    same order as the stable descending argsort stage 2 uses). The
    union of the per-shard winners is what the exact masked top-k then
    sees. Cost per shard is O(per·quota) instead of a global O(N log N)
    sort, and under ``shard_map`` each device only ranks its own rows.

    Exactness: with ``quota >= k`` (always true at ``frac=1.0``, where
    the mask is all-True) every global top-k member survives its own
    shard's cut, so stage 2 returns bit-identical selections.
    """
    n = scores.shape[0]
    shards = max(1, min(int(shards), int(n)))
    per = -(-n // shards)
    quota = selection.candidate_quota(n, k, frac, shards)
    pad = shards * per - n
    s = scores
    if pad:
        s = jnp.concatenate(
            [s, jnp.full((pad,), -jnp.inf, scores.dtype)])
    s = s.reshape(shards, per)
    _, keep = jax.lax.top_k(s, quota)
    mask = jnp.zeros((shards, per), bool)
    mask = mask.at[jnp.arange(shards)[:, None], keep].set(True)
    return mask.reshape(-1)[:n]


def two_stage_select(scores: jnp.ndarray, k: int, *,
                     candidate_frac: Optional[float] = None,
                     candidate_shards: int = 8,
                     epsilon: float = 0.0,
                     eps_u: Optional[jnp.ndarray] = None,
                     pick_u: Optional[jnp.ndarray] = None,
                     live: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Candidate pre-filter + the existing exact masked top-k.

    ``candidate_frac=None`` is the legacy single-stage path, untouched.
    Otherwise non-candidates are masked to -inf for the top-k AND
    removed from the ε-exploration pool (exploration stays inside the
    candidate union by design — at scale the pool must not require the
    full population). At ``frac=1.0`` the mask is all-True, so both the
    scores and the pool are bit-identical to single-stage.
    """
    if candidate_frac is None:
        return select_topk_epsilon(scores, k, epsilon,
                                   eps_u=eps_u, pick_u=pick_u, live=live)
    cand = candidate_mask(scores, k, candidate_frac, candidate_shards)
    masked = jnp.where(cand, scores, -jnp.inf)
    pool_live = cand if live is None else (live & cand)
    return select_topk_epsilon(masked, k, epsilon,
                               eps_u=eps_u, pick_u=pick_u, live=pool_live)


# ---------------------------------------------------------------------------
# dynamic batch sizing (oracle: core.batchsize.BatchSizeController)
# ---------------------------------------------------------------------------

def batch_feedback(state: ControlState, cohort: jnp.ndarray,
                   round_times: jnp.ndarray, valid: jnp.ndarray,
                   b_min: int = _POW2_MIN, b_max: int = _POW2_MAX,
                   straggler_factor: float = 1.5) -> ControlState:
    """Straggler demote / fast promote over the cohort's round times.

    cohort: (K,) ids; round_times: (K,) f32; valid: (K,) bool (clients
    that actually reported a time this round). The median is the upper
    median over the valid entries — ``sorted(ts)[len(ts)//2]`` — exactly
    the host controller's rule.
    """
    new_b = batch_rule(state.batch[cohort], round_times, valid,
                       b_min, b_max, straggler_factor)
    return state._replace(batch=state.batch.at[cohort].set(new_b))


def batch_rule(b: jnp.ndarray, round_times: jnp.ndarray,
               valid: jnp.ndarray, b_min: int = _POW2_MIN,
               b_max: int = _POW2_MAX,
               straggler_factor: float = 1.5) -> jnp.ndarray:
    """``batch_feedback``'s decision on GATHERED assignments (shared
    with the shard-local kernels). The median is computed from the
    replicated (K,) cohort observations, so every shard derives the
    identical threshold."""
    m = valid.sum().astype(jnp.int32)
    ts = jnp.where(valid, round_times, jnp.inf)
    med = jnp.sort(ts)[jnp.minimum(m // 2, ts.shape[0] - 1)]
    f = jnp.float32(straggler_factor)
    demote = (round_times > f * med) & (b > b_min)
    promote = (round_times < med / f) & (b < b_max)
    new_b = jnp.where(demote, b // 2, jnp.where(promote, b * 2, b))
    return jnp.where(valid & (m > 0), new_b, b)


# ---------------------------------------------------------------------------
# misc per-client transitions
# ---------------------------------------------------------------------------

def grad_norm_update(state: ControlState, cohort: jnp.ndarray,
                     norms: jnp.ndarray, valid: jnp.ndarray) -> ControlState:
    """0.5/0.5 EMA of update L2 norms (the ACFL critical-period proxy)."""
    g = state.grad_norm[cohort]
    new_g = jnp.where(valid, 0.5 * g + 0.5 * norms, g)
    return state._replace(grad_norm=state.grad_norm.at[cohort].set(new_g))


def lr_scale_update(state: ControlState, cohort: jnp.ndarray,
                    norms: jnp.ndarray, valid: jnp.ndarray) -> ControlState:
    """FedL2P-style meta-rule: grow the scale while updates are small,
    shrink while they are large; clipped to [0.25, 2]."""
    s = state.lr_scale[cohort]
    new_s = jnp.clip(s * jnp.where(norms < 1.0, 1.05, 0.9), 0.25, 2.0)
    new_s = jnp.where(valid, new_s, s)
    return state._replace(lr_scale=state.lr_scale.at[cohort].set(new_s))


def staleness_update(state: ControlState, cohort: jnp.ndarray,
                     sent: jnp.ndarray) -> ControlState:
    """Per-client staleness counters: +1 every round, reset on transmit."""
    stale = state.staleness + 1
    new_c = jnp.where(sent, 0, stale[cohort])
    return state._replace(staleness=stale.at[cohort].set(new_c))


def checkpoint_update(state: ControlState, cohort: jnp.ndarray,
                      active: jnp.ndarray) -> ControlState:
    """Participating clients persist a local checkpoint (§IV-C)."""
    new_c = state.has_ckpt[cohort] | active
    return state._replace(has_ckpt=state.has_ckpt.at[cohort].set(new_c))


# ---------------------------------------------------------------------------
# local step count (oracle: async_engine.local_step_count)
# ---------------------------------------------------------------------------

def local_steps(n: jnp.ndarray, batch: jnp.ndarray, local_epochs: int,
                max_samples: int) -> jnp.ndarray:
    """Device twin of ``local_step_count``: per-round local steps,
    quantized UP to powers of two, capped by the per-round sample budget.
    All inputs broadcastable i32/f32 arrays; returns i32."""
    b = jnp.maximum(batch.astype(jnp.float32), 1.0)
    cap = jnp.maximum(1.0, jnp.floor(jnp.float32(max_samples) / b))
    steps = jnp.maximum(1.0, jnp.ceil(jnp.float32(local_epochs)
                                      * n.astype(jnp.float32) / b))
    steps = jnp.minimum(steps, cap)
    steps = 2.0 ** jnp.ceil(jnp.log2(steps))     # next power of two
    return jnp.minimum(steps, cap).astype(jnp.int32)
