"""Event-driven federated simulation engine (paper §IV-B, §V).

Reproduces the paper's experimental apparatus on CPU: N clients with
heterogeneous speed / network / dropout profiles train REAL models (jitted
JAX local steps on their non-IID shard); the server runs either

  sync  — barrier aggregation: the round completes when the SLOWEST
          selected client's update arrives (straggler effect, Fig. 2
          left); barrier idle time is tracked explicitly;
  async — continuous aggregation: updates are applied in completion-time
          order with staleness weighting α(τ)=α₀(1+τ)^-0.5; the round
          clock advances at a QUORUM of arrivals (default 50%), so fast
          clients never wait for stragglers (Fig. 2 right). Straggler
          updates are still applied, discounted by their staleness.

Composable strategy flags mirror the paper's ablations (Table III):
  theta            — gradient-sign-alignment client-side filter (§IV-C);
                     the reference direction is the sign of the LAST
                     GLOBAL UPDATE (w_g^t − w_g^{t−1}), per Algorithm 1
  selection        — adaptive top-k client selection from reliability EMAs
  dynamic_batch    — capacity-proportional batch assignment (§IV-A)
  checkpointing    — Weibull-interval checkpoint/restore on dropout (§IV-C)

Execution: by default each round's client work runs as ONE compiled
cohort megastep (core/megastep.py) — selected clients' fixed-shape
batches are stacked into (C, steps, B, ...) and a single jitted
vmap-of-scan returns per-client deltas (packed into the flat parameter
arena), losses, sign-alignment ratios and update norms; server
aggregation is one weighted arena sum (Pallas on TPU, jnp oracle on
CPU). Heterogeneous (steps, batch) shapes fall into a handful of
power-of-two groups, each one dispatch. ``megastep=False`` selects the
original per-client Python loop, kept as the seeded reference
implementation (tests/test_megastep.py pins the two trajectories to each
other). Timing and byte accounting stay event-driven in Python either
way, consuming the batched device results.

Simulated time model (recorded separately from real wall time):
  train_time  = steps · batch · t_sample / speed
  comm_time   = latency + bytes/bandwidth   (only if the update is SENT —
                filtered clients transmit a 1-bit "skip" beacon)
All stochastic choices draw from a seeded Generator → runs are exactly
reproducible; with equal speeds, zero latency, no dropout, full quorum and
theta=None, the async trajectory coincides with sync FedAvg (tested).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, alignment, compression
from repro.core import control as control_mod
from repro.core import megastep as megastep_mod
from repro.core import scenario as scenario_mod
from repro.core.batchsize import BatchSizeController, ClientMetrics
from repro.core.checkpoint_policy import fit_weibull, optimal_interval
from repro.core.schedule import ScheduleSpec
from repro.core.selection import AdaptiveClientSelector, candidate_mask_np
from repro.data.loader import ArrayLoader, LoaderPool
from repro.kernels import arena as arena_mod
from repro.models import api
from repro.optim import adamw as optim_mod
from repro.topology import engine as topology_mod
from repro.topology.spec import resolve_topology


@dataclasses.dataclass
class CommModel:
    bandwidth: float = 1e9        # bytes/s client->server
    latency: float = 0.05         # s per message
    t_sample: float = 2e-6        # s of compute per training sample (ref speed)
    t_launch: float = 0.0         # fixed per-step dispatch overhead — the
                                  # paper's kernel-launch/memcpy cost that
                                  # large batches amortize (Tables V-VI)
    beacon_bytes: float = 0.125   # 1-bit "skip" beacon a θ-filtered client
                                  # still transmits (§IV-C); charged to both
                                  # bytes_sent and transfer time so the sim
                                  # and SPMD engines account identically


@dataclasses.dataclass
class ClientProfile:
    speed: float = 1.0            # relative compute throughput
    net_latency: float = 0.05
    dropout_p: float = 0.0
    memory: float = 1.0


@dataclasses.dataclass
class StrategyConfig:
    # mode / quorum / alpha0 are the LEGACY spelling of the server
    # schedule axis — engines consume a ScheduleSpec (core/schedule.py),
    # derived from these fields via ScheduleSpec.from_strategy when no
    # explicit schedule is given (see the CHANGES.md migration table)
    mode: str = "async"                   # async | sync
    theta: Optional[float] = 0.65         # None -> no filtering
    selection: bool = True
    select_fraction: float = 1.0          # top-k fraction when selecting
    dynamic_batch: bool = False
    checkpointing: bool = True
    local_epochs: int = 1
    batch_size: int = 64
    lr: float = 5e-3
    alpha0: float = 1.0                   # fresh-update weight in buffered
                                          # async aggregation: α(τ)=α₀(1+τ)^-½
                                          # discounts stale arrivals; τ=0 ->
                                          # exactly FedAvg over the senders.
                                          # (Sequential convex mixing with
                                          # α₀>0.2 chased the last arrival
                                          # and collapsed the θ-filter —
                                          # kept in EXPERIMENTS §Sim.)
    quorum: float = 0.5                   # async round advances at this frac
    per_client_lr: bool = False           # FedL2P-style personalization
    grad_norm_selection: bool = False     # ACFL-style critical-period proxy
    quantize_updates: bool = False        # beyond-paper §VI hybrid: int8 +
                                          # error feedback on the wire (4x
                                          # fewer bytes, multiplies with θ)
    max_samples_per_round: int = 4096     # per-round sample cap (NOT a step
                                          # cap: batch sizes then see equal
                                          # data, isolating the launch-
                                          # overhead effect the paper measures)


def local_step_count(n: int, batch_size: int, st: StrategyConfig) -> int:
    """Per-round local step count, quantized UP to powers of two.

    Heterogeneous client datasets otherwise produce a distinct
    (steps, batch) shape per client, and every distinct shape re-traces
    the jitted local scan — the dominant CPU cost at 100 clients.
    Power-of-two quantization caps the trace count at ~7 per batch size
    (and, on the megastep path, caps the number of cohort shape GROUPS —
    each group is one compiled dispatch per round).
    Shared with the spmd runner (repro.api) so both engines consume and
    account the same per-round sample volume.
    """
    cap = max(1, st.max_samples_per_round // batch_size)
    steps = max(1, math.ceil(st.local_epochs * n / batch_size))
    steps = min(steps, cap)
    steps = 1 << (steps - 1).bit_length()          # next power of two
    return min(steps, cap)


@dataclasses.dataclass
class RoundMetrics:
    round: int
    sim_time: float          # simulated end-to-end wall clock so far
    comm_time: float         # cumulative transfer seconds
    idle_time: float         # cumulative barrier-idle seconds (sync only)
    bytes_sent: float
    updates_applied: int
    accept_rate: float
    accuracy: float
    loss: float


class FederatedSimulation:
    def __init__(self, cfg, client_arrays: List[dict], eval_arrays: dict,
                 strategy: StrategyConfig, profiles: List[ClientProfile],
                 comm: CommModel = None, seed: int = 0,
                 eval_fn: Callable = None, eval_every: int = 1,
                 megastep: bool = True,
                 rounds_per_dispatch: Optional[int] = None,
                 schedule: Optional[ScheduleSpec] = None,
                 scenario: Optional[scenario_mod.ScenarioSpec] = None,
                 candidate_frac: Optional[float] = None,
                 candidate_shards: int = 8, topology=None,
                 fused_eval: bool = False):
        self.cfg = cfg
        self.strategy = strategy
        # schedule=None -> legacy StrategyConfig.mode shim
        self.schedule = (schedule if schedule is not None
                         else ScheduleSpec.from_strategy(strategy)).validate()
        self.comm = comm or CommModel()
        self.profiles = profiles
        self.rng = np.random.default_rng(seed)
        self.seed = seed
        self.num_clients = len(client_arrays)
        self.eval_arrays = eval_arrays
        # device-cache the eval batch ONCE (was re-transferred every round)
        self._eval_dev = jax.tree.map(jnp.asarray, eval_arrays)
        self.eval_every = max(1, int(eval_every))
        self.megastep = bool(megastep)
        # rounds_per_dispatch=None -> host control plane (per-round
        # megastep / reference loop); an int >= 1 -> the device-resident
        # control plane, R rounds per compiled dispatch (lax.scan)
        self.rounds_per_dispatch = (int(rounds_per_dispatch)
                                    if rounds_per_dispatch else None)
        if self.rounds_per_dispatch and not self.megastep:
            raise ValueError("rounds_per_dispatch requires megastep=True "
                             "(the scanned path runs on the parameter "
                             "arena)")
        # whole-experiment fusion: eval lives in the scan carry (no
        # per-dispatch host readback); needs the scanned path and the
        # default (traceable) eval — a custom eval_fn has no traceability
        # contract, so it keeps the host eval dispatch
        self.fused_eval = bool(fused_eval)
        if self.fused_eval and not self.rounds_per_dispatch:
            raise ValueError("fused_eval folds evaluation into the "
                             "scanned lax.scan carry — set "
                             "rounds_per_dispatch")
        if self.fused_eval and eval_fn is not None:
            raise ValueError("fused_eval traces the eval inside the "
                             "compiled scan; custom eval_fn callables "
                             "are not guaranteed traceable — drop one "
                             "of the two")
        # two-stage selection: None -> legacy single-stage; 1.0 is
        # bit-identical to it (all-True candidate mask) on every path
        self.candidate_frac = (None if candidate_frac is None
                               else float(candidate_frac))
        self.candidate_shards = max(1, int(candidate_shards))
        self._lazy_world = bool(getattr(client_arrays, "lazy", False))
        if self._lazy_world and self.rounds_per_dispatch:
            raise ValueError(
                "the scanned control plane gathers client data "
                "device-side, so the population must be resident — drop "
                "rounds_per_dispatch for lazy worlds")
        self.dispatches = 0           # compiled-call count (bench metric)

        # --- dynamic-world scenario (core/scenario.py) --------------------
        # None / inactive -> the world stays frozen at round 0 and every
        # code path below is bit-identical to the pre-scenario engine
        self.scenario = scenario_mod.resolve_scenario(scenario)
        self._world_state = scenario_mod.init_world(self.scenario,
                                                    len(client_arrays))
        self._world_view = None       # this round's host view (or None)
        self._drift_dirs = None
        self._drift_label = None
        if self.scenario is not None and self.scenario.drift is not None:
            keys = set(client_arrays[0])
            if "x" not in keys or "y" not in keys:
                raise ValueError(
                    "scenario.drift needs feature/label client arrays "
                    f"('x' + 'y'); got {sorted(keys)}")
            self._drift_label = "y"
            self._drift_dirs = jnp.asarray(scenario_mod.drift_directions(
                self.scenario.drift, cfg.num_classes, cfg.num_features))

        # --- model/optim setup ------------------------------------------
        self._params_tree = api.init_params(jax.random.PRNGKey(seed), cfg)
        self.param_bytes = sum(x.size * x.dtype.itemsize
                               for x in jax.tree.leaves(self._params_tree))
        self.opt = optim_mod.sgd(lr=strategy.lr)
        self.ref_sign = None          # sign(w_g^t − w_g^{t−1}); None round 0
        self._local_run = self._build_local_run()
        self._eval = eval_fn or self._build_eval()

        # --- cohort megastep / parameter arena ----------------------------
        self._arena = arena_mod.ParamArena(self._params_tree)
        self._params_mat = None       # canonical device state when megastep
        self._ref_mat = None          # (rows, lane) int8, -2 padding
        self._ef_arena = None         # (N, rows, lane) batched EF buffers
        if self.megastep:
            self._params_mat = self._arena.pack(self._params_tree)
            self._cohort_step = megastep_mod.build_cohort_step(
                cfg, self.opt, self._arena, theta=strategy.theta,
                quantize=strategy.quantize_updates)
            self._apply_update = megastep_mod.build_apply_update(self._arena)
            self._unpack = jax.jit(self._arena.unpack)
            if strategy.quantize_updates:
                # +1 dummy row absorbs the EF residuals of cohort-width
                # padding rows (see _run_round_mega pass 3)
                self._ef_arena = compression.init_error_arena(
                    self.num_clients + 1, self._arena)

        # --- hierarchical topology (repro.topology) -----------------------
        # an accumulate-and-sync measurement layer over the flat round:
        # the training trajectory is untouched (None / single-tier is
        # bit-identical to today's path); the carry advances EVERY round
        # on every execution path so the absolute-round sync cadence is
        # independent of loop/mega/scanned grouping
        self.topology = resolve_topology(topology)
        self._topo = None
        self._topo_state = None
        if self.topology is not None:
            self._topo = topology_mod.TopologyRuntime(
                self.topology, self.num_clients, self._arena, self.comm)
            self._topo_state = self._topo.init()
            self._topo_step = jax.jit(self._topo.step)

        # --- per-client state --------------------------------------------
        self.batch_ctrl = BatchSizeController()

        def initial_bs(cid: int) -> int:
            bs = strategy.batch_size
            if strategy.dynamic_batch:
                p = profiles[cid]
                bs = self.batch_ctrl.initial(cid, ClientMetrics(
                    compute=p.speed, memory=p.memory,
                    latency=p.net_latency))
            return bs

        if self._lazy_world:
            # non-resident world: loaders (and the client shards behind
            # them) materialize per selected cohort, LRU-bounded — host
            # memory scales with cohort size, not population
            k = max(1, int(strategy.select_fraction * self.num_clients))
            self.loaders = LoaderPool(client_arrays, initial_bs,
                                      seed=seed,
                                      capacity=max(4 * k, 64))
        else:
            self.loaders = [ArrayLoader(arrays, initial_bs(cid),
                                        seed=seed + cid)
                            for cid, arrays in enumerate(client_arrays)]
        self.selector = AdaptiveClientSelector(self.num_clients, seed=seed)
        self.client_lr_scale = np.ones(self.num_clients)
        self.grad_norms = np.ones(self.num_clients)

        # --- fault tolerance ----------------------------------------------
        self.failure_log: List[float] = []
        self.checkpoints: Dict[int, bool] = {}
        self.ckpt_interval = 10.0
        self.recovery_time = 0.2      # restore from checkpoint
        self.restart_time = 1.0      # cold restart without one

        # --- compression (beyond-paper) -----------------------------------
        self._ef_state = {}
        self._wire_bytes = (compression.arena_wire_bytes(self._arena)
                            if (self.megastep and strategy.quantize_updates)
                            else None)

        # --- unified staleness weights (one jnp impl for both engines):
        # τ < #arrivals <= N, so one table lookup replaces the per-arrival
        # host formula — identical values on every execution path
        self._alpha_table = aggregation.staleness_weights_np(
            np.arange(self.num_clients + 1), self.schedule.alpha0)

        # --- device-resident control plane (scanned path, built lazily) ---
        self._scan_fns: Dict[int, Callable] = {}   # R -> jitted scan
        self._scan_world = None                    # (data, sizes, profiles)
        self._scan_ctl = None                      # ControlState carry
        self._scan_ref_valid = jnp.asarray(False)
        self._scan_round0 = 0
        self._scan_key = jax.random.fold_in(jax.random.PRNGKey(seed), 7)

        # --- accounting -----------------------------------------------------
        self.sim_time = 0.0
        self.comm_time = 0.0
        self.idle_time = 0.0
        self.bytes_sent = 0.0
        self.server_step = 0
        self.round_idx = 0            # absolute rounds completed — run()
                                      # calls CONTINUE numbering, so a
                                      # checkpointed/resumed session is
                                      # label-identical to an unbroken one
        self.history: List[RoundMetrics] = []

    # ------------------------------------------------------------------
    # parameter state (pytree view lazily unpacked from the arena)
    # ------------------------------------------------------------------
    @property
    def params(self):
        if self._params_tree is None:
            self._params_tree = self._unpack(self._params_mat)
            self.dispatches += 1
        return self._params_tree

    @params.setter
    def params(self, tree):
        self._params_tree = tree

    # ------------------------------------------------------------------
    # jitted pieces
    # ------------------------------------------------------------------
    def _build_local_run(self):
        cfg, opt = self.cfg, self.opt

        @jax.jit
        def run(params, batches, lr_scale):
            opt_state = opt.init(params)

            def step(carry, batch):
                p, s = carry
                loss, grads = jax.value_and_grad(
                    lambda q: api.loss_fn(q, batch, cfg))(p)
                grads = jax.tree.map(lambda g: g * lr_scale, grads)
                p, s = opt.update(grads, s, p)
                return (p, s), loss

            (params, _), losses = jax.lax.scan(step, (params, opt_state), batches)
            return params, losses.mean()

        return run

    def _build_eval(self):
        return api.build_default_eval(self.cfg)

    # ------------------------------------------------------------------
    # client-local training (simulated timing + real gradients)
    # ------------------------------------------------------------------
    def _client_batches(self, cid: int):
        """Fixed-step resampled batches -> stable jit shapes (step count
        from ``local_step_count``)."""
        loader = self.loaders[cid]
        bs = loader.batch_size
        steps = local_step_count(loader.n, bs, self.strategy)
        batches = [loader.sample() for _ in range(steps)]
        stacked = {k: np.stack([b[k] for b in batches]) for k in batches[0]}
        return stacked, steps, steps * bs

    def _train_time(self, steps: int, n_samples: int,
                    prof: ClientProfile) -> float:
        """Per-step dispatch overhead + per-sample compute (paper §IV-A:
        larger batches -> fewer steps -> amortized launch cost)."""
        return (steps * self.comm.t_launch
                + n_samples * self.comm.t_sample) / max(prof.speed, 1e-3)

    def _train_client(self, cid: int):
        batches, steps, n_samples = self._client_batches(cid)
        dev = jax.tree.map(jnp.asarray, batches)
        if self._drift_dirs is not None:
            dev = scenario_mod.apply_drift(
                dev, jnp.float32(self._world_view["drift_amp"]),
                self._drift_dirs, self._drift_label)
        new_params, loss = self._local_run(
            self.params, dev, jnp.float32(self.client_lr_scale[cid]))
        self.dispatches += 1
        prof = self.profiles[cid]
        train_time = self._train_time(steps, n_samples, prof)
        delta = jax.tree.map(lambda n, o: (n - o).astype(jnp.float32),
                             new_params, self.params)
        wv = self._world_view
        if wv is not None and float(wv["byz_factor"][cid]) != 1.0:
            # byzantine corruption BEFORE wire compression — the client
            # transmits (and the θ-filter scores) the corrupted update
            f = jnp.float32(wv["byz_factor"][cid])
            delta = jax.tree.map(lambda d: d * f, delta)
            new_params = jax.tree.map(
                lambda o, d: (o.astype(jnp.float32) + d).astype(o.dtype),
                self.params, delta)
        if self.strategy.quantize_updates:
            # int8 + error feedback on the wire; server dequantizes
            err = self._ef_state.setdefault(
                cid, compression.init_error_state(delta))
            q, s, _n, self._ef_state[cid] = compression.compress_update(
                delta, err)
            delta = compression.decompress_update(q, s, delta)
            new_params = jax.tree.map(
                lambda o, d: (o.astype(jnp.float32) + d).astype(o.dtype),
                self.params, delta)
            self._wire_bytes = compression.transport_bytes(q, s)
            self.dispatches += 2
        return new_params, delta, float(loss), train_time

    def _filter_update(self, delta) -> tuple:
        """Client-side sign-alignment filter (Algorithm 1 lines 27-32)."""
        if self.strategy.theta is None or self.ref_sign is None:
            return True, 1.0
        ratio = float(alignment.alignment_ratio(delta, self.ref_sign))
        self.dispatches += 1
        return ratio >= self.strategy.theta, ratio

    def _payload_bytes(self) -> float:
        if self.strategy.quantize_updates and self._wire_bytes:
            return float(self._wire_bytes)
        return float(self.param_bytes)

    def _transfer_time(self, sent: bool, prof: ClientProfile,
                       cid: Optional[int] = None) -> float:
        lat, bw = prof.net_latency, self.comm.bandwidth
        wv = self._world_view
        if wv is not None and cid is not None:
            # link-quality walk re-prices this round's transfer
            lat *= float(wv["lat_scale"][cid])
            bw *= float(wv["bw_scale"][cid])
        if sent:
            return lat + self._payload_bytes() / bw
        # 1-bit skip beacon: still a message, still on the wire
        return lat + self.comm.beacon_bytes / bw

    # ------------------------------------------------------------------
    # hierarchical topology (host paths)
    # ------------------------------------------------------------------
    def _topology_host_round(self, deltas, cids, weights) -> None:
        """Advance the topology carry for the round that just ran on a
        host path (loop/megastep): leaf-pod accumulation of exactly the
        weighted deltas the flat aggregation consumed, plus any due
        inter-tier syncs. Called EVERY round — the cadence is a closed
        form on the absolute round index (``round_idx - 1``; run_round
        already counted this round), matching the scanned carry.

        deltas: list of (rows, lane) arena rows (device), or a list of
        (cids, padded, deltas) shape groups from the megastep path;
        cids: matching client ids; weights: cid -> aggregation weight.
        """
        if self._topo is None:
            return
        r = self.round_idx - 1
        if deltas and isinstance(deltas[0], tuple):
            groups = deltas
            d = jnp.concatenate([g[2][:len(g[0])] for g in groups])
            cids = [c for g in groups for c in g[0]]
        elif deltas:
            d = jnp.stack(deltas)
        else:                              # empty round: cadence still ticks
            d = jnp.zeros((1, self._arena.rows, self._arena.lane),
                          jnp.float32)
            cids = [0]
        w = jnp.asarray([float(weights.get(c, 0.0)) for c in cids],
                        jnp.float32)
        pods = self._topo.pod_of[jnp.asarray(cids, jnp.int32)]
        self._topo_state = self._topo_step(self._topo_state, jnp.int32(r),
                                           d, w, pods)
        self.dispatches += 1

    def topology_summary(self) -> Optional[dict]:
        """Per-tier inter-tier byte/time/sync accounting + the flat-star
        comparison (None when no topology is attached)."""
        if self._topo is None:
            return None
        return self._topo.summary(self._topo_state, rounds=self.round_idx)

    # ------------------------------------------------------------------
    # rounds
    # ------------------------------------------------------------------
    def _select_clients(self) -> List[int]:
        """This round's cohort. Under scenario churn the live roster is
        applied BEFORE top-k — matching the scanned/spmd control plane,
        which masks churned-out scores to -inf before selecting — so
        every execution path fills its cohort from the same candidate
        set (churned clients are absent, never observed, not failed)."""
        st = self.strategy
        k = max(1, int(st.select_fraction * self.num_clients))
        wv = self._world_view
        live = wv["live"] if wv is not None else None
        if st.grad_norm_selection:
            gn = self.grad_norms
            if live is not None:
                gn = np.where(live, gn, -np.inf)
            selected = [int(c) for c in np.argsort(-gn)[:k]
                        if live is None or live[c]]
        elif st.selection and st.select_fraction < 1.0:
            candidates = None
            if self.candidate_frac is not None:
                # stage 1: the sharded candidate pre-filter, computed on
                # the SAME effective scores the device paths rank (live
                # mask applied before the per-shard top-k). frac=1.0 is
                # an all-True mask -> bit-identical selections.
                scores = np.array([self.selector.score(c)
                                   for c in range(self.num_clients)])
                if live is not None:
                    scores = np.where(np.asarray(live, bool), scores,
                                      -np.inf)
                candidates = candidate_mask_np(scores, k,
                                               self.candidate_frac,
                                               self.candidate_shards)
            selected = self.selector.select(k, live=live,
                                            candidates=candidates)
        else:
            selected = [c for c in range(self.num_clients)
                        if live is None or live[c]]
        return selected

    def _dropout_p(self, prof: ClientProfile) -> float:
        wv = self._world_view
        scale = wv["dropout_scale"] if wv is not None else 1.0
        return min(1.0, prof.dropout_p * scale)

    def _advance_world(self) -> None:
        """Transition the WorldState for the round now starting (the
        absolute index ``round_idx - 1``: run_round already counted it)
        and cache one host view for this round's event accounting."""
        if self.scenario is None:
            return
        self._world_state = scenario_mod.world_step(
            self._world_state, self.round_idx - 1, self.scenario,
            self.num_clients)
        self._world_view = scenario_mod.host_view(self._world_state)

    def run_round(self, rnd: int, evaluate: bool = True) -> RoundMetrics:
        self.round_idx += 1
        self._advance_world()
        if self.megastep:
            return self._run_round_mega(rnd, evaluate)
        return self._run_round_loop(rnd, evaluate)

    def _finish_round(self, rnd: int, evaluate: bool, n_selected: int,
                      losses: List[float], n_sent: int, updates_applied: int,
                      round_times: Dict[int, float]) -> RoundMetrics:
        """Round tail shared by both execution paths: Weibull checkpoint
        refit, dynamic-batch feedback, (optional) evaluation, metrics."""
        st = self.strategy
        if st.checkpointing and len(self.failure_log) >= 2:
            lam, k = fit_weibull(np.diff(sorted(self.failure_log)))
            self.ckpt_interval = optimal_interval(
                max(self.sim_time, 1.0), self.recovery_time, lam, k)
        if st.dynamic_batch:
            for cid, b in self.batch_ctrl.feedback(round_times).items():
                if cid < len(self.loaders):
                    self.loaders[cid].set_batch_size(b)
        if evaluate:
            acc = float(self._eval(self.params, self._eval_dev))
            self.dispatches += 1
        else:
            # off-round: carry the last measured accuracy forward
            acc = self.history[-1].accuracy if self.history else float("nan")
        m = RoundMetrics(
            round=rnd, sim_time=self.sim_time, comm_time=self.comm_time,
            idle_time=self.idle_time, bytes_sent=self.bytes_sent,
            updates_applied=updates_applied,
            accept_rate=n_sent / max(n_selected, 1), accuracy=acc,
            loss=float(np.mean(losses)) if losses else float("nan"))
        self.history.append(m)
        return m

    # ------------------------------------------------------------------
    # megastep path: one compiled dispatch per cohort shape group
    # ------------------------------------------------------------------
    def _run_round_mega(self, rnd: int, evaluate: bool = True) -> RoundMetrics:
        st = self.strategy
        selected = self._select_clients()
        round_start = self.sim_time

        # pass 1: dropout draws — SAME Generator order as the loop path
        cohort: List[int] = []
        meta: Dict[int, tuple] = {}       # cid -> (delay, steps, n_samples)
        for cid in selected:
            prof = self.profiles[cid]
            delay = 0.0
            if self.rng.random() < self._dropout_p(prof):
                self.failure_log.append(round_start)
                self.selector.observe(cid, delivered=False)
                if not st.checkpointing:
                    continue                      # client lost this round
                delay = (self.recovery_time if self.checkpoints.get(cid)
                         else self.restart_time)
            cohort.append(cid)
            meta[cid] = (delay, 0, 0)

        # pass 2: per-loader batch draws (per-client Generators — identical
        # draws to the loop path), grouped by rectangular (steps, batch)
        groups: Dict[tuple, dict] = {}
        for cid in cohort:
            batches, steps, n_samples = self._client_batches(cid)
            meta[cid] = (meta[cid][0], steps, n_samples)
            g = groups.setdefault((steps, self.loaders[cid].batch_size),
                                  {"cids": [], "batches": []})
            g["cids"].append(cid)
            g["batches"].append(batches)

        # pass 3: ONE compiled dispatch per shape group — per-client
        # deltas stay on device in the arena; only (C,)-vectors come home.
        # The cohort width is bucketed UP to a power of two (padding
        # replicates the last client; pad results are discarded and pad
        # aggregation weights are zero) so dropout-varying survivor
        # counts reuse compiled traces instead of re-tracing per C.
        has_ref = self._ref_mat is not None and st.theta is not None
        per_client: Dict[int, tuple] = {}     # cid -> (loss, ratio, norm)
        group_results = []                    # (cids, padded_C, deltas_dev)
        for (steps, bs), g in groups.items():
            cids = g["cids"]
            C = len(cids)
            padded = 1 << (C - 1).bit_length()
            blist = g["batches"] + [g["batches"][-1]] * (padded - C)
            batch = {k: jnp.asarray(np.stack([b[k] for b in blist]))
                     for k in blist[0]}
            if self._drift_dirs is not None:
                # same elementwise shift as the loop path's per-client
                # batches — bit-identical regardless of cohort stacking
                batch = scenario_mod.apply_drift(
                    batch, jnp.float32(self._world_view["drift_amp"]),
                    self._drift_dirs, self._drift_label)
            lr_scale = np.ones(padded, np.float32)
            lr_scale[:C] = self.client_lr_scale[cids]
            byz = None
            wv = self._world_view
            if wv is not None and (wv["byz_factor"] != 1.0).any():
                byz_np = np.ones(padded, np.float32)
                byz_np[:C] = wv["byz_factor"][cids]
                byz = jnp.asarray(byz_np)
            idx = None
            if st.quantize_updates:
                # pad rows scatter their EF residual into the dummy row
                # (index num_clients) of the (N+1)-row error arena
                idx = jnp.asarray(
                    np.concatenate([cids, np.full(padded - C,
                                                  self.num_clients)]),
                    jnp.int32)
            deltas, losses, ratios, norms, new_ef = self._cohort_step(
                self._params_mat, batch, jnp.asarray(lr_scale), byz,
                self._ref_mat if has_ref else None,
                self._ef_arena, idx, has_ref=has_ref)
            self.dispatches += 1
            if st.quantize_updates:
                self._ef_arena = new_ef
            losses, ratios, norms = (np.asarray(losses), np.asarray(ratios),
                                     np.asarray(norms))
            for j, cid in enumerate(cids):
                per_client[cid] = (float(losses[j]), float(ratios[j]),
                                   float(norms[j]))
            group_results.append((cids, padded, deltas))

        # pass 4: event-driven accounting, in the loop path's client order
        losses_all: List[float] = []
        arrivals = []                     # (arrive, cid, sent)
        round_times: Dict[int, float] = {}
        n_sent = 0
        for cid in cohort:
            delay, steps, n_samples = meta[cid]
            loss, ratio, gn = per_client[cid]
            prof = self.profiles[cid]
            losses_all.append(loss)
            sent = (st.theta is None or not has_ref
                    or ratio >= st.theta)
            transfer = self._transfer_time(sent, prof, cid)
            arrive = (round_start + delay
                      + self._train_time(steps, n_samples, prof) + transfer)
            arrivals.append((arrive, cid, sent))
            round_times[cid] = arrive - round_start
            self.selector.observe(cid, delivered=True, passed=sent,
                                  round_time=arrive - round_start)
            self.grad_norms[cid] = 0.5 * self.grad_norms[cid] + 0.5 * gn
            if st.per_client_lr:
                self.client_lr_scale[cid] = float(np.clip(
                    self.client_lr_scale[cid] * (1.05 if gn < 1.0 else 0.9),
                    0.25, 2.0))
            if sent:
                n_sent += 1
                self.bytes_sent += self._payload_bytes()
            else:
                self.bytes_sent += self.comm.beacon_bytes
            self.comm_time += transfer
            if st.checkpointing:
                self.checkpoints[cid] = True   # periodic local state save

        arrivals.sort(key=lambda a: a[0])
        updates_applied = 0
        sched = self.schedule
        weights: Dict[int, float] = {}    # cid -> aggregation weight

        if sched.is_sync:
            senders = [cid for (_, cid, sent) in arrivals if sent]
            if senders:
                w = 1.0 / len(senders)
                weights = {cid: w for cid in senders}
                self.server_step += 1
                updates_applied = len(senders)
            if arrivals:
                barrier = arrivals[-1][0]
                self.idle_time += sum(barrier - a for (a, *_r) in arrivals)
                self.sim_time = barrier
        else:
            # async: quorum clock + FedBuff-style buffered mean of
            # staleness-discounted deltas (see the loop path's notes);
            # semi-async DROPS arrivals staler than the bound instead of
            # discounting them (bounded-staleness aggregation)
            if arrivals:
                q_idx = max(0, math.ceil(sched.quorum * len(arrivals)) - 1)
                self.sim_time = arrivals[q_idx][0]
                buf = []
                for i, (_arrive, cid, sent) in enumerate(arrivals):
                    if not sent:
                        continue
                    tau = max(0, i - q_idx)
                    if (sched.max_staleness is not None
                            and tau > sched.max_staleness):
                        continue          # too stale: transmitted, dropped
                    alpha = float(self._alpha_table[tau])
                    buf.append((cid, alpha))
                    self.server_step += 1
                    updates_applied += 1
                if buf:
                    inv = 1.0 / len(buf)
                    weights = {cid: alpha * inv for cid, alpha in buf}

        # server aggregation: ONE weighted arena sum over all shape groups
        # (w_g ← w_anchor + Σ w_i·Δ_i covers both sync FedAvg and async
        # staleness buffering — no per-round pytree stacking)
        if weights:
            d_groups = tuple(d for (_cids, _p, d) in group_results)
            w_groups = []
            for cids, padded, _d in group_results:
                w = np.zeros(padded, np.float32)    # pad rows weigh nothing
                w[:len(cids)] = [weights.get(c, 0.0) for c in cids]
                w_groups.append(jnp.asarray(w))
            new_mat, ref_mat = self._apply_update(self._params_mat,
                                                  d_groups, tuple(w_groups))
            self.dispatches += 1
            self._params_mat = new_mat
            self._params_tree = None      # pytree view now stale
            # reference direction = sign of the global movement this round
            if updates_applied and st.theta is not None:
                self._ref_mat = ref_mat

        self._topology_host_round(group_results, None, weights)

        return self._finish_round(rnd, evaluate, len(selected), losses_all,
                                  n_sent, updates_applied, round_times)

    # ------------------------------------------------------------------
    # reference path: the original per-client loop (O(clients) dispatches
    # per round) — kept as the seeded oracle the megastep is pinned to
    # ------------------------------------------------------------------
    def _run_round_loop(self, rnd: int, evaluate: bool = True) -> RoundMetrics:
        st = self.strategy
        selected = self._select_clients()
        round_start = self.sim_time
        prev_params = self.params
        arrivals = []   # (arrive, cid, new_params, sent, transfer)
        round_times: Dict[int, float] = {}
        losses = []
        n_sent = 0
        topo_deltas: List = []        # arena-packed rows (topology only)
        topo_cids: List[int] = []

        for cid in selected:
            prof = self.profiles[cid]
            delay = 0.0
            if self.rng.random() < self._dropout_p(prof):
                self.failure_log.append(round_start)
                self.selector.observe(cid, delivered=False)
                if not st.checkpointing:
                    continue                      # client lost this round
                delay = (self.recovery_time if self.checkpoints.get(cid)
                         else self.restart_time)
            new_params, delta, loss, t_train = self._train_client(cid)
            if self._topo is not None:
                topo_deltas.append(self._arena.pack(delta))
                topo_cids.append(cid)
            losses.append(loss)
            sent, ratio = self._filter_update(delta)
            transfer = self._transfer_time(sent, prof, cid)
            arrive = round_start + delay + t_train + transfer
            arrivals.append((arrive, cid, new_params, sent, transfer))
            round_times[cid] = arrive - round_start
            self.selector.observe(cid, delivered=True, passed=sent,
                                  round_time=arrive - round_start)
            gn = float(np.sqrt(sum(float(jnp.vdot(g, g))
                                   for g in jax.tree.leaves(delta))))
            self.dispatches += 1
            self.grad_norms[cid] = 0.5 * self.grad_norms[cid] + 0.5 * gn
            if st.per_client_lr:
                self.client_lr_scale[cid] = float(np.clip(
                    self.client_lr_scale[cid] * (1.05 if gn < 1.0 else 0.9),
                    0.25, 2.0))
            if sent:
                n_sent += 1
                self.bytes_sent += self._payload_bytes()
            else:
                self.bytes_sent += self.comm.beacon_bytes
            self.comm_time += transfer
            if st.checkpointing:
                self.checkpoints[cid] = True   # periodic local state save

        arrivals.sort(key=lambda a: a[0])
        updates_applied = 0
        sched = self.schedule
        weights: Dict[int, float] = {}    # cid -> aggregation weight

        if sched.is_sync:
            sent_params = [p for (_, _, p, sent, _) in arrivals if sent]
            if sent_params:
                stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *sent_params)
                self.params = aggregation.fedavg(stacked)
                self.dispatches += 1
                self.server_step += 1
                updates_applied = len(sent_params)
                w1 = 1.0 / len(sent_params)
                weights = {cid: w1 for (_, cid, _p, sent, _t) in arrivals
                           if sent}
            if arrivals:
                barrier = arrivals[-1][0]
                self.idle_time += sum(barrier - a for (a, *_r) in arrivals)
                self.sim_time = barrier
        else:
            # async: clock advances at the quorum arrival; later updates are
            # stale (they overlap the next round) and are discounted.
            # Aggregation is FedBuff-style BUFFERED (mean of staleness-
            # discounted deltas): sequential convex mixing over-weights the
            # last arrival and destabilizes the θ-filter (EXPERIMENTS §Sim).
            # semi-async drops arrivals staler than the bound entirely.
            if arrivals:
                q_idx = max(0, math.ceil(sched.quorum * len(arrivals)) - 1)
                self.sim_time = arrivals[q_idx][0]
                buf = []
                buf_cids = []
                for i, (arrive, cid, new_params, sent, _t) in enumerate(arrivals):
                    if not sent:
                        continue
                    tau = max(0, i - q_idx)
                    if (sched.max_staleness is not None
                            and tau > sched.max_staleness):
                        continue          # too stale: transmitted, dropped
                    alpha = float(self._alpha_table[tau])
                    buf.append((alpha, new_params))
                    buf_cids.append(cid)
                    self.server_step += 1
                    updates_applied += 1
                if buf:
                    self.params = aggregation.buffered_async_update(
                        self.params, buf)
                    self.dispatches += 1
                    inv = 1.0 / len(buf)
                    weights = {c: a * inv
                               for c, (a, _p) in zip(buf_cids, buf)}

        self._topology_host_round(topo_deltas, topo_cids, weights)

        # reference direction = sign of the global movement this round
        if updates_applied and st.theta is not None:
            self.ref_sign = jax.tree.map(
                lambda n, o: jnp.sign(n.astype(jnp.float32)
                                      - o.astype(jnp.float32)).astype(jnp.int8),
                self.params, prev_params)
            self.dispatches += 1

        return self._finish_round(rnd, evaluate, len(selected), losses,
                                  n_sent, updates_applied, round_times)

    # ------------------------------------------------------------------
    # scanned path: the device-resident control plane — R rounds of
    # {select -> train -> θ-filter -> aggregate -> control update} per
    # compiled dispatch (core/megastep.build_scanned_rounds)
    # ------------------------------------------------------------------
    def _scan_setup(self):
        """Build the device world + ControlState once (lazy)."""
        if self._scan_world is not None:
            return self._scan_world
        if self._lazy_world:
            raise RuntimeError(
                "the scanned control plane stacks the full population "
                "device-side; non-resident worlds run the loop/megastep "
                "paths")
        cap = max(l.n for l in self.loaders)
        data = {}
        for k in self.loaders[0].arrays:
            stacked = []
            for l in self.loaders:
                a = np.asarray(l.arrays[k])
                pad = np.zeros((cap - len(a),) + a.shape[1:], a.dtype)
                stacked.append(np.concatenate([a, pad]) if len(pad)
                               else a)
            data[k] = jnp.asarray(np.stack(stacked))
        sizes = jnp.asarray([l.n for l in self.loaders], jnp.int32)
        speed = jnp.asarray([p.speed for p in self.profiles], jnp.float32)
        latency = jnp.asarray([p.net_latency for p in self.profiles],
                              jnp.float32)
        dropout_p = jnp.asarray([p.dropout_p for p in self.profiles],
                                jnp.float32)
        self._scan_world = (data, sizes, speed, latency, dropout_p)
        self._scan_ctl = control_mod.init_control(
            self.num_clients,
            batch_sizes=[l.batch_size for l in self.loaders],
            arena=self._arena,
            quantize=self.strategy.quantize_updates)
        return self._scan_world

    def _scan_shapes(self):
        """Static (select_k, steps_phys, batch_phys) of the scanned trace."""
        st = self.strategy
        k = max(1, int(st.select_fraction * self.num_clients))
        if not (st.grad_norm_selection
                or (st.selection and st.select_fraction < 1.0)):
            k = self.num_clients
        batch_phys = min(l.batch_size for l in self.loaders)
        steps_phys = min(local_step_count(l.n, batch_phys, st)
                         for l in self.loaders)
        return k, steps_phys, batch_phys

    def _scan_fn(self, R: int):
        if R not in self._scan_fns:
            k, steps_phys, batch_phys = self._scan_shapes()
            self._scan_fns[R] = megastep_mod.build_scanned_rounds(
                self.cfg, self.opt, self._arena, self.strategy, self.comm,
                num_clients=self.num_clients, select_k=k,
                steps_phys=steps_phys, batch_phys=batch_phys,
                rounds_per_dispatch=R, param_bytes=self.param_bytes,
                wire_bytes=self._wire_bytes,
                recovery_time=self.recovery_time,
                restart_time=self.restart_time,
                schedule=self.schedule,
                scenario=self.scenario, drift_dirs=self._drift_dirs,
                drift_label=self._drift_label or "y",
                candidate_frac=self.candidate_frac,
                candidate_shards=self.candidate_shards,
                topology=self._topo,
                eval_fn=(self._eval if self.fused_eval else None),
                eval_every=self.eval_every)
        return self._scan_fns[R]

    def _run_scanned(self, num_rounds: int,
                     eval_final: bool = True) -> List[RoundMetrics]:
        data, sizes, speed, latency, dropout_p = self._scan_setup()
        R = self.rounds_per_dispatch
        ref_mat = self._ref_mat
        if ref_mat is None:      # no reference yet; gated by ref_valid
            ref_mat = jnp.where(jnp.asarray(self._arena.valid_mask()),
                                jnp.int8(0), jnp.int8(-2))
        start = self.round_idx   # absolute round labels across run() calls
        done = 0
        while done < num_rounds:
            Rg = min(R, num_rounds - done)
            last = start + done + Rg - 1
            prev_acc = (self.history[-1].accuracy if self.history
                        else float("nan"))
            args = [self._params_mat, ref_mat, self._scan_ref_valid,
                    self._scan_ctl, self._world_state, self._topo_state,
                    data, sizes, speed, latency, dropout_p,
                    self._scan_key, jnp.int32(self._scan_round0),
                    jnp.asarray([self.sim_time, self.comm_time,
                                 self.idle_time, self.bytes_sent],
                                jnp.float32)]
            if self.fused_eval:
                # eval rides the scan carry: only the final round of the
                # whole run() is forced (eval_final), the rest follow
                # the absolute-round eval_every cadence inside the scan
                mark = (last if (eval_final
                                 and last == start + num_rounds - 1)
                        else -1)
                args += [jnp.float32(prev_acc), jnp.int32(mark),
                         self._eval_dev]
            carry, ms = self._scan_fn(Rg)(*args)
            self.dispatches += 1
            (self._params_mat, ref_mat, self._scan_ref_valid,
             self._scan_ctl, self._world_state, self._topo_state,
             *_rest) = carry
            self._params_tree = None          # pytree view now stale
            ms = {k: np.asarray(v) for k, v in ms.items()}

            if self.fused_eval:
                acc_val = None                # accuracy is per-round in ms
            else:
                # evaluate once per dispatch (at its last round) when the
                # eval cadence lands inside the dispatch or the run ends —
                # cadence over the ABSOLUTE round index, so a resumed
                # session keeps the uninterrupted run's eval rounds
                do_eval = (any(r % self.eval_every == 0
                               for r in range(start + done,
                                              start + done + Rg))
                           or (eval_final and last == start + num_rounds - 1))
                if do_eval:
                    acc_val = float(self._eval(self.params, self._eval_dev))
                    self.dispatches += 1
                else:
                    acc_val = None
            for j in range(Rg):
                is_last = j == Rg - 1
                self.history.append(RoundMetrics(
                    round=start + done + j,
                    sim_time=float(ms["sim_time"][j]),
                    comm_time=float(ms["comm_time"][j]),
                    idle_time=float(ms["idle_time"][j]),
                    bytes_sent=float(ms["bytes_sent"][j]),
                    updates_applied=int(ms["updates_applied"][j]),
                    accept_rate=float(ms["accept_rate"][j]),
                    accuracy=(float(ms["accuracy"][j]) if self.fused_eval
                              else (acc_val
                                    if (is_last and acc_val is not None)
                                    else prev_acc)),
                    loss=float(ms["loss"][j])))
            self.server_step += int(ms["updates_applied"].sum())
            # failure times are only known to round granularity on the
            # scanned path; log each at its round's start clock
            starts = [self.sim_time] + [float(t) for t
                                        in ms["sim_time"][:-1]]
            for j in range(Rg):
                self.failure_log.extend([starts[j]]
                                        * int(ms["n_failures"][j]))
            self.sim_time = float(ms["sim_time"][-1])
            self.comm_time = float(ms["comm_time"][-1])
            self.idle_time = float(ms["idle_time"][-1])
            self.bytes_sent = float(ms["bytes_sent"][-1])
            self._scan_round0 += Rg
            self.round_idx += Rg
            done += Rg
        self._ref_mat = (ref_mat if bool(self._scan_ref_valid) else None)
        return self.history

    # ------------------------------------------------------------------
    # full-state serialization (ExperimentSession.checkpoint/restore)
    # ------------------------------------------------------------------
    def state_dict(self) -> dict:
        """Everything a bit-identical resume needs, as host (picklable)
        values: parameters (arena matrix or pytree), the θ reference,
        every numpy Generator position (engine, loaders, selector), the
        control statistics of BOTH control planes (host EMAs and the
        scanned ``ControlState``), error-feedback buffers, fault/ckpt
        bookkeeping, accounting accumulators and round history. The
        training data itself is NOT stored — it is rebuilt
        deterministically from the spec's seed."""
        dev = jax.device_get
        return {
            "round_idx": self.round_idx,
            "rng": self.rng.bit_generator.state,
            "loaders": (self.loaders.state_dict() if self._lazy_world
                        else [{"batch_size": l.batch_size,
                               "rng": l.rng.bit_generator.state}
                              for l in self.loaders]),
            "selector": {
                "rng": self.selector.rng.bit_generator.state,
                "records": {cid: dataclasses.asdict(r)
                            for cid, r in self.selector.records.items()}},
            "batch_assignment": dict(self.batch_ctrl.assignment),
            "client_lr_scale": np.array(self.client_lr_scale),
            "grad_norms": np.array(self.grad_norms),
            "failure_log": list(self.failure_log),
            "checkpoints": dict(self.checkpoints),
            "ckpt_interval": float(self.ckpt_interval),
            "ef_state": {cid: dev(t) for cid, t in self._ef_state.items()},
            "ef_arena": (None if self._ef_arena is None
                         else dev(self._ef_arena)),
            "wire_bytes": self._wire_bytes,
            "params_mat": (dev(self._params_mat) if self.megastep
                           else None),
            "params_tree": (None if self.megastep
                            else dev(self._params_tree)),
            "ref_mat": (None if self._ref_mat is None
                        else dev(self._ref_mat)),
            "ref_sign": (None if self.ref_sign is None
                         else dev(self.ref_sign)),
            "world_state": (None if self.scenario is None
                            else dev(self._world_state)),
            "topology": (None if self._topo is None
                         else dev(self._topo_state)),
            "scan": {
                "ctl": (None if self._scan_ctl is None
                        else dev(self._scan_ctl)),
                "ref_valid": dev(self._scan_ref_valid),
                "round0": int(self._scan_round0),
                "key": dev(self._scan_key)},
            "sim_time": self.sim_time, "comm_time": self.comm_time,
            "idle_time": self.idle_time, "bytes_sent": self.bytes_sent,
            "server_step": self.server_step,
            "dispatches": self.dispatches,
            "history": [dataclasses.asdict(m) for m in self.history],
        }

    def load_state_dict(self, state: dict) -> None:
        """Restore :meth:`state_dict` output into a freshly-constructed
        simulation (same cfg/strategy/world/seed spec)."""
        def _gen(saved):
            g = np.random.default_rng(0)
            g.bit_generator.state = saved
            return g

        self.round_idx = state["round_idx"]
        self.rng = _gen(state["rng"])
        saved_loaders = state["loaders"]
        saved_lazy = (isinstance(saved_loaders, dict)
                      and saved_loaders.get("lazy"))
        if self._lazy_world != bool(saved_lazy):
            raise ValueError(
                "checkpoint world residency mismatch: saved "
                f"{'lazy' if saved_lazy else 'eager'} loaders, this "
                f"world is {'lazy' if self._lazy_world else 'eager'}")
        if self._lazy_world:
            self.loaders.load_state_dict(saved_loaders)
        else:
            if len(saved_loaders) != len(self.loaders):
                raise ValueError(
                    f"checkpoint has {len(saved_loaders)} client "
                    f"loaders, this world has {len(self.loaders)}")
            for l, s in zip(self.loaders, saved_loaders):
                l.batch_size = s["batch_size"]
                l.rng = _gen(s["rng"])
        self.selector.rng = _gen(state["selector"]["rng"])
        from repro.core.selection import ClientRecord
        self.selector.records = {
            cid: ClientRecord(**r)
            for cid, r in state["selector"]["records"].items()}
        self.batch_ctrl.assignment = dict(state["batch_assignment"])
        self.client_lr_scale = np.array(state["client_lr_scale"])
        self.grad_norms = np.array(state["grad_norms"])
        self.failure_log = list(state["failure_log"])
        self.checkpoints = dict(state["checkpoints"])
        self.ckpt_interval = state["ckpt_interval"]
        self._ef_state = {cid: jax.tree.map(jnp.asarray, t)
                          for cid, t in state["ef_state"].items()}
        self._ef_arena = (None if state["ef_arena"] is None
                          else jnp.asarray(state["ef_arena"]))
        self._wire_bytes = state["wire_bytes"]
        if self.megastep:
            self._params_mat = jnp.asarray(state["params_mat"])
            self._params_tree = None
        else:
            self._params_tree = jax.tree.map(jnp.asarray,
                                             state["params_tree"])
        self._ref_mat = (None if state["ref_mat"] is None
                         else jnp.asarray(state["ref_mat"]))
        self.ref_sign = (None if state["ref_sign"] is None
                         else jax.tree.map(jnp.asarray, state["ref_sign"]))
        if state.get("world_state") is not None:
            self._world_state = jax.tree.map(jnp.asarray,
                                             state["world_state"])
            self._world_view = scenario_mod.host_view(self._world_state)
        if state.get("topology") is not None:
            if self._topo is None:
                raise ValueError("checkpoint carries topology state but "
                                 "this simulation has no topology")
            self._topo_state = jax.tree.map(jnp.asarray, state["topology"])
        scan = state["scan"]
        if scan["ctl"] is not None:
            self._scan_setup()        # rebuild the device world and shapes
            self._scan_ctl = jax.tree.map(jnp.asarray, scan["ctl"])
        self._scan_ref_valid = jnp.asarray(scan["ref_valid"])
        self._scan_round0 = scan["round0"]
        self._scan_key = jnp.asarray(scan["key"])
        self.sim_time = state["sim_time"]
        self.comm_time = state["comm_time"]
        self.idle_time = state["idle_time"]
        self.bytes_sent = state["bytes_sent"]
        self.server_step = state["server_step"]
        self.dispatches = state["dispatches"]
        self.history = [RoundMetrics(**m) for m in state["history"]]

    def client_pass_rates(self) -> np.ndarray:
        """(num_clients,) θ pass-rate EMAs the server has learned — the
        device ControlState on the scanned path, the host selector
        records otherwise. Diagnostics surface (the differential
        harness's byzantine-rejection assert reads it through
        ``ExperimentSession.client_pass_rates``)."""
        if self._scan_ctl is not None:
            return np.asarray(self._scan_ctl.pass_rate)
        return np.array([self.selector.records[c].pass_rate
                         for c in range(self.num_clients)])

    def run(self, num_rounds: int,
            eval_final: bool = True) -> List[RoundMetrics]:
        if self.rounds_per_dispatch:
            return self._run_scanned(num_rounds, eval_final=eval_final)
        first = self.round_idx          # absolute: resumes keep numbering
        for r in range(first, first + num_rounds):
            # eval_every > 1 skips the eval dispatch on off-rounds (the
            # previous accuracy is carried forward); the final round is
            # evaluated too (unless eval_final=False — session streaming
            # chunks) so ``result.final`` stays meaningful
            evaluate = ((r % self.eval_every == 0)
                        or (eval_final and r == first + num_rounds - 1))
            self.run_round(r, evaluate=evaluate)
        return self.history


# ---------------------------------------------------------------------------
# profile factories
# ---------------------------------------------------------------------------

def heterogeneous_profile_arrays(n: int, seed: int = 0,
                                 dropout_p: float = 0.0,
                                 speed_sigma: float = 0.6) -> dict:
    """Array-backed profile fields (the million-client spelling): the
    SAME Generator draws, in the same order, as the historical
    ``heterogeneous_profiles`` list — one dict of four (n,) arrays
    instead of n dataclass instances."""
    rng = np.random.default_rng(seed)
    speeds = rng.lognormal(0.0, speed_sigma, size=n)
    lats = rng.uniform(0.01, 0.2, size=n)
    mems = rng.uniform(0.4, 1.0, size=n)
    return {"speed": speeds, "net_latency": lats,
            "dropout_p": np.full(n, float(dropout_p)), "memory": mems}


def uniform_profile_arrays(n: int, dropout_p: float = 0.0) -> dict:
    return {"speed": np.ones(n), "net_latency": np.zeros(n),
            "dropout_p": np.full(n, float(dropout_p)),
            "memory": np.ones(n)}


class ProfileView:
    """Sequence[ClientProfile] over per-field arrays.

    ``view[cid]`` builds one dataclass per ACCESS instead of holding one
    per client — at 1M clients the list is hundreds of MB of Python
    objects, the four float arrays ~32 MB. Duck-types the profile lists
    everywhere the engine indexes or iterates them."""

    def __init__(self, arrays: dict):
        self._a = arrays

    def __len__(self) -> int:
        return len(self._a["speed"])

    def field(self, name: str) -> np.ndarray:
        return self._a[name]

    def __getitem__(self, cid):
        if isinstance(cid, slice):
            return [self[i] for i in range(*cid.indices(len(self)))]
        a = self._a
        return ClientProfile(speed=float(a["speed"][cid]),
                             net_latency=float(a["net_latency"][cid]),
                             dropout_p=float(a["dropout_p"][cid]),
                             memory=float(a["memory"][cid]))


def heterogeneous_profiles(n: int, seed: int = 0, dropout_p: float = 0.0,
                           speed_sigma: float = 0.6) -> List[ClientProfile]:
    """Lognormal speeds (stragglers!), uniform latencies."""
    a = heterogeneous_profile_arrays(n, seed=seed, dropout_p=dropout_p,
                                     speed_sigma=speed_sigma)
    return [ClientProfile(speed=float(s), net_latency=float(l),
                          dropout_p=dropout_p, memory=float(m))
            for s, l, m in zip(a["speed"], a["net_latency"], a["memory"])]


def uniform_profiles(n: int, dropout_p: float = 0.0) -> List[ClientProfile]:
    return [ClientProfile(speed=1.0, net_latency=0.0, dropout_p=dropout_p,
                          memory=1.0) for _ in range(n)]
