"""Event-driven federated simulation engine (paper §IV-B, §V).

Reproduces the paper's experimental apparatus on CPU: N clients with
heterogeneous speed / network / dropout profiles train REAL models (jitted
JAX local steps on their non-IID shard); the server runs either

  sync  — barrier aggregation: the round completes when the SLOWEST
          selected client's update arrives (straggler effect, Fig. 2
          left); barrier idle time is tracked explicitly;
  async — continuous aggregation: updates are applied in completion-time
          order with staleness weighting α(τ)=α₀(1+τ)^-0.5; the round
          clock advances at a QUORUM of arrivals (default 50%), so fast
          clients never wait for stragglers (Fig. 2 right). Straggler
          updates are still applied, discounted by their staleness.

Composable strategy flags mirror the paper's ablations (Table III):
  theta            — gradient-sign-alignment client-side filter (§IV-C);
                     the reference direction is the sign of the LAST
                     GLOBAL UPDATE (w_g^t − w_g^{t−1}), per Algorithm 1
  selection        — adaptive top-k client selection from reliability EMAs
  dynamic_batch    — capacity-proportional batch assignment (§IV-A)
  checkpointing    — Weibull-interval checkpoint/restore on dropout (§IV-C)

Simulated time model (recorded separately from real wall time):
  train_time  = steps · batch · t_sample / speed
  comm_time   = latency + bytes/bandwidth   (only if the update is SENT —
                filtered clients transmit a 1-bit "skip" beacon)
All stochastic choices draw from a seeded Generator → runs are exactly
reproducible; with equal speeds, zero latency, no dropout, full quorum and
theta=None, the async trajectory coincides with sync FedAvg (tested).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, alignment
from repro.core.batchsize import BatchSizeController, ClientMetrics
from repro.core.checkpoint_policy import fit_weibull, optimal_interval
from repro.core.selection import AdaptiveClientSelector
from repro.data.loader import ArrayLoader
from repro.models import api
from repro.optim import adamw as optim_mod


@dataclasses.dataclass
class CommModel:
    bandwidth: float = 1e9        # bytes/s client->server
    latency: float = 0.05         # s per message
    t_sample: float = 2e-6        # s of compute per training sample (ref speed)
    t_launch: float = 0.0         # fixed per-step dispatch overhead — the
                                  # paper's kernel-launch/memcpy cost that
                                  # large batches amortize (Tables V-VI)
    beacon_bytes: float = 0.125   # 1-bit "skip" beacon a θ-filtered client
                                  # still transmits (§IV-C); charged to both
                                  # bytes_sent and transfer time so the sim
                                  # and SPMD engines account identically


@dataclasses.dataclass
class ClientProfile:
    speed: float = 1.0            # relative compute throughput
    net_latency: float = 0.05
    dropout_p: float = 0.0
    memory: float = 1.0


@dataclasses.dataclass
class StrategyConfig:
    mode: str = "async"                   # async | sync
    theta: Optional[float] = 0.65         # None -> no filtering
    selection: bool = True
    select_fraction: float = 1.0          # top-k fraction when selecting
    dynamic_batch: bool = False
    checkpointing: bool = True
    local_epochs: int = 1
    batch_size: int = 64
    lr: float = 5e-3
    alpha0: float = 1.0                   # fresh-update weight in buffered
                                          # async aggregation: α(τ)=α₀(1+τ)^-½
                                          # discounts stale arrivals; τ=0 ->
                                          # exactly FedAvg over the senders.
                                          # (Sequential convex mixing with
                                          # α₀>0.2 chased the last arrival
                                          # and collapsed the θ-filter —
                                          # kept in EXPERIMENTS §Sim.)
    quorum: float = 0.5                   # async round advances at this frac
    per_client_lr: bool = False           # FedL2P-style personalization
    grad_norm_selection: bool = False     # ACFL-style critical-period proxy
    quantize_updates: bool = False        # beyond-paper §VI hybrid: int8 +
                                          # error feedback on the wire (4x
                                          # fewer bytes, multiplies with θ)
    max_samples_per_round: int = 4096     # per-round sample cap (NOT a step
                                          # cap: batch sizes then see equal
                                          # data, isolating the launch-
                                          # overhead effect the paper measures)


def local_step_count(n: int, batch_size: int, st: StrategyConfig) -> int:
    """Per-round local step count, quantized UP to powers of two.

    Heterogeneous client datasets otherwise produce a distinct
    (steps, batch) shape per client, and every distinct shape re-traces
    the jitted local scan — the dominant CPU cost at 100 clients.
    Power-of-two quantization caps the trace count at ~7 per batch size.
    Shared with the spmd runner (repro.api) so both engines consume and
    account the same per-round sample volume.
    """
    cap = max(1, st.max_samples_per_round // batch_size)
    steps = max(1, math.ceil(st.local_epochs * n / batch_size))
    steps = min(steps, cap)
    steps = 1 << (steps - 1).bit_length()          # next power of two
    return min(steps, cap)


@dataclasses.dataclass
class RoundMetrics:
    round: int
    sim_time: float          # simulated end-to-end wall clock so far
    comm_time: float         # cumulative transfer seconds
    idle_time: float         # cumulative barrier-idle seconds (sync only)
    bytes_sent: float
    updates_applied: int
    accept_rate: float
    accuracy: float
    loss: float


class FederatedSimulation:
    def __init__(self, cfg, client_arrays: List[dict], eval_arrays: dict,
                 strategy: StrategyConfig, profiles: List[ClientProfile],
                 comm: CommModel = None, seed: int = 0,
                 eval_fn: Callable = None):
        self.cfg = cfg
        self.strategy = strategy
        self.comm = comm or CommModel()
        self.profiles = profiles
        self.rng = np.random.default_rng(seed)
        self.num_clients = len(client_arrays)
        self.eval_arrays = eval_arrays

        # --- model/optim setup ------------------------------------------
        self.params = api.init_params(jax.random.PRNGKey(seed), cfg)
        self.param_bytes = sum(x.size * x.dtype.itemsize
                               for x in jax.tree.leaves(self.params))
        self.opt = optim_mod.sgd(lr=strategy.lr)
        self.ref_sign = None          # sign(w_g^t − w_g^{t−1}); None round 0
        self._local_run = self._build_local_run()
        self._eval = eval_fn or self._build_eval()

        # --- per-client state --------------------------------------------
        self.batch_ctrl = BatchSizeController()
        self.loaders = []
        for cid, arrays in enumerate(client_arrays):
            bs = strategy.batch_size
            if strategy.dynamic_batch:
                p = profiles[cid]
                bs = self.batch_ctrl.initial(cid, ClientMetrics(
                    compute=p.speed, memory=p.memory, latency=p.net_latency))
            self.loaders.append(ArrayLoader(arrays, bs, seed=seed + cid))
        self.selector = AdaptiveClientSelector(self.num_clients, seed=seed)
        self.client_lr_scale = np.ones(self.num_clients)
        self.grad_norms = np.ones(self.num_clients)

        # --- fault tolerance ----------------------------------------------
        self.failure_log: List[float] = []
        self.checkpoints: Dict[int, bool] = {}
        self.ckpt_interval = 10.0
        self.recovery_time = 0.2      # restore from checkpoint
        self.restart_time = 1.0      # cold restart without one

        # --- compression (beyond-paper) -----------------------------------
        self._ef_state = {}
        self._wire_bytes = None

        # --- accounting -----------------------------------------------------
        self.sim_time = 0.0
        self.comm_time = 0.0
        self.idle_time = 0.0
        self.bytes_sent = 0.0
        self.server_step = 0
        self.history: List[RoundMetrics] = []

    # ------------------------------------------------------------------
    # jitted pieces
    # ------------------------------------------------------------------
    def _build_local_run(self):
        cfg, opt = self.cfg, self.opt

        @jax.jit
        def run(params, batches, lr_scale):
            opt_state = opt.init(params)

            def step(carry, batch):
                p, s = carry
                loss, grads = jax.value_and_grad(
                    lambda q: api.loss_fn(q, batch, cfg))(p)
                grads = jax.tree.map(lambda g: g * lr_scale, grads)
                p, s = opt.update(grads, s, p)
                return (p, s), loss

            (params, _), losses = jax.lax.scan(step, (params, opt_state), batches)
            return params, losses.mean()

        return run

    def _build_eval(self):
        return api.build_default_eval(self.cfg)

    # ------------------------------------------------------------------
    # client-local training (simulated timing + real gradients)
    # ------------------------------------------------------------------
    def _client_batches(self, cid: int):
        """Fixed-step resampled batches -> stable jit shapes (step count
        from ``local_step_count``)."""
        loader = self.loaders[cid]
        bs = loader.batch_size
        steps = local_step_count(loader.n, bs, self.strategy)
        batches = [loader.sample() for _ in range(steps)]
        stacked = {k: np.stack([b[k] for b in batches]) for k in batches[0]}
        return stacked, steps, steps * bs

    def _train_client(self, cid: int):
        batches, steps, n_samples = self._client_batches(cid)
        new_params, loss = self._local_run(
            self.params, jax.tree.map(jnp.asarray, batches),
            jnp.float32(self.client_lr_scale[cid]))
        prof = self.profiles[cid]
        # per-step dispatch overhead + per-sample compute (paper §IV-A:
        # larger batches -> fewer steps -> amortized launch cost)
        train_time = (steps * self.comm.t_launch
                      + n_samples * self.comm.t_sample) / max(prof.speed, 1e-3)
        delta = jax.tree.map(lambda n, o: (n - o).astype(jnp.float32),
                             new_params, self.params)
        if self.strategy.quantize_updates:
            # int8 + error feedback on the wire; server dequantizes
            from repro.core import compression
            err = self._ef_state.setdefault(
                cid, compression.init_error_state(delta))
            q, s, _n, self._ef_state[cid] = compression.compress_update(
                delta, err)
            delta = compression.decompress_update(q, s, delta)
            new_params = jax.tree.map(
                lambda o, d: (o.astype(jnp.float32) + d).astype(o.dtype),
                self.params, delta)
            self._wire_bytes = compression.transport_bytes(q, s)
        return new_params, delta, float(loss), train_time

    def _filter_update(self, delta) -> tuple:
        """Client-side sign-alignment filter (Algorithm 1 lines 27-32)."""
        if self.strategy.theta is None or self.ref_sign is None:
            return True, 1.0
        ratio = float(alignment.alignment_ratio(delta, self.ref_sign))
        return ratio >= self.strategy.theta, ratio

    def _payload_bytes(self) -> float:
        if self.strategy.quantize_updates and self._wire_bytes:
            return float(self._wire_bytes)
        return float(self.param_bytes)

    def _transfer_time(self, sent: bool, prof: ClientProfile) -> float:
        if sent:
            return prof.net_latency + self._payload_bytes() / self.comm.bandwidth
        # 1-bit skip beacon: still a message, still on the wire
        return prof.net_latency + self.comm.beacon_bytes / self.comm.bandwidth

    # ------------------------------------------------------------------
    # rounds
    # ------------------------------------------------------------------
    def _select_clients(self) -> List[int]:
        st = self.strategy
        k = max(1, int(st.select_fraction * self.num_clients))
        if st.grad_norm_selection:
            return list(np.argsort(-self.grad_norms)[:k])
        if st.selection and st.select_fraction < 1.0:
            return self.selector.select(k)
        return list(range(self.num_clients))

    def run_round(self, rnd: int) -> RoundMetrics:
        st = self.strategy
        selected = self._select_clients()
        round_start = self.sim_time
        prev_params = self.params
        arrivals = []   # (arrive, cid, new_params, sent, transfer)
        round_times: Dict[int, float] = {}
        losses = []
        n_sent = 0

        for cid in selected:
            prof = self.profiles[cid]
            delay = 0.0
            if self.rng.random() < prof.dropout_p:
                self.failure_log.append(round_start)
                self.selector.observe(cid, delivered=False)
                if not st.checkpointing:
                    continue                      # client lost this round
                delay = (self.recovery_time if self.checkpoints.get(cid)
                         else self.restart_time)
            new_params, delta, loss, t_train = self._train_client(cid)
            losses.append(loss)
            sent, ratio = self._filter_update(delta)
            transfer = self._transfer_time(sent, prof)
            arrive = round_start + delay + t_train + transfer
            arrivals.append((arrive, cid, new_params, sent, transfer))
            round_times[cid] = arrive - round_start
            self.selector.observe(cid, delivered=True, passed=sent,
                                  round_time=arrive - round_start)
            gn = float(np.sqrt(sum(float(jnp.vdot(g, g))
                                   for g in jax.tree.leaves(delta))))
            self.grad_norms[cid] = 0.5 * self.grad_norms[cid] + 0.5 * gn
            if st.per_client_lr:
                self.client_lr_scale[cid] = float(np.clip(
                    self.client_lr_scale[cid] * (1.05 if gn < 1.0 else 0.9),
                    0.25, 2.0))
            if sent:
                n_sent += 1
                self.bytes_sent += self._payload_bytes()
            else:
                self.bytes_sent += self.comm.beacon_bytes
            self.comm_time += transfer
            if st.checkpointing:
                self.checkpoints[cid] = True   # periodic local state save

        arrivals.sort(key=lambda a: a[0])
        updates_applied = 0

        if st.mode == "sync":
            sent_params = [p for (_, _, p, sent, _) in arrivals if sent]
            if sent_params:
                stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *sent_params)
                self.params = aggregation.fedavg(stacked)
                self.server_step += 1
                updates_applied = 1
            if arrivals:
                barrier = arrivals[-1][0]
                self.idle_time += sum(barrier - a for (a, *_r) in arrivals)
                self.sim_time = barrier
        else:
            # async: clock advances at the quorum arrival; later updates are
            # stale (they overlap the next round) and are discounted.
            # Aggregation is FedBuff-style BUFFERED (mean of staleness-
            # discounted deltas): sequential convex mixing over-weights the
            # last arrival and destabilizes the θ-filter (EXPERIMENTS §Sim).
            if arrivals:
                q_idx = max(0, math.ceil(st.quorum * len(arrivals)) - 1)
                self.sim_time = arrivals[q_idx][0]
                buf = []
                for i, (arrive, cid, new_params, sent, _t) in enumerate(arrivals):
                    if not sent:
                        continue
                    tau = max(0, i - q_idx)
                    alpha = float(aggregation.staleness_weight(tau, st.alpha0))
                    buf.append((alpha, new_params))
                    self.server_step += 1
                    updates_applied += 1
                self.params = aggregation.buffered_async_update(
                    self.params, buf)

        if st.checkpointing and len(self.failure_log) >= 2:
            lam, k = fit_weibull(np.diff(sorted(self.failure_log)))
            self.ckpt_interval = optimal_interval(
                max(self.sim_time, 1.0), self.recovery_time, lam, k)
        if st.dynamic_batch:
            for cid, b in self.batch_ctrl.feedback(round_times).items():
                if cid < len(self.loaders):
                    self.loaders[cid].set_batch_size(b)

        # reference direction = sign of the global movement this round
        if updates_applied and st.theta is not None:
            self.ref_sign = jax.tree.map(
                lambda n, o: jnp.sign(n.astype(jnp.float32)
                                      - o.astype(jnp.float32)).astype(jnp.int8),
                self.params, prev_params)

        acc = float(self._eval(self.params,
                               jax.tree.map(jnp.asarray, self.eval_arrays)))
        m = RoundMetrics(
            round=rnd, sim_time=self.sim_time, comm_time=self.comm_time,
            idle_time=self.idle_time, bytes_sent=self.bytes_sent,
            updates_applied=updates_applied,
            accept_rate=n_sent / max(len(selected), 1), accuracy=acc,
            loss=float(np.mean(losses)) if losses else float("nan"))
        self.history.append(m)
        return m

    def run(self, num_rounds: int) -> List[RoundMetrics]:
        for r in range(num_rounds):
            self.run_round(r)
        return self.history


# ---------------------------------------------------------------------------
# profile factories
# ---------------------------------------------------------------------------

def heterogeneous_profiles(n: int, seed: int = 0, dropout_p: float = 0.0,
                           speed_sigma: float = 0.6) -> List[ClientProfile]:
    """Lognormal speeds (stragglers!), uniform latencies."""
    rng = np.random.default_rng(seed)
    speeds = rng.lognormal(0.0, speed_sigma, size=n)
    lats = rng.uniform(0.01, 0.2, size=n)
    mems = rng.uniform(0.4, 1.0, size=n)
    return [ClientProfile(speed=float(s), net_latency=float(l),
                          dropout_p=dropout_p, memory=float(m))
            for s, l, m in zip(speeds, lats, mems)]


def uniform_profiles(n: int, dropout_p: float = 0.0) -> List[ClientProfile]:
    return [ClientProfile(speed=1.0, net_latency=0.0, dropout_p=dropout_p,
                          memory=1.0) for _ in range(n)]
