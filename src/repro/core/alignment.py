"""Gradient sign-alignment relevance scoring (paper §IV-C, Algorithm 1
lines 3–12).

``relevance = (# params whose local-update sign matches the reference
global-update sign) / (# params)``. Clients with relevance ≥ θ (0.65)
transmit; others are filtered at the source.

Implementation notes:
  * operates on flat pytrees; zero entries in the reference count as
    "matching" only if the local entry is also zero (sign(0)==sign(0)),
    mirroring the paper's ``sign(W)`` comparison.
  * ``per_client_alignment`` vectorizes over a leading client axis
    (pytree space — the small-scale oracle).
  * ``cohort_alignment`` is the production path used by ``fl_step`` and
    the simulator megastep: it consumes the flat (C, rows, LANE) arena
    layout (repro.kernels.arena) so all C clients are scored in one
    kernel sweep — Pallas on TPU, jnp oracle on CPU, no per-tensor
    launches (DESIGN.md §7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import arena as arena_ops


def tree_sign(tree):
    """int8 sign pytree (the ``ref_sign`` carried in FL state)."""
    return jax.tree.map(lambda x: jnp.sign(x).astype(jnp.int8), tree)


def _leaf_counts(local, ref_sign):
    a = jnp.sign(local.astype(jnp.float32)).astype(jnp.int8)
    aligned = jnp.sum((a == ref_sign).astype(jnp.float32))
    return aligned, jnp.float32(local.size)


def alignment_ratio(local_tree, ref_sign_tree) -> jnp.ndarray:
    """Scalar relevance of ONE client's update against the reference sign."""
    aligned = jnp.float32(0.0)
    total = jnp.float32(0.0)
    for loc, ref in zip(jax.tree.leaves(local_tree),
                        jax.tree.leaves(ref_sign_tree)):
        a, t = _leaf_counts(loc, ref)
        aligned += a
        total += t
    return aligned / jnp.maximum(total, 1.0)


def per_client_alignment(client_trees, ref_sign_tree) -> jnp.ndarray:
    """client_trees: pytree with leading client dim C. Returns (C,) ratios."""
    leaves = jax.tree.leaves(client_trees)
    C = leaves[0].shape[0]
    aligned = jnp.zeros((C,), jnp.float32)
    total = jnp.float32(0.0)
    for loc, ref in zip(leaves, jax.tree.leaves(ref_sign_tree)):
        a = jnp.sign(loc.astype(jnp.float32)).astype(jnp.int8)
        eq = (a == ref[None]).astype(jnp.float32)
        aligned += eq.reshape(C, -1).sum(axis=1)
        total += jnp.float32(ref.size)
    return aligned / jnp.maximum(total, 1.0)


def cohort_alignment(u_mat, ref_mat, n: int) -> jnp.ndarray:
    """(C,) relevance ratios from arena-layout updates.

    u_mat: (C, rows, LANE) f32 packed updates; ref_mat: (rows, LANE) int8
    reference signs with -2 padding sentinel; n: true element count.
    """
    counts = arena_ops.cohort_sign_align(u_mat, ref_mat)
    return counts / jnp.maximum(jnp.float32(n), 1.0)


def selection_mask(ratios: jnp.ndarray, theta: float) -> jnp.ndarray:
    """(C,) float mask; paper's acceptance rule relevance ≥ θ."""
    return (ratios >= theta).astype(jnp.float32)
