"""Sharded population plane — the million-client control state.

The device control plane (core/control.py) keeps every per-client
statistic as a ``(num_clients,)`` array and every transition as a
gather → EMA → scatter over a (K,)-cohort. At 1M clients those arrays
must live sharded over the mesh "data" axis, and a transition must touch
only the shard-local rows: each shard gathers with OWNED indices (ids
that fall inside its slice), applies the identical arithmetic
(``control.observe_ema`` / ``control.batch_rule`` are shared, so the
float ops are bitwise the same) and scatters through a dummy-row trick —
non-owned cohort slots are redirected to an appended scratch row that is
sliced off, so the scatter is deterministic (owned indices are unique;
only the discarded dummy row ever sees colliding writes).

Two drivers run the same kernel:

  ``round_update_logical``  — single-device: the (N,) arrays are viewed
                              as (shards, N/shards) and the kernel is
                              vmapped with per-shard offsets. This is
                              how tests pin shard-local == global
                              bit-identity without a multi-device host,
                              and how the scaling benchmark isolates the
                              sharded arithmetic from device count.
  ``round_update_sharded``  — the real ``shard_map`` over mesh "data"
                              (cohort observations replicated, state
                              sharded); exercised by the CI scale-smoke
                              under ``--xla_force_host_platform_device_
                              count=8`` and by the dry-run launcher.

Selection stage 1 lives here too: ``sharded_candidates`` ranks only the
local rows per shard (partial top-k, ``selection.candidate_quota``) and
emits a small replicated candidate union; ``topk_from_candidates``
recovers the EXACT global top-k from the union via a (score desc, id
asc) lexsort — the same order as the single-stage stable argsort, so the
two-stage result is bit-identical whenever quota >= k (always at
``candidate_frac=1.0``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import control, selection

try:                                    # jax <= 0.5
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:                     # jax >= 0.6 top-level export
    from jax import shard_map as _shard_map

# the (num_clients,)-shaped ControlState fields the kernels shard; the
# error-feedback arena ``ef`` is cohort-indexed (N+1 dummy-row layout of
# its own) and stays outside the population kernels.
_FIELDS = ("avail", "pass_rate", "round_time", "batch", "lr_scale",
           "grad_norm", "staleness", "has_ckpt")


# ---------------------------------------------------------------------------
# single-device reference: the full per-round control update
# ---------------------------------------------------------------------------

def round_update(state, cohort, *, failed, active, passed, round_time,
                 sent, norms, ema: float = 0.8):
    """The canonical per-round control-plane composition the sharded
    kernels are pinned against: two-phase observation (dropouts first,
    then participants — core/megastep.py's order), batch feedback, norm
    EMAs, LR meta-rule, staleness counters, checkpoint bits."""
    state = control.observe_round(state, cohort, failed, active, passed,
                                  round_time, ema)
    state = control.batch_feedback(state, cohort, round_time, active)
    state = control.grad_norm_update(state, cohort, norms, active)
    state = control.lr_scale_update(state, cohort, norms, active)
    state = control.staleness_update(state, cohort, sent)
    state = control.checkpoint_update(state, cohort, active)
    return state


# ---------------------------------------------------------------------------
# the shard-local kernel
# ---------------------------------------------------------------------------

def _round_kernel(leaves, cohort, failed, active, passed, round_time,
                  sent, norms, offset, ema):
    """One shard's slice of ``round_update``.

    ``leaves``: the 8 per-client arrays (local slices, length per);
    observations are the full replicated (K,) cohort stream; ``offset``
    is the shard's first global client id. Gathers clip non-owned ids to
    a safe local index (their values are garbage but masked out of the
    scatter); scatters append one dummy row, write non-owned slots
    there, and slice it off."""
    avail, pass_rate, rtime, batch, lr_scale, grad_norm, \
        staleness, has_ckpt = leaves
    local_n = avail.shape[0]
    rel = cohort - offset
    owned = (rel >= 0) & (rel < local_n)
    safe = jnp.clip(rel, 0, local_n - 1)
    idx = jnp.where(owned, safe, local_n)

    def scat(arr, vals):
        ext = jnp.concatenate([arr, jnp.zeros((1,), arr.dtype)])
        return ext.at[idx].set(vals.astype(arr.dtype))[:local_n]

    # observe_round, phase 1: every dropout observed delivered=False
    false = jnp.zeros_like(failed)
    a1, p1, t1 = control.observe_ema(
        avail[safe], pass_rate[safe], rtime[safe],
        failed, false, false, round_time, ema)
    avail, pass_rate, rtime = scat(avail, a1), scat(pass_rate, p1), \
        scat(rtime, t1)
    # phase 2: every participant observed delivered=True (gathers read
    # the POST-phase-1 values, exactly like the chained global observes)
    a2, p2, t2 = control.observe_ema(
        avail[safe], pass_rate[safe], rtime[safe],
        active, active, passed, round_time, ema)
    avail, pass_rate, rtime = scat(avail, a2), scat(pass_rate, p2), \
        scat(rtime, t2)
    # batch feedback — the median comes from the replicated cohort
    # observations, so every shard computes the identical threshold
    batch = scat(batch, control.batch_rule(batch[safe], round_time,
                                           active))
    g = grad_norm[safe]
    grad_norm = scat(grad_norm, jnp.where(active, 0.5 * g + 0.5 * norms,
                                          g))
    s = lr_scale[safe]
    lr_scale = scat(lr_scale, jnp.where(
        active, jnp.clip(s * jnp.where(norms < 1.0, 1.05, 0.9),
                         0.25, 2.0), s))
    stale = staleness + 1
    staleness = scat(stale, jnp.where(sent, 0, stale[safe]))
    has_ckpt = scat(has_ckpt, has_ckpt[safe] | active)
    return (avail, pass_rate, rtime, batch, lr_scale, grad_norm,
            staleness, has_ckpt)


def _pad_leaf(arr, padded: int):
    """Zero-extend a (n,) population leaf to ``padded`` rows. Pad rows
    are inert in ``_round_kernel``: cohort ids are < n so no gather or
    scatter ever selects them (every row transition is row-local — the
    only cross-row statistic, the batch-rule median, comes from the
    replicated cohort observations), and they are sliced off after."""
    n = arr.shape[0]
    if padded == n:
        return arr
    return jnp.concatenate([arr, jnp.zeros((padded - n,), arr.dtype)])


def _split_state(state, shards: int):
    """(leaves viewed as (shards, per), per) — ragged populations are
    zero-padded up to the next multiple of ``shards``."""
    n = state.avail.shape[0]
    per = -(-n // shards)           # ceil: pad instead of raising
    padded = per * shards
    return tuple(_pad_leaf(getattr(state, f), padded).reshape(shards, per)
                 for f in _FIELDS), per


def round_update_logical(state, cohort, *, shards: int, failed, active,
                         passed, round_time, sent, norms,
                         ema: float = 0.8):
    """Single-device logical-shard driver: vmap ``_round_kernel`` over
    ``shards`` contiguous slices. Bit-identical to ``round_update`` —
    the parity suite (tests/test_population.py) pins exactly this.
    Populations that don't divide ``shards`` are zero-padded to the
    next multiple (masked dummy rows, sliced off) — same bits as the
    unsharded update either way."""
    leaves, per = _split_state(state, int(shards))
    offsets = (jnp.arange(int(shards)) * per).astype(cohort.dtype)
    out = jax.vmap(
        lambda lv, off: _round_kernel(lv, cohort, failed, active, passed,
                                      round_time, sent, norms, off, ema),
        in_axes=(0, 0))(leaves, offsets)
    n = state.avail.shape[0]
    return state._replace(**{f: o.reshape((-1,))[:n]
                             for f, o in zip(_FIELDS, out)})


def round_update_sharded(state, cohort, *, mesh, failed, active, passed,
                         round_time, sent, norms, ema: float = 0.8):
    """The real thing: state sharded over mesh "data" via ``shard_map``,
    cohort observations replicated. Same kernel, same bits. Ragged
    populations (n % devices != 0) are zero-padded to the next multiple
    of the "data" axis with inert dummy rows and sliced back — bitwise
    parity with ``round_update`` holds either way."""
    nshards = mesh.shape["data"]
    n = state.avail.shape[0]
    per = -(-n // nshards)
    padded = per * nshards
    leaves = tuple(_pad_leaf(getattr(state, f), padded) for f in _FIELDS)
    rep = P()

    def body(lv, cohort, failed, active, passed, round_time, sent, norms):
        off = (jax.lax.axis_index("data") * per).astype(cohort.dtype)
        return _round_kernel(lv, cohort, failed, active, passed,
                             round_time, sent, norms, off, ema)

    out = _shard_map(
        body, mesh=mesh,
        in_specs=((P("data"),) * len(_FIELDS),
                  rep, rep, rep, rep, rep, rep, rep),
        out_specs=(P("data"),) * len(_FIELDS),
        check_rep=False)(leaves, cohort, failed, active, passed,
                         round_time, sent, norms)
    return state._replace(**{f: o[:n] for f, o in zip(_FIELDS, out)})


# ---------------------------------------------------------------------------
# two-stage selection over the sharded population
# ---------------------------------------------------------------------------

def sharded_candidates(scores: jnp.ndarray, k: int, frac: float, *,
                       mesh):
    """Stage 1 under ``shard_map``: each "data" shard ranks ONLY its own
    rows (``lax.top_k``, quota per ``selection.candidate_quota``) and
    emits (quota,) winners as (score, global id). Returns the
    (shards·quota,) concatenated union — tiny next to N, and the only
    cross-shard traffic selection needs."""
    n = scores.shape[0]
    nshards = mesh.shape["data"]
    per = -(-n // nshards)
    quota = selection.candidate_quota(n, k, frac, nshards)
    pad = per * nshards - n
    if pad:
        # ragged population: -inf pad rows lose every ranking, and the
        # quota already budgets for quota-displacing padding positions
        # (selection.candidate_quota), so the union still holds >= k
        # real clients
        scores = jnp.concatenate(
            [scores, jnp.full((pad,), -jnp.inf, scores.dtype)])

    def local(s):
        v, i = jax.lax.top_k(s, quota)
        gid = i.astype(jnp.int32) + jax.lax.axis_index("data") * per
        return v, gid

    return _shard_map(local, mesh=mesh, in_specs=P("data"),
                      out_specs=(P("data"), P("data")),
                      check_rep=False)(scores)


def logical_candidates(scores: jnp.ndarray, k: int, frac: float,
                       shards: int):
    """Single-device twin of ``sharded_candidates`` (same union, same
    order) — lets the scaling benchmark time the two-stage arithmetic
    independently of host device count."""
    n = scores.shape[0]
    shards = int(shards)
    per = -(-n // shards)
    quota = selection.candidate_quota(n, k, frac, shards)
    pad = per * shards - n
    if pad:
        scores = jnp.concatenate(
            [scores, jnp.full((pad,), -jnp.inf, scores.dtype)])
    v, i = jax.lax.top_k(scores.reshape(shards, per), quota)
    gid = i.astype(jnp.int32) + (jnp.arange(shards, dtype=jnp.int32)
                                 * per)[:, None]
    return v.reshape(-1), gid.reshape(-1)


def topk_from_candidates(cand_scores: jnp.ndarray,
                         cand_idx: jnp.ndarray, k: int) -> jnp.ndarray:
    """Stage 2: exact top-k over the union, ordered (score desc, global
    id asc). ``jnp.lexsort`` sorts by its LAST key first, so ties break
    toward the lower global id — the same order as the single-stage
    stable descending argsort, hence bit-identical selections whenever
    every global top-k member is in the union (quota >= k)."""
    order = jnp.lexsort((cand_idx, -cand_scores))
    return cand_idx[order[:int(k)]]


# ---------------------------------------------------------------------------
# population-only round (the scaling benchmark's unit of work)
# ---------------------------------------------------------------------------

def build_population_round(num_clients: int, select_k: int, *,
                           candidate_frac: Optional[float] = None,
                           candidate_shards: int = 8,
                           mesh=None, ema: float = 0.8, seed: int = 0):
    """Score → (two-stage) selection → synthetic cohort observations →
    full control round update; training deliberately absent. This
    isolates the selection+control cost per round — the quantity
    ``BENCH_scale.json`` tracks from 1k to 1M clients. Observations are
    folded from the ABSOLUTE round index, so the stream is independent
    of how rounds are grouped into dispatches.

    With ``mesh`` the state transitions run under ``shard_map`` and
    stage 1 ranks per-device rows; without, logical shards on one
    device. Returns ``round_fn(state, round_idx) -> (state, cohort)``
    (scan-compatible)."""
    n, k = int(num_clients), int(select_k)
    base = jax.random.PRNGKey(seed)

    def round_fn(state, r):
        scores = control.score(state)
        if candidate_frac is not None:
            if mesh is not None:
                v, i = sharded_candidates(scores, k, candidate_frac,
                                          mesh=mesh)
            else:
                v, i = logical_candidates(scores, k, candidate_frac,
                                          candidate_shards)
            cohort = topk_from_candidates(v, i, k)
        else:
            cohort = control.select_topk_epsilon(scores, k)
        key = jax.random.fold_in(base, r)
        kf, kp, kt, kn = jax.random.split(key, 4)
        failed = jax.random.bernoulli(kf, 0.05, (k,))
        active = ~failed
        passed = jax.random.bernoulli(kp, 0.9, (k,)) & active
        rt = jax.random.uniform(kt, (k,), jnp.float32, 0.5, 1.5)
        norms = jax.random.uniform(kn, (k,), jnp.float32, 0.1, 2.0)
        kwargs = dict(failed=failed, active=active, passed=passed,
                      round_time=rt, sent=active, norms=norms, ema=ema)
        if mesh is not None:
            state = round_update_sharded(state, cohort, mesh=mesh,
                                         **kwargs)
        else:
            state = round_update(state, cohort, **kwargs)
        return state, cohort

    return round_fn
