"""Dynamic-world scenario engine: the non-stationarity the control plane
was built for (paper §V "varying client conditions"; companion works
arXiv:2501.15038 / arXiv:2502.00036 motivate selection by churn and
shifting client quality).

Every world the simulators ran before this module was frozen at round 0:
profiles, partitions and link quality never changed, so adaptive
selection (§V-C), dynamic batch feedback (§IV-A) and staleness-aware
aggregation (§IV-C) were never exercised against the conditions they
exist to absorb. A :class:`ScenarioSpec` composes per-round world
transitions:

  drift      — label-conditional feature shift: x ← x + amp(t)·dir[y]
               with a fixed per-class direction matrix, amplitude on a
               linear or sinusoidal schedule (concept drift over the
               synthetic UNSW/ROAD surrogates in data/synthetic.py);
  churn      — join/leave masks: a rotating block of clients is offline
               each membership phase (deterministic, so every execution
               path sees the identical federation roster);
  links      — link-quality dynamics: per-client multiplicative
               lognormal walks on bandwidth and latency, re-pricing
               every CommModel byte (flaky networks, Fig. 2 regime);
  dropout    — failure-rate regime switches: a piecewise-constant
               multiplier on every profile's dropout probability;
  byzantine  — adversarial clients whose updates are scaled and/or
               sign-flipped before transmission — exactly the updates
               the θ sign-alignment filter (§IV-C) should reject.

The world lives in a :class:`WorldState` of device arrays with pure-jnp
transitions (:func:`world_step`), mirroring ``core/control.py``'s
``ControlState`` design: the SAME transition function runs eagerly in
the host loop/megastep paths, inside the ``lax.scan`` of
``core/megastep.build_scanned_rounds`` (the world joins the scan carry),
and inside the compiled spmd ``fl_step`` (the world rides in
``FLState``), so all execution paths traverse bit-identical world
trajectories. Randomized transitions (the link walks) fold a JAX key
from the absolute round index, making them independent of dispatch
grouping — ``rounds_per_dispatch=4`` replays ``=1`` exactly — and the
state serializes through ``ExperimentSession.checkpoint()/restore()``.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DRIFT_MODES = ("linear", "sine")


# ---------------------------------------------------------------------------
# component specs (all pure data, all frozen)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DriftSpec:
    """Label-conditional concept drift: x ← x + amp(round)·dir[y].

    ``dir`` is a fixed (num_classes, num_features) matrix drawn once
    from ``seed`` (unit-ish rows), so the drift moves each class's
    feature cloud along its own direction — the class-conditional shift
    that degrades a frozen detector but not an adapting one. ``linear``
    grows amp by ``rate`` per round up to ``max_amp``; ``sine`` cycles
    0 → max_amp → 0 with the given ``period``. Round 0 has amp 0, so a
    drift world is indistinguishable from a static one at round 0.
    Training batches drift; the eval split stays at the round-0
    distribution (accuracy measures the original task).
    """
    rate: float = 0.05
    max_amp: float = 1.0
    mode: str = "linear"          # linear | sine
    period: int = 16              # sine mode: rounds per full cycle
    seed: int = 0

    def issues(self, prefix="scenario.drift") -> List[Tuple[str, object, str]]:
        out = []
        if self.mode not in DRIFT_MODES:
            out.append((f"{prefix}.mode", self.mode,
                        f"expected one of {DRIFT_MODES}"))
        if self.rate < 0:
            out.append((f"{prefix}.rate", self.rate, "rate must be >= 0"))
        if self.max_amp <= 0:
            out.append((f"{prefix}.max_amp", self.max_amp,
                        "max_amp must be > 0"))
        if self.period < 1:
            out.append((f"{prefix}.period", self.period,
                        "period must be >= 1"))
        return out


@dataclasses.dataclass(frozen=True)
class ChurnSpec:
    """Join/leave membership: every ``period`` rounds the offline block
    of ``round(leave_frac·N)`` clients rotates to the next position, so
    clients keep joining and leaving but the live count stays constant
    (the mask-conservation invariant the differential harness checks).
    Deterministic by construction — no draws — so the host loop, the
    scanned control plane and the spmd path agree on the roster bit-
    for-bit. ``seed`` offsets the rotation start."""
    period: int = 4
    leave_frac: float = 0.25
    seed: int = 0

    def issues(self, prefix="scenario.churn") -> List[Tuple[str, object, str]]:
        out = []
        if self.period < 1:
            out.append((f"{prefix}.period", self.period,
                        "period must be >= 1"))
        if not (0.0 <= self.leave_frac < 1.0):
            out.append((f"{prefix}.leave_frac", self.leave_frac,
                        "leave_frac must be in [0, 1) — at least one "
                        "client must stay live"))
        return out


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Per-client link-quality walks: bandwidth and latency scales take
    multiplicative lognormal steps each round, clipped to
    [1/clip, clip]. Transfer time is re-priced every round as
    ``latency·lat_scale + bytes/(bandwidth·bw_scale)`` — the flaky-link
    regime that makes reliability-scored selection earn its keep. The
    steps draw from a key folded with the absolute round index, so the
    walk is identical on every execution path and at any
    rounds_per_dispatch grouping."""
    bw_sigma: float = 0.25
    lat_sigma: float = 0.25
    clip: float = 4.0
    seed: int = 0

    def issues(self, prefix="scenario.links") -> List[Tuple[str, object, str]]:
        out = []
        if self.bw_sigma < 0:
            out.append((f"{prefix}.bw_sigma", self.bw_sigma,
                        "bw_sigma must be >= 0"))
        if self.lat_sigma < 0:
            out.append((f"{prefix}.lat_sigma", self.lat_sigma,
                        "lat_sigma must be >= 0"))
        if self.clip <= 1.0:
            out.append((f"{prefix}.clip", self.clip, "clip must be > 1"))
        return out


@dataclasses.dataclass(frozen=True)
class DropoutSchedule:
    """Failure-rate regime switches: a piecewise-constant multiplier on
    every profile's dropout_p. ``scales[i]`` applies from round
    ``boundaries[i-1]`` (inclusive) to ``boundaries[i]`` (exclusive);
    ``scales[0]`` applies before the first boundary."""
    boundaries: Tuple[int, ...] = (8,)
    scales: Tuple[float, ...] = (1.0, 3.0)

    def issues(self, prefix="scenario.dropout") -> List[Tuple[str, object, str]]:
        out = []
        if len(self.scales) != len(self.boundaries) + 1:
            out.append((f"{prefix}.scales", self.scales,
                        f"need len(boundaries)+1 = "
                        f"{len(self.boundaries) + 1} scales"))
        if any(b2 <= b1 for b1, b2 in zip(self.boundaries,
                                          self.boundaries[1:])):
            out.append((f"{prefix}.boundaries", self.boundaries,
                        "boundaries must be strictly increasing"))
        if any(s < 0 for s in self.scales):
            out.append((f"{prefix}.scales", self.scales,
                        "scales must be >= 0"))
        return out


@dataclasses.dataclass(frozen=True)
class ByzantineSpec:
    """Adversarial clients: the FIRST ``n_byz`` client ids transmit
    updates multiplied by ``-scale`` (sign_flip) or ``+scale``. A
    sign-flipped update's alignment ratio against the reference
    direction collapses, so the θ-filter (§IV-C) rejects it at the
    source — the property the differential harness asserts."""
    n_byz: int = 1
    scale: float = 2.0
    sign_flip: bool = True

    def issues(self, prefix="scenario.byzantine") -> List[Tuple[str, object, str]]:
        out = []
        if self.n_byz < 0:
            out.append((f"{prefix}.n_byz", self.n_byz,
                        "n_byz must be >= 0"))
        if self.scale <= 0:
            out.append((f"{prefix}.scale", self.scale,
                        "scale must be > 0 (sign_flip controls direction)"))
        return out


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Composition of per-round world transitions; all-None == static."""
    drift: Optional[DriftSpec] = None
    churn: Optional[ChurnSpec] = None
    links: Optional[LinkSpec] = None
    dropout: Optional[DropoutSchedule] = None
    byzantine: Optional[ByzantineSpec] = None

    def active(self) -> bool:
        return any((self.drift, self.churn, self.links, self.dropout,
                    self.byzantine))

    def issues(self) -> List[Tuple[str, object, str]]:
        out: List[Tuple[str, object, str]] = []
        for comp in (self.drift, self.churn, self.links, self.dropout,
                     self.byzantine):
            if comp is not None:
                out.extend(comp.issues())
        return out

    def validate(self) -> "ScenarioSpec":
        issues = self.issues()
        if issues:
            raise ValueError(
                "invalid ScenarioSpec: "
                + "; ".join(f"{f}={v!r}: {h}" for f, v, h in issues))
        return self


# ---------------------------------------------------------------------------
# presets (the differential-harness matrix columns)
# ---------------------------------------------------------------------------

SCENARIO_PRESETS = {
    "static": ScenarioSpec(),
    "drift": ScenarioSpec(drift=DriftSpec(rate=0.08, max_amp=1.2)),
    "churn": ScenarioSpec(churn=ChurnSpec(period=2, leave_frac=0.25)),
    "flaky-links": ScenarioSpec(
        links=LinkSpec(bw_sigma=0.35, lat_sigma=0.35),
        dropout=DropoutSchedule(boundaries=(4,), scales=(1.0, 2.5))),
    "byzantine": ScenarioSpec(
        byzantine=ByzantineSpec(n_byz=1, scale=2.0, sign_flip=True)),
    "churn+flaky-links": ScenarioSpec(
        churn=ChurnSpec(period=2, leave_frac=0.25),
        links=LinkSpec(bw_sigma=0.35, lat_sigma=0.35),
        dropout=DropoutSchedule(boundaries=(4,), scales=(1.0, 2.5))),
    "dynamic": ScenarioSpec(
        drift=DriftSpec(rate=0.05, max_amp=1.0),
        churn=ChurnSpec(period=3, leave_frac=0.25),
        links=LinkSpec(bw_sigma=0.25, lat_sigma=0.25),
        dropout=DropoutSchedule(boundaries=(8,), scales=(1.0, 2.0))),
}


def resolve_scenario(scenario) -> Optional[ScenarioSpec]:
    """None | preset name | ScenarioSpec -> validated ScenarioSpec or
    None (inactive scenarios normalize to None)."""
    if scenario is None:
        return None
    if isinstance(scenario, str):
        if scenario not in SCENARIO_PRESETS:
            raise ValueError(
                f"unknown scenario preset {scenario!r}; expected one of "
                f"{sorted(SCENARIO_PRESETS)} or a ScenarioSpec")
        scenario = SCENARIO_PRESETS[scenario]
    if not isinstance(scenario, ScenarioSpec):
        raise ValueError(f"cannot resolve scenario from {type(scenario)}; "
                         "expected None, a preset name or a ScenarioSpec")
    return scenario if scenario.active() else None


def is_active(scenario) -> bool:
    return scenario is not None and scenario.active()


# ---------------------------------------------------------------------------
# WorldState + pure-jnp transitions
# ---------------------------------------------------------------------------

class WorldState(NamedTuple):
    """Per-round world, all device-resident (the scenario twin of
    ``control.ControlState``). ``(N,)``-shaped per-client fields plus
    two scalars; an INACTIVE scenario uses the 0-width placeholder from
    :func:`empty_world` so the scan carry keeps one structure."""
    live: jnp.ndarray           # (N,) bool — churn membership
    bw_scale: jnp.ndarray       # (N,) f32 — bandwidth multiplier walk
    lat_scale: jnp.ndarray      # (N,) f32 — latency multiplier walk
    drift_amp: jnp.ndarray      # f32 scalar — current drift amplitude
    dropout_scale: jnp.ndarray  # f32 scalar — failure-regime multiplier
    byz_factor: jnp.ndarray     # (N,) f32 — update multiplier (1 honest)


def empty_world() -> WorldState:
    """Structure-compatible placeholder for static worlds (0-width)."""
    z = jnp.zeros((0,), jnp.float32)
    s = jnp.zeros((), jnp.float32)
    return WorldState(live=jnp.zeros((0,), bool), bw_scale=z, lat_scale=z,
                      drift_amp=s, dropout_scale=s, byz_factor=z)


def _byz_factor(scn: ScenarioSpec, n: int) -> jnp.ndarray:
    if scn.byzantine is None or scn.byzantine.n_byz == 0:
        return jnp.ones((n,), jnp.float32)
    b = scn.byzantine
    f = jnp.float32((-b.scale) if b.sign_flip else b.scale)
    return jnp.where(jnp.arange(n) < b.n_byz, f, jnp.float32(1.0))


def init_world(scn: Optional[ScenarioSpec], num_clients: int) -> WorldState:
    """The pre-round-0 world: everyone live, neutral scales, amp 0."""
    if not is_active(scn):
        return empty_world()
    n = int(num_clients)
    ones = jnp.ones((n,), jnp.float32)
    scale0 = (scn.dropout.scales[0] if scn.dropout is not None else 1.0)
    return WorldState(
        live=jnp.ones((n,), bool), bw_scale=ones, lat_scale=ones,
        drift_amp=jnp.float32(0.0), dropout_scale=jnp.float32(scale0),
        byz_factor=_byz_factor(scn, n))


def world_step(ws: WorldState, round_idx, scn: Optional[ScenarioSpec],
               num_clients: int) -> WorldState:
    """One round's world transition — pure jnp, safe inside jit/scan.

    ``round_idx`` is the ABSOLUTE round about to execute (traced i32 is
    fine); the returned state is the world THAT round runs under.
    Everything except the link walks is a closed-form function of
    ``round_idx``; the walks are recurrent but their steps fold a key
    from ``round_idx``, so trajectories never depend on how rounds are
    grouped into dispatches.
    """
    if not is_active(scn):
        return ws
    n = int(num_clients)
    r = jnp.asarray(round_idx, jnp.int32)

    live = ws.live
    if scn.churn is not None:
        c = scn.churn
        leave = min(int(round(c.leave_frac * n)), n - 1)
        if leave > 0:
            phase = r // jnp.int32(c.period)
            offset = (phase * jnp.int32(leave)
                      + jnp.int32(c.seed)) % jnp.int32(n)
            idx = jnp.arange(n, dtype=jnp.int32)
            live = ((idx - offset) % jnp.int32(n)) >= jnp.int32(leave)

    bw, lat = ws.bw_scale, ws.lat_scale
    if scn.links is not None:
        lk = scn.links
        key = jax.random.fold_in(jax.random.PRNGKey(lk.seed), r)
        kb, kl = jax.random.split(key)
        lo, hi = jnp.float32(1.0 / lk.clip), jnp.float32(lk.clip)
        bw = jnp.clip(bw * jnp.exp(jnp.float32(lk.bw_sigma)
                                   * jax.random.normal(kb, (n,))), lo, hi)
        lat = jnp.clip(lat * jnp.exp(jnp.float32(lk.lat_sigma)
                                     * jax.random.normal(kl, (n,))), lo, hi)

    amp = ws.drift_amp
    if scn.drift is not None:
        d = scn.drift
        if d.mode == "sine":
            amp = jnp.float32(d.max_amp) * 0.5 * (
                1.0 - jnp.cos(2.0 * jnp.pi * r.astype(jnp.float32)
                              / jnp.float32(d.period)))
        else:
            amp = jnp.minimum(jnp.float32(d.rate) * r.astype(jnp.float32),
                              jnp.float32(d.max_amp))

    scale = ws.dropout_scale
    if scn.dropout is not None and scn.dropout.boundaries:
        dp = scn.dropout
        regime = jnp.sum(
            (r >= jnp.asarray(dp.boundaries, jnp.int32)).astype(jnp.int32))
        scale = jnp.asarray(dp.scales, jnp.float32)[regime]

    return WorldState(live=live, bw_scale=bw, lat_scale=lat, drift_amp=amp,
                      dropout_scale=scale, byz_factor=ws.byz_factor)


# ---------------------------------------------------------------------------
# drift application (shared by every execution path)
# ---------------------------------------------------------------------------

def drift_directions(drift: DriftSpec, num_classes: int,
                     num_features: int) -> np.ndarray:
    """Fixed (num_classes, num_features) f32 per-class drift directions,
    unit-ish scale (||dir_c|| ≈ 1), drawn once from ``drift.seed``."""
    rng = np.random.default_rng(drift.seed)
    dirs = rng.normal(size=(num_classes, num_features))
    dirs /= np.sqrt(num_features)
    return dirs.astype(np.float32)


def apply_drift(batch: dict, amp, dirs, label_key: str = "y") -> dict:
    """x ← x + amp·dir[y], elementwise over any leading batch dims —
    bit-identical whether the batch is (B, F), (steps, B, F) or a
    stacked cohort (C, steps, B, F), so the host loop, megastep, scanned
    and spmd paths all drift the same samples the same way."""
    if "x" not in batch or label_key not in batch:
        raise ValueError("drift needs feature/label batches "
                         f"('x' + {label_key!r}); token datasets do not "
                         "support label-conditional feature drift")
    shift = jnp.asarray(amp, jnp.float32) * jnp.asarray(dirs)[batch[label_key]]
    return {**batch, "x": batch["x"] + shift}


# ---------------------------------------------------------------------------
# drift DETECTION (serving side): the same machinery, pointed the other way.
# The simulator above injects distribution shift; repro.serve's online
# monitor needs to *measure* it on live traffic. A DriftStats summary
# (per-feature mean/var + score-distribution mean/var) serves both as the
# training-time reference snapshot and as the streaming serving-time EMA
# state; drift_stats_update is pure jnp so the serving engine fuses it
# into the scoring dispatch (one jit per batch bucket, no extra dispatch).
# ---------------------------------------------------------------------------

class DriftStats(NamedTuple):
    """Distribution summary: feature moments + anomaly-score moments.

    ``count`` is the number of samples absorbed; a freshly initialized
    state (count 0) snaps to the first batch it sees, after which
    updates are exponential moving averages."""
    feat_mean: jnp.ndarray    # (F,) f32
    feat_var: jnp.ndarray     # (F,) f32
    score_mean: jnp.ndarray   # f32 scalar
    score_var: jnp.ndarray    # f32 scalar
    count: jnp.ndarray        # f32 scalar — samples absorbed


def init_drift_stats(num_features: int) -> DriftStats:
    z = jnp.zeros((num_features,), jnp.float32)
    s = jnp.zeros((), jnp.float32)
    return DriftStats(feat_mean=z, feat_var=jnp.ones_like(z),
                      score_mean=s, score_var=jnp.ones_like(s), count=s)


def reference_snapshot(x, scores) -> DriftStats:
    """Exact moments of a reference sample — the training-time snapshot
    the serving monitor compares live traffic against. ``x`` is (N, F)
    features, ``scores`` (N,) anomaly scores of the SAME samples under
    the model about to be served."""
    x = jnp.asarray(x, jnp.float32)
    s = jnp.asarray(scores, jnp.float32)
    return DriftStats(
        feat_mean=x.mean(0), feat_var=x.var(0),
        score_mean=s.mean(), score_var=s.var(),
        count=jnp.float32(x.shape[0]))


def drift_stats_update(stats: DriftStats, x, scores, mask=None,
                       decay: float = 0.98) -> DriftStats:
    """One streaming window's masked EMA update — pure jnp, safe inside
    jit (the serving engine fuses it into the scoring dispatch).

    ``mask`` flags the real rows of a padded batch bucket (None == all
    real). A batch absorbing ``m`` samples moves the EMA by
    ``1 - decay**m`` toward the batch moments, so the state trajectory
    does not depend on how a stream is chunked into buckets; an all-
    padding batch is a no-op and the FIRST real batch snaps the state."""
    x = jnp.asarray(x, jnp.float32)
    s = jnp.asarray(scores, jnp.float32)
    if mask is None:
        mask = jnp.ones(x.shape[:1], jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    m = mask.sum()
    denom = jnp.maximum(m, 1.0)
    bm = (x * mask[:, None]).sum(0) / denom
    bv = (((x - bm) ** 2) * mask[:, None]).sum(0) / denom
    sm = (s * mask).sum() / denom
    sv = (((s - sm) ** 2) * mask).sum() / denom
    w = 1.0 - jnp.float32(decay) ** m
    w = jnp.where(m > 0, jnp.where(stats.count > 0, w, 1.0), 0.0)
    return DriftStats(
        feat_mean=stats.feat_mean + w * (bm - stats.feat_mean),
        feat_var=stats.feat_var + w * (bv - stats.feat_var),
        score_mean=stats.score_mean + w * (sm - stats.score_mean),
        score_var=stats.score_var + w * (sv - stats.score_var),
        count=stats.count + m)


def drift_statistic(stats: DriftStats, ref: DriftStats,
                    eps: float = 1e-6) -> jnp.ndarray:
    """Normalized shift of ``stats`` away from ``ref`` — 0 when the
    streaming moments match the reference, ~1 when feature means have
    moved one reference standard deviation on average (or the score
    distribution has moved equivalently). Pure jnp.

      feat term:  mean_f |mu_f - mu_ref,f| / sqrt(var_ref,f + eps)
      score term: |s - s_ref| / sqrt(svar_ref + eps)

    The max of the two is reported so either signal alone can trip the
    monitor (covariate shift without score shift, or vice versa)."""
    feat = jnp.mean(jnp.abs(stats.feat_mean - ref.feat_mean)
                    / jnp.sqrt(ref.feat_var + eps))
    score = (jnp.abs(stats.score_mean - ref.score_mean)
             / jnp.sqrt(ref.score_var + eps))
    return jnp.maximum(feat, score)


# ---------------------------------------------------------------------------
# host views (the event-driven engines read the SAME device trajectory)
# ---------------------------------------------------------------------------

def host_view(ws: WorldState) -> dict:
    """One device_get of the whole state as numpy (host-path reads)."""
    h = jax.device_get(ws)
    return {"live": np.asarray(h.live), "bw_scale": np.asarray(h.bw_scale),
            "lat_scale": np.asarray(h.lat_scale),
            "drift_amp": float(h.drift_amp),
            "dropout_scale": float(h.dropout_scale),
            "byz_factor": np.asarray(h.byz_factor)}


def replay(scn: Optional[ScenarioSpec], num_clients: int,
           rounds: int) -> List[dict]:
    """Host replay of the first ``rounds`` world states (one host_view
    per round) — the differential harness's oracle for invariants like
    churn mask conservation, independent of any engine."""
    out = []
    ws = init_world(scn, num_clients)
    for r in range(rounds):
        ws = world_step(ws, r, scn, num_clients)
        out.append(host_view(ws) if is_active(scn) else None)
    return out
