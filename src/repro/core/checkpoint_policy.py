"""Weibull-based adaptive checkpointing (paper §IV-C).

Failure CDF:      F(t)   = 1 − exp(−(t/λ)^k)
Cost function:    C(t_c) = t_w/t_c + F(t_c) · t_r/T
Optimal interval: t_c*   = argmin C(t_c) over (0, T]

Note on fidelity: the paper WRITES the first term as ``t_c/T``, but that
expression is strictly increasing in t_c while F(t_c)·t_r/T is also
increasing — the literal formula is minimized at t_c → 0 (checkpoint
constantly), which cannot be the intended semantics. We read the first
term as the paper surely intends (and as Young/Daly-style analyses
define): the checkpoint WRITE cost t_w amortized over the interval,
``t_w/t_c`` — overhead of checkpointing too often vs expected recovery
loss of checkpointing too rarely. Recorded in DESIGN.md §2.

λ, k are fitted from historical inter-failure times by profile MLE; the
manager re-fits as failures accumulate, so the interval adapts to the
observed failure regime.
"""
from __future__ import annotations

import math
from typing import Sequence

import numpy as np


def weibull_cdf(t, lam: float, k: float):
    t = np.maximum(np.asarray(t, dtype=np.float64), 0.0)
    return 1.0 - np.exp(-((t / lam) ** k))


def weibull_mtbf(lam: float, k: float) -> float:
    """Mean time between failures of Weibull(λ, k): λ·Γ(1+1/k)."""
    return float(lam * math.gamma(1.0 + 1.0 / max(k, 1e-6)))


def checkpoint_cost(t_c, total_time: float, recovery_time: float,
                    lam: float, k: float, write_cost: float = None):
    """Expected overhead per unit time at interval t_c:

        C(t_c) = t_w/t_c  +  (t_c/2 + t_r) / MTBF(λ,k)

    write cost amortized over the interval + expected rework (half an
    interval of lost work + recovery) per failure, failures at the
    Weibull-fitted MTBF rate. This is the Young/Daly form; see the module
    docstring for why the paper's literal ``t_c/T`` first term (and the
    per-interval ``F(t_c)`` weighting, which saturates at 1 for t ≫ λ)
    cannot be used as written."""
    if write_cost is None:
        write_cost = 0.1 * recovery_time
    t_c = np.asarray(t_c, dtype=np.float64)
    mtbf = weibull_mtbf(lam, k)
    return (write_cost / np.maximum(t_c, 1e-12)
            + (0.5 * t_c + recovery_time) / max(mtbf, 1e-12))


def optimal_interval(total_time: float, recovery_time: float,
                     lam: float, k: float, grid: int = 4096,
                     write_cost: float = None) -> float:
    """Grid + golden-section refinement of argmin C(t_c) on (0, T]."""
    ts = np.linspace(total_time / grid, total_time, grid)
    costs = checkpoint_cost(ts, total_time, recovery_time, lam, k,
                            write_cost)
    i = int(np.argmin(costs))
    lo = ts[max(i - 1, 0)]
    hi = ts[min(i + 1, grid - 1)]
    phi = (math.sqrt(5) - 1) / 2
    for _ in range(60):
        m1 = hi - phi * (hi - lo)
        m2 = lo + phi * (hi - lo)
        if checkpoint_cost(m1, total_time, recovery_time, lam, k, write_cost) \
                < checkpoint_cost(m2, total_time, recovery_time, lam, k,
                                  write_cost):
            hi = m2
        else:
            lo = m1
    return float(0.5 * (lo + hi))


def fit_weibull(samples: Sequence[float], k_grid=None) -> tuple:
    """Fit (λ, k) to inter-failure times by profile likelihood over k."""
    x = np.asarray([s for s in samples if s > 0], dtype=np.float64)
    if len(x) == 0:
        return 1e9, 1.0            # no failures observed: effectively stable
    if len(x) == 1:
        return float(x[0]), 1.0
    k_grid = k_grid if k_grid is not None else np.linspace(0.3, 5.0, 150)
    best = (x.mean(), 1.0)
    best_ll = -np.inf
    for k in k_grid:
        lam = (np.mean(x ** k)) ** (1.0 / k)    # MLE of λ given k
        ll = (len(x) * (math.log(k) - k * math.log(lam))
              + (k - 1) * np.sum(np.log(x)) - np.sum((x / lam) ** k))
        if ll > best_ll:
            best_ll, best = ll, (float(lam), float(k))
    return best
