"""Dynamic batch-size optimization (paper §IV-A).

Clients report local metrics (compute capacity, memory headroom, network
latency); the server assigns a batch size proportional to available
resources — "a high-capacity client might train with 512 samples per
batch ... a lower-capacity client uses 64 to prevent straggler delays".

The controller also adapts across rounds from observed round times
(straggler feedback): clients that finish far after the round median get
their batch lowered a power-of-two step; fast clients are promoted.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

_POW2 = (64, 128, 256, 512, 1024)


@dataclasses.dataclass
class ClientMetrics:
    compute: float       # relative throughput, 1.0 = reference
    memory: float        # free-memory fraction in [0,1]
    latency: float       # network RTT seconds


def capacity_score(m: ClientMetrics) -> float:
    """Scalar capacity in (0, ~2]: throughput-dominant, latency-penalized."""
    lat_penalty = 1.0 / (1.0 + 10.0 * max(m.latency, 0.0))
    return max(m.compute, 1e-3) * (0.5 + 0.5 * min(max(m.memory, 0.0), 1.0)) \
        * lat_penalty


def assign_batch_size(m: ClientMetrics, b_min: int = 64,
                      b_max: int = 1024) -> int:
    """Map capacity to the nearest power-of-two batch in [b_min, b_max]."""
    score = capacity_score(m)
    # score 1.0 (reference client) -> geometric middle of the range
    mid = math.sqrt(b_min * b_max)
    raw = mid * score
    best = min(_POW2, key=lambda b: abs(math.log(b) - math.log(max(raw, 1))))
    return int(min(max(best, b_min), b_max))


class BatchSizeController:
    """Cross-round adaptation from straggler feedback (§IV-A)."""

    def __init__(self, b_min: int = 64, b_max: int = 1024,
                 straggler_factor: float = 1.5):
        self.b_min, self.b_max = b_min, b_max
        self.straggler_factor = straggler_factor
        self.assignment: Dict[int, int] = {}

    def initial(self, cid: int, metrics: ClientMetrics) -> int:
        b = assign_batch_size(metrics, self.b_min, self.b_max)
        self.assignment[cid] = b
        return b

    def feedback(self, round_times: Dict[int, float]) -> Dict[int, int]:
        if not round_times:
            return dict(self.assignment)
        med = sorted(round_times.values())[len(round_times) // 2]
        for cid, t in round_times.items():
            b = self.assignment.get(cid, self.b_min)
            if t > self.straggler_factor * med and b > self.b_min:
                self.assignment[cid] = b // 2      # demote straggler
            elif t < med / self.straggler_factor and b < self.b_max:
                self.assignment[cid] = b * 2      # promote fast client
        return dict(self.assignment)
