"""Adaptive client selection (paper §V-C: "efficient client selection
mechanisms identify reliable clients based on historical performance").

Tracks per-client EMAs of (i) availability (did the client deliver an
update, i.e. not drop out), (ii) alignment pass rate (did its update pass
the θ filter), (iii) round time. The selector scores clients as
``reliability × timeliness`` and picks the top-k for the next round; an
ε-greedy floor keeps exploring unreliable clients so slow-but-unique data
is not permanently excluded (the bias concern in §II-A).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


# ---------------------------------------------------------------------------
# two-stage selection, stage 1: the sharded candidate pre-filter
# ---------------------------------------------------------------------------

def candidate_quota(n: int, k: int, frac: float, shards: int) -> int:
    """Per-shard candidate quota for the two-stage pre-filter.

    ``ceil(frac * shard_size)`` floored so the union of per-shard top-
    quota sets always holds >= k REAL clients even when the last logical
    shard is padding-partial (each of the ``pad`` padding positions can
    displace at most one real candidate, hence the ``(k + pad) /
    shards`` floor). With ``quota >= k`` the two-stage top-k is EXACTLY
    the single-stage top-k: every member of the global top-k is inside
    its own shard's top-k (ties break toward lower index in both)."""
    import math
    n, k, shards = int(n), int(k), max(1, min(int(shards), int(n)))
    per = -(-n // shards)
    pad = shards * per - n
    quota = max(math.ceil(float(frac) * per), -(-(k + pad) // shards), 1)
    return min(quota, per)


def candidate_mask_np(scores: np.ndarray, k: int, frac: float,
                      shards: int) -> np.ndarray:
    """(N,) bool numpy oracle of ``control.candidate_mask``: split the
    score vector into ``shards`` contiguous logical shards, keep each
    shard's top-``quota`` (ties -> lower index, matching both
    ``jax.lax.top_k`` and stable descending argsort)."""
    scores = np.asarray(scores)
    n = scores.shape[0]
    shards = max(1, min(int(shards), n))
    per = -(-n // shards)
    quota = candidate_quota(n, k, frac, shards)
    pad = shards * per - n
    s = np.concatenate([scores, np.full((pad,), -np.inf, scores.dtype)]) \
        if pad else scores
    s = s.reshape(shards, per)
    keep = np.argsort(-s, axis=1, kind="stable")[:, :quota]
    mask = np.zeros((shards, per), bool)
    np.put_along_axis(mask, keep, True, axis=1)
    return mask.reshape(-1)[:n]


@dataclasses.dataclass
class ClientRecord:
    availability: float = 1.0
    pass_rate: float = 1.0
    round_time: float = 1.0


class AdaptiveClientSelector:
    def __init__(self, num_clients: int, ema: float = 0.8,
                 epsilon: float = 0.1, seed: int = 0):
        self.records: Dict[int, ClientRecord] = {
            c: ClientRecord() for c in range(num_clients)}
        self.ema = ema
        self.epsilon = epsilon
        self.rng = np.random.default_rng(seed)

    def observe(self, cid: int, *, delivered: bool, passed: bool = True,
                round_time: float = 1.0):
        r = self.records[cid]
        e = self.ema
        r.availability = e * r.availability + (1 - e) * float(delivered)
        if delivered:
            r.pass_rate = e * r.pass_rate + (1 - e) * float(passed)
            r.round_time = e * r.round_time + (1 - e) * float(round_time)

    def score(self, cid: int) -> float:
        r = self.records[cid]
        timeliness = 1.0 / (1.0 + r.round_time)
        return r.availability * (0.5 + 0.5 * r.pass_rate) * timeliness

    def select(self, k: int, live=None, candidates=None) -> List[int]:
        """Top-k + ε-greedy selection. ``live`` (optional bool mask by
        cid) restricts both the top-k and the exploration pool to the
        currently-live roster (scenario churn) — the same pre-selection
        masking the device control plane applies, so every execution
        path fills its cohort from the same candidate set. ``live=None``
        leaves the historical draw sequence untouched.

        ``candidates`` (optional bool mask, ``candidate_mask_np``) is
        stage 1 of two-stage selection: top-k AND exploration pool are
        restricted to the candidate union — at scale neither may touch
        the full population. ``None`` / all-True leaves everything
        bit-identical."""
        cids = [c for c in self.records
                if (live is None or live[c])
                and (candidates is None or candidates[c])]
        if not cids:
            return []
        scores = np.array([self.score(c) for c in cids])
        order = list(np.argsort(-scores))
        chosen = [cids[i] for i in order[:k]]
        # ε-greedy exploration: swap in random unchosen clients
        # (set membership: the old `c not in chosen` list scan was O(n·k);
        # pool order and contents are identical, so seeded draws match)
        chosen_set = set(chosen)
        pool = [c for c in cids if c not in chosen_set]
        for i in range(len(chosen)):
            if pool and self.rng.random() < self.epsilon:
                j = self.rng.integers(len(pool))
                chosen[i] = pool.pop(int(j))
        return chosen
