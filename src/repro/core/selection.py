"""Adaptive client selection (paper §V-C: "efficient client selection
mechanisms identify reliable clients based on historical performance").

Tracks per-client EMAs of (i) availability (did the client deliver an
update, i.e. not drop out), (ii) alignment pass rate (did its update pass
the θ filter), (iii) round time. The selector scores clients as
``reliability × timeliness`` and picks the top-k for the next round; an
ε-greedy floor keeps exploring unreliable clients so slow-but-unique data
is not permanently excluded (the bias concern in §II-A).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass
class ClientRecord:
    availability: float = 1.0
    pass_rate: float = 1.0
    round_time: float = 1.0


class AdaptiveClientSelector:
    def __init__(self, num_clients: int, ema: float = 0.8,
                 epsilon: float = 0.1, seed: int = 0):
        self.records: Dict[int, ClientRecord] = {
            c: ClientRecord() for c in range(num_clients)}
        self.ema = ema
        self.epsilon = epsilon
        self.rng = np.random.default_rng(seed)

    def observe(self, cid: int, *, delivered: bool, passed: bool = True,
                round_time: float = 1.0):
        r = self.records[cid]
        e = self.ema
        r.availability = e * r.availability + (1 - e) * float(delivered)
        if delivered:
            r.pass_rate = e * r.pass_rate + (1 - e) * float(passed)
            r.round_time = e * r.round_time + (1 - e) * float(round_time)

    def score(self, cid: int) -> float:
        r = self.records[cid]
        timeliness = 1.0 / (1.0 + r.round_time)
        return r.availability * (0.5 + 0.5 * r.pass_rate) * timeliness

    def select(self, k: int, live=None) -> List[int]:
        """Top-k + ε-greedy selection. ``live`` (optional bool mask by
        cid) restricts both the top-k and the exploration pool to the
        currently-live roster (scenario churn) — the same pre-selection
        masking the device control plane applies, so every execution
        path fills its cohort from the same candidate set. ``live=None``
        leaves the historical draw sequence untouched."""
        cids = [c for c in self.records if live is None or live[c]]
        if not cids:
            return []
        scores = np.array([self.score(c) for c in cids])
        order = list(np.argsort(-scores))
        chosen = [cids[i] for i in order[:k]]
        # ε-greedy exploration: swap in random unchosen clients
        # (set membership: the old `c not in chosen` list scan was O(n·k);
        # pool order and contents are identical, so seeded draws match)
        chosen_set = set(chosen)
        pool = [c for c in cids if c not in chosen_set]
        for i in range(len(chosen)):
            if pool and self.rng.random() < self.epsilon:
                j = self.rng.integers(len(pool))
                chosen[i] = pool.pop(int(j))
        return chosen
