"""Hierarchical (cross-pod) selective synchronization — beyond-paper.

.. deprecated:: PR 9
    This module is the 2-tier special case; the general declarative
    machinery now lives in :mod:`repro.topology` (`TopologySpec` tier
    trees wired through ``ExperimentSpec(topology=...)``).  The
    equivalent of ``maybe_pod_sync(sync_every=S, theta=T)`` is the
    2-tier tree ``as_topology_spec(sync_every=S, theta=T)`` (or the
    ``"two-tier-pods"`` preset).  `maybe_pod_sync` is kept intact as
    the oracle-pinned reference implementation — new code should
    attach a `TopologySpec` instead.

The paper's async + selective-update idea applied RECURSIVELY to the pod
axis of the production mesh: within a pod, every round runs the masked
selective all-reduce (core/fl_step.py); ACROSS pods, models sync only
every ``sync_every`` rounds, and the cross-pod exchange itself is gated by
the SAME sign-alignment test — a pod whose aggregate movement disagrees
with the global direction keeps training locally (async between pods, the
paper's Fig. 2 at datacenter scale).

Pure-jnp + lax.cond; the pod dim is materialized as a leading axis (one
row per pod), so the same code runs under pjit on the 2×16×16 mesh (pod
axis sharded) and in CPU simulation (pod axis local).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import aggregation, alignment
# re-exported for migration: the N-tier generalization of this module
from repro.topology.spec import TierSpec, TopologySpec  # noqa: F401


def as_topology_spec(*, fanout: int = 8, sync_every: int = 4,
                     theta: float = 0.65,
                     assignment_seed: int = 0) -> TopologySpec:
    """The `repro.topology` equivalent of this module's 2-tier scheme:
    leaf pods of ``fanout`` clients syncing into one global tier every
    ``sync_every`` rounds under the same theta veto."""
    return TopologySpec(tiers=(
        TierSpec("pod", fanout=fanout),
        TierSpec("global", sync_every=sync_every, theta=theta)),
        assignment_seed=assignment_seed)


class PodSyncState(NamedTuple):
    global_ref_sign: dict      # sign of the last cross-pod global update
    last_global: dict          # params after the last cross-pod sync
    rounds_since_sync: jnp.ndarray
    has_ref: jnp.ndarray
    # bool scalar: a sync has happened, so global_ref_sign is a real
    # reference. Tracked explicitly because rounds_since_sync == 0 ALSO
    # holds right after every sync reset — keying the bootstrap rule on
    # the counter silently disarmed the cross-pod veto at sync_every=1.


def init_pod_sync(params) -> PodSyncState:
    return PodSyncState(
        global_ref_sign=jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.int8), params),
        last_global=jax.tree.map(lambda p: p.astype(jnp.float32), params),
        rounds_since_sync=jnp.zeros((), jnp.int32),
        has_ref=jnp.asarray(False))


def maybe_pod_sync(pod_params, state: PodSyncState, *, sync_every: int,
                   theta: float = 0.65):
    """pod_params: pytree with leading pod dim P. Returns
    (new_pod_params, new_state, metrics)."""
    P = jax.tree.leaves(pod_params)[0].shape[0]
    due = (state.rounds_since_sync + 1) >= sync_every

    def do_sync(_):
        # each pod's movement since the last global sync
        deltas = jax.tree.map(
            lambda p, g: p.astype(jnp.float32) - g[None],
            pod_params, state.last_global)
        ratios = alignment.per_client_alignment(deltas, state.global_ref_sign)
        passed = alignment.selection_mask(ratios, theta)
        # bootstrap / fallback: accept all when no reference or no pass
        mask = jnp.where((passed.sum() > 0) & state.has_ref,
                         passed, jnp.ones_like(passed))
        agg_delta = aggregation.masked_mean(deltas, mask)
        new_global = jax.tree.map(
            lambda g, d: g + d, state.last_global, agg_delta)
        new_pod = jax.tree.map(
            lambda g, p: jnp.broadcast_to(g[None], p.shape).astype(p.dtype),
            new_global, pod_params)
        new_ref = jax.tree.map(
            lambda d: jnp.sign(d).astype(jnp.int8), agg_delta)
        return (new_pod, PodSyncState(new_ref, new_global,
                                      jnp.zeros((), jnp.int32),
                                      jnp.asarray(True)),
                {"synced": jnp.float32(1.0), "pod_accept": mask.mean(),
                 "pod_alignment": ratios.mean()})

    def no_sync(_):
        return (pod_params,
                state._replace(rounds_since_sync=state.rounds_since_sync + 1),
                {"synced": jnp.float32(0.0),
                 "pod_accept": jnp.float32(0.0),
                 "pod_alignment": jnp.float32(0.0)})

    return jax.lax.cond(due, do_sync, no_sync, None)