"""Quantized update aggregation with error feedback (beyond-paper §VI:
"compression (e.g., gradient quantization) remains a complementary option
for bandwidth-constrained scenarios").

Client→server updates are per-row int8-quantized (kernels/quantize.py, 4×
fewer bytes on the wire — multiplicative with the θ-filter's savings).
Quantization residuals are carried in per-client ERROR-FEEDBACK buffers
(Seide et al. / EF-SGD) so the compression bias vanishes over rounds:

    q_t   = Q(g_t + e_{t-1})
    e_t   = (g_t + e_{t-1}) − deQ(q_t)

The aggregation itself then operates on dequantized updates — drop-in with
``masked_mean``. ``quantize_for_transport`` / ``dequantize_from_transport``
are the wire format used by the async simulator's bandwidth accounting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import arena as arena_ops
from repro.kernels import ops


def init_error_state(params):
    """Per-client error-feedback buffers (fp32, zero)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# batched (arena-space) error feedback — the cohort megastep path
# ---------------------------------------------------------------------------

def init_error_arena(num_clients: int, arena) -> jnp.ndarray:
    """All clients' EF buffers as ONE (N, rows, lane) f32 device array —
    gathered/scattered by cohort index inside the megastep, so per-round
    compression costs one dispatch instead of O(clients) pytree walks."""
    return jnp.zeros((num_clients, arena.rows, arena.lane), jnp.float32)


def compress_cohort(deltas, err):
    """EF-corrected int8 round-trip for a whole cohort in arena space.

    deltas, err: (C, rows, lane) f32. Returns (restored, new_err) where
    ``restored`` is the dequantized wire payload (what the server sees)
    and ``new_err`` the residuals to carry. Row-wise quantization is
    independent per row, so the cohort folds into one (C·rows, lane)
    kernel call — identical scales to the per-client path.
    """
    corrected = deltas + err
    C, R, L = corrected.shape
    q, s = arena_ops.quantize_rows(corrected.reshape(C * R, L))
    restored = arena_ops.dequantize_rows(q, s).reshape(C, R, L)
    return restored, corrected - restored


def arena_wire_bytes(arena) -> int:
    """Wire bytes of one client's compressed update in the arena layout
    (int8 payload + one f32 scale per row) — matches ``transport_bytes``
    for the same flattened tree."""
    return arena.rows * arena.lane + 4 * arena.rows


def compress_update(update, error, interpret=None):
    """(update, error) -> (q, scales, n_true, new_error).

    q/scales are the transport payload: bytes = n_lanes + 4·rows vs 4·n.
    """
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, update, error)
    q, s, n = ops.quantize_tree(corrected, interpret=interpret)
    restored = ops.dequantize_tree(q, s, corrected, interpret=interpret)
    new_error = jax.tree.map(lambda c, r: c - r.astype(jnp.float32),
                             corrected, restored)
    return q, s, n, new_error


def decompress_update(q, s, like, interpret=None):
    return ops.dequantize_tree(q, s, like, interpret=interpret)


def transport_bytes(q, s) -> int:
    """Actual wire bytes of a compressed update."""
    return int(q.size * q.dtype.itemsize + s.size * s.dtype.itemsize)


def compression_ratio(params) -> float:
    """fp32-update bytes / compressed bytes (≈4 for int8+row scales)."""
    n = sum(x.size for x in jax.tree.leaves(params))
    rows = (n + ops.LANE - 1) // ops.LANE
    return (4.0 * n) / (rows * ops.LANE + 4.0 * rows)