"""Quantized update aggregation with error feedback (beyond-paper §VI:
"compression (e.g., gradient quantization) remains a complementary option
for bandwidth-constrained scenarios").

Client→server updates are per-row int8-quantized (kernels/quantize.py, 4×
fewer bytes on the wire — multiplicative with the θ-filter's savings).
Quantization residuals are carried in per-client ERROR-FEEDBACK buffers
(Seide et al. / EF-SGD) so the compression bias vanishes over rounds:

    q_t   = Q(g_t + e_{t-1})
    e_t   = (g_t + e_{t-1}) − deQ(q_t)

The aggregation itself then operates on dequantized updates — drop-in with
``masked_mean``. ``quantize_for_transport`` / ``dequantize_from_transport``
are the wire format used by the async simulator's bandwidth accounting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


def init_error_state(params):
    """Per-client error-feedback buffers (fp32, zero)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_update(update, error, interpret=None):
    """(update, error) -> (q, scales, n_true, new_error).

    q/scales are the transport payload: bytes = n_lanes + 4·rows vs 4·n.
    """
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, update, error)
    q, s, n = ops.quantize_tree(corrected, interpret=interpret)
    restored = ops.dequantize_tree(q, s, corrected, interpret=interpret)
    new_error = jax.tree.map(lambda c, r: c - r.astype(jnp.float32),
                             corrected, restored)
    return q, s, n, new_error


def decompress_update(q, s, like, interpret=None):
    return ops.dequantize_tree(q, s, like, interpret=interpret)


def transport_bytes(q, s) -> int:
    """Actual wire bytes of a compressed update."""
    return int(q.size * q.dtype.itemsize + s.size * s.dtype.itemsize)


def compression_ratio(params) -> float:
    """fp32-update bytes / compressed bytes (≈4 for int8+row scales)."""
    n = sum(x.size for x in jax.tree.leaves(params))
    rows = (n + ops.LANE - 1) // ops.LANE
    return (4.0 * n) / (rows * ops.LANE + 4.0 * rows)