"""Production federated train step — the paper's technique as a single
SPMD program on the production mesh (DESIGN.md §4).

One FL "round" = one compiled step:
  1. the global batch arrives client-batched: leading dim C (one FL client
     cohort per (pod×data) mesh shard);
  2. ``vmap(grad)`` produces per-client gradient pytrees (C, ...) — each
     mesh shard materializes exactly one client's gradients;
  3. gradients are packed ONCE into the flat (C, rows, LANE) parameter
     arena (repro.kernels.arena); per-client sign-alignment ratios vs the
     sign of the previous global update (Algorithm 1,
     CALCULATE-RELEVANCE) run as one kernel sweep over that buffer —
     Pallas on TPU, jnp oracle on CPU;
  4. the mask ``ratio ≥ θ`` gates a weighted arena sum over C — GSPMD
     lowers this to a masked all-reduce (the paper's selective update as
     a collective);
  5. optimizer update + new reference sign.

``theta=None`` (or mask forced to ones) gives the synchronous FedAvg
baseline the paper compares against. If no client passes, parameters and
ref_sign are kept unchanged (server keeps w_g — §IV-C).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import alignment
from repro.kernels import arena as arena_mod
from repro.models import api
from repro.optim import adamw as optim_mod


class FLState(NamedTuple):
    params: dict
    opt_state: dict
    ref_sign: dict          # int8 sign of last accepted global update
    step: jnp.ndarray       # i32
    metrics: dict           # running counters (accept rate, bytes saved)


def init_state(rng, cfg, optimizer=None) -> FLState:
    params = api.init_params(rng, cfg)
    optimizer = optimizer or optim_mod.for_config(cfg)
    opt_state = optimizer.init(params)
    ref_sign = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.int8), params)
    return FLState(params, opt_state, ref_sign, jnp.zeros((), jnp.int32),
                   {"accepted": jnp.zeros((), jnp.float32),
                    "rounds": jnp.zeros((), jnp.float32)})


def make_raw_step(cfg, optimizer=None, theta: Optional[float] = 0.65,
                  lr_schedule=None, agg_dtype=jnp.bfloat16,
                  beacon_bytes: float = 0.125):
    """Un-jitted step(state, batch) -> (state, metrics) — the dry-run wraps
    this with explicit in/out shardings; trainers use build_fl_train_step.

    batch leaves have leading dims (C, per_client_batch, ...).
    theta=None -> synchronous FedAvg baseline (mask == ones).
    agg_dtype: cross-client reduction precision (§Perf iteration E —
    bf16 halves the aggregation all-reduce; optimizer math stays fp32).
    beacon_bytes: wire cost of a filtered client's 1-bit skip beacon —
    charged into ``bytes_sent`` so the metric matches the event-driven
    simulator's accounting (CommModel.beacon_bytes).
    """
    optimizer = optimizer or optim_mod.for_config(cfg)
    # static arena layout from the config's parameter template — no
    # allocation (eval_shape); pack/unpack trace away inside the step
    template = jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0), cfg))
    arena = arena_mod.ParamArena(template)

    def loss_for_client(params, client_batch):
        return api.loss_fn(params, client_batch, cfg)

    def step(state: FLState, batch):
        # (2) per-client gradients — one client per mesh shard
        loss, grads = jax.vmap(
            jax.value_and_grad(loss_for_client), in_axes=(None, 0)
        )(state.params, batch)                                 # loss: (C,)
        C = loss.shape[0]

        # (3)+(4) selective aggregation (the paper's contribution) on the
        # flat (C, rows, LANE) arena — one packed buffer, one kernel sweep
        u = arena.pack_cohort(grads)
        if theta is None:
            mask = jnp.ones((C,), jnp.float32)
            ratios = jnp.ones((C,), jnp.float32)
            passed = mask
        else:
            ratios = alignment.cohort_alignment(
                u, arena.pack_signs(state.ref_sign), arena.n)
            passed = alignment.selection_mask(ratios, theta)
            # bootstrap: round 0 has no reference direction yet -> accept all
            passed = jnp.where(state.step == 0, jnp.ones_like(passed), passed)
            # production fallback (deviation from the paper's "server keeps
            # w_g", which deadlocks a per-step trainer): if NO client passes
            # θ this round, accept all rather than stall. The faithful
            # keep-w_g semantics live in the async simulator path.
            mask = jnp.where(passed.sum() > 0, passed, jnp.ones_like(passed))
        w = mask / jnp.maximum(mask.sum(), 1e-9)
        agg = arena.unpack(
            arena_mod.weighted_sum(u, w, compute_dtype=agg_dtype),
            dtype=jnp.float32)
        any_accepted = mask.sum() > 0

        # (5) optimizer update; hold position if nothing was accepted
        lr_now = lr_schedule(state.step) if lr_schedule else None
        new_params, new_opt = optimizer.update(agg, state.opt_state,
                                               state.params, lr_now=lr_now)
        keep = lambda new, old: jax.tree.map(
            lambda n, o: jnp.where(any_accepted, n, o), new, old)
        new_params = keep(new_params, state.params)
        new_opt = keep(new_opt, state.opt_state)
        new_ref = jax.tree.map(
            lambda a, r: jnp.where(any_accepted,
                                   jnp.sign(a).astype(jnp.int8), r),
            agg, state.ref_sign)

        update_bytes = _update_bytes(state.params)
        metrics = {
            "loss": loss.mean(),
            "accept_rate": passed.mean(),
            "alignment_mean": ratios.mean(),
            # per-client transmit mask (post-fallback) — the api runner
            # needs it for per-client transfer-time accounting
            "mask": mask,
            # client->server bytes actually transmitted this round (the
            # paper's communication-overhead metric, §V-D); filtered
            # clients are charged their 1-bit skip beacon, matching the
            # event-driven simulator
            "bytes_sent": (mask.sum() * update_bytes
                           + (jnp.float32(C) - mask.sum()) * beacon_bytes),
            "bytes_baseline": jnp.float32(C) * update_bytes,
        }
        run = {"accepted": state.metrics["accepted"] + mask.sum(),
               "rounds": state.metrics["rounds"] + 1.0}
        return FLState(new_params, new_opt, new_ref, state.step + 1, run), metrics

    return step


def build_fl_train_step(cfg, optimizer=None, theta: Optional[float] = 0.65,
                        lr_schedule=None, donate: bool = True,
                        beacon_bytes: float = 0.125):
    """jit'd step(state, batch) -> (state, metrics)."""
    step = make_raw_step(cfg, optimizer, theta, lr_schedule,
                         beacon_bytes=beacon_bytes)
    if donate:
        return jax.jit(step, donate_argnums=(0,))
    return jax.jit(step)


def _update_bytes(params) -> jnp.ndarray:
    n = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    return jnp.float32(n)


# ---------------------------------------------------------------------------
# serving / prefill steps (used by the dry-run for the inference shapes)
# ---------------------------------------------------------------------------

def build_prefill_step(cfg):
    def step(params, batch):
        return api.prefill(params, batch, cfg)
    return step


def build_serve_step(cfg):
    def step(params, cache, batch):
        return api.decode_step(params, cache, batch, cfg)
    return step
