"""Production federated train step — the paper's technique as a single
SPMD program on the production mesh (DESIGN.md §4).

One FL "round" = one compiled step:
  1. the global batch arrives client-batched: leading dim C (one FL client
     cohort per (pod×data) mesh shard);
  2. ``vmap(grad)`` produces per-client gradient pytrees (C, ...) — each
     mesh shard materializes exactly one client's gradients;
  3. gradients are packed ONCE into the flat (C, rows, LANE) parameter
     arena (repro.kernels.arena); per-client sign-alignment ratios vs the
     sign of the previous global update (Algorithm 1,
     CALCULATE-RELEVANCE) run as one kernel sweep over that buffer —
     Pallas on TPU, jnp oracle on CPU;
  4. the mask ``ratio ≥ θ`` gates a weighted arena sum over C — GSPMD
     lowers this to a masked all-reduce (the paper's selective update as
     a collective);
  5. optimizer update + new reference sign.

``theta=None`` (or mask forced to ones) gives the synchronous FedAvg
baseline the paper compares against. If no client passes, parameters and
ref_sign are kept unchanged (server keeps w_g — §IV-C).

The device-resident control plane (core/control.py) routes through this
step as COHORT MASKING: with a ``ControlPlane`` attached, adaptive
selection (top-k + ε-greedy over reliability scores), per-client dropout
draws, per-client LR scaling and int8+error-feedback wire quantization
all run inside the same compiled program — clients that are unselected
or dropped simply carry zero aggregation weight and zero wire bytes, so
the cohort dim stays static and nothing retraces.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import alignment, compression
from repro.core import control as control_mod
from repro.core import scenario as scenario_mod
from repro.kernels import arena as arena_mod
from repro.models import api
from repro.optim import adamw as optim_mod
from repro.topology import engine as topology_engine
from repro.topology.spec import resolve_topology


class FLState(NamedTuple):
    params: dict
    opt_state: dict
    ref_sign: dict          # int8 sign of last accepted global update
    step: jnp.ndarray       # i32
    metrics: dict           # running counters (accept rate, bytes saved)
    control: Optional[control_mod.ControlState] = None
    # device control plane (None -> plain masked-FedAvg semantics)
    world: Optional[scenario_mod.WorldState] = None
    # dynamic-world scenario state (None -> the world stays frozen);
    # transitions run INSIDE the compiled step (core/scenario.py), so
    # churn / drift / byzantine corruption cost no extra dispatches
    topology: Optional[topology_engine.TopologyState] = None
    # hierarchical topology carry (repro.topology): per-tier pod
    # accumulators + reference signs; advanced inside the compiled step
    # every round, cadence keyed off the absolute ``step`` counter


@dataclasses.dataclass(frozen=True)
class ControlPlane:
    """Static configuration of the spmd engine's device control plane.

    ``select_k == num_clients`` disables selection; an empty
    ``dropout_p`` disables dropout draws. ``round_time_hint`` is the
    analytic per-client round time (train + transfer at the CommModel's
    rates) the reliability EMAs observe — the compiled step has no event
    clock, so timeliness is scored from this static profile-derived
    estimate while availability / pass-rate stay live per round.
    """
    num_clients: int
    select_k: int
    epsilon: float = 0.1
    candidate_frac: Optional[float] = None
    # two-stage selection: per-shard candidate pre-filter before the
    # exact masked top-k (None -> single-stage; 1.0 bit-identical to it)
    candidate_shards: int = 8
    grad_norm_selection: bool = False
    dropout_p: Tuple[float, ...] = ()
    quantize: bool = False
    per_client_lr: bool = False
    round_time_hint: Tuple[float, ...] = ()
    seed: int = 0
    ema: float = 0.8

    @property
    def selecting(self) -> bool:
        return (self.grad_norm_selection
                or self.select_k < self.num_clients)

    @property
    def has_dropout(self) -> bool:
        return any(p > 0 for p in self.dropout_p)

    def active(self) -> bool:
        return (self.selecting or self.has_dropout or self.quantize
                or self.per_client_lr)


def init_state(rng, cfg, optimizer=None,
               control_plane: Optional[ControlPlane] = None,
               scenario=None, num_clients: Optional[int] = None,
               topology=None, comm=None) -> FLState:
    params = api.init_params(rng, cfg)
    optimizer = optimizer or optim_mod.for_config(cfg)
    opt_state = optimizer.init(params)
    ref_sign = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.int8), params)
    ctl = None
    if control_plane is not None and control_plane.active():
        arena = arena_mod.ParamArena(jax.eval_shape(lambda: params))
        ctl = control_mod.init_control(
            control_plane.num_clients, arena=arena,
            quantize=control_plane.quantize)
    world = None
    if scenario_mod.is_active(scenario):
        n = num_clients if num_clients is not None else (
            control_plane.num_clients if control_plane is not None
            else None)
        if n is None:
            raise ValueError("init_state(scenario=...) needs num_clients "
                             "(or a control_plane that names it)")
        world = scenario_mod.init_world(scenario, n)
    topo = None
    topology = resolve_topology(topology)
    if topology is not None:
        n = num_clients if num_clients is not None else (
            control_plane.num_clients if control_plane is not None
            else None)
        if n is None:
            raise ValueError("init_state(topology=...) needs num_clients "
                             "(or a control_plane that names it)")
        arena = arena_mod.ParamArena(jax.eval_shape(lambda: params))
        topo = topology_engine.TopologyRuntime(
            topology, n, arena, comm).init()
    return FLState(params, opt_state, ref_sign, jnp.zeros((), jnp.int32),
                   {"accepted": jnp.zeros((), jnp.float32),
                    "rounds": jnp.zeros((), jnp.float32)}, ctl, world,
                   topo)


def make_raw_step(cfg, optimizer=None, theta: Optional[float] = 0.65,
                  lr_schedule=None, agg_dtype=jnp.bfloat16,
                  beacon_bytes: float = 0.125,
                  control_plane: Optional[ControlPlane] = None,
                  scenario=None, drift_dirs=None, label_key: str = "y",
                  topology=None, comm=None,
                  num_clients: Optional[int] = None):
    """Un-jitted step(state, batch) -> (state, metrics) — the dry-run wraps
    this with explicit in/out shardings; trainers use build_fl_train_step.

    batch leaves have leading dims (C, per_client_batch, ...).
    theta=None -> synchronous FedAvg baseline (mask == ones).
    agg_dtype: cross-client reduction precision (§Perf iteration E —
    bf16 halves the aggregation all-reduce; optimizer math stays fp32).
    beacon_bytes: wire cost of a filtered client's 1-bit skip beacon —
    charged into ``bytes_sent`` so the metric matches the event-driven
    simulator's accounting (CommModel.beacon_bytes).
    control_plane: attach the device control plane — adaptive selection,
    dropout, per-client LR and quantized updates as cohort masking.
    scenario: attach the dynamic-world scenario (core/scenario.py) —
    churn gates the cohort masks, drift shifts the batch, byzantine
    factors corrupt updates before θ scoring, all inside this one
    compiled program; the WorldState rides in ``FLState.world``.
    """
    optimizer = optimizer or optim_mod.for_config(cfg)
    # static arena layout from the config's parameter template — no
    # allocation (eval_shape); pack/unpack trace away inside the step
    template = jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0), cfg))
    arena = arena_mod.ParamArena(template)
    cp = control_plane if (control_plane is not None
                           and control_plane.active()) else None
    scn = scenario if scenario_mod.is_active(scenario) else None
    dirs = (jnp.asarray(drift_dirs)
            if (scn is not None and scn.drift is not None) else None)
    wire_bytes = (float(compression.arena_wire_bytes(arena))
                  if (cp and cp.quantize) else None)
    topo_rt = None
    topology = resolve_topology(topology)
    if topology is not None:
        n_top = num_clients if num_clients is not None else (
            cp.num_clients if cp is not None else None)
        if n_top is None:
            raise ValueError("make_raw_step(topology=...) needs "
                             "num_clients (or an active control_plane)")
        topo_rt = topology_engine.TopologyRuntime(topology, n_top, arena,
                                                  comm)

    def loss_for_client(params, client_batch):
        return api.loss_fn(params, client_batch, cfg)

    def step(state: FLState, batch):
        # (1b) dynamic world: this round's WorldState (FLState.world)
        ws = state.world
        if scn is not None:
            ws = scenario_mod.world_step(ws, state.step, scn,
                                         ws.live.shape[0])
            if dirs is not None:
                batch = scenario_mod.apply_drift(batch, ws.drift_amp,
                                                 dirs, label_key)

        # (2) per-client gradients — one client per mesh shard
        loss, grads = jax.vmap(
            jax.value_and_grad(loss_for_client), in_axes=(None, 0)
        )(state.params, batch)                                 # loss: (C,)
        C = loss.shape[0]
        ctl = state.control

        # (2b) control plane: selection + dropout as static-width masks
        if cp is not None:
            key = jax.random.fold_in(jax.random.PRNGKey(cp.seed),
                                     state.step)
            k_sel, k_drop = jax.random.split(key)
            if cp.has_dropout:
                drop_p = jnp.asarray(cp.dropout_p, jnp.float32)
                if scn is not None and scn.dropout is not None:
                    drop_p = drop_p * ws.dropout_scale
                delivered = jax.random.uniform(k_drop, (C,)) >= drop_p
            else:
                delivered = jnp.ones((C,), bool)
            if cp.grad_norm_selection:
                gn = (ctl.grad_norm if scn is None
                      else jnp.where(ws.live, ctl.grad_norm, -jnp.inf))
                sel_idx = jnp.argsort(-gn, stable=True)[:cp.select_k]
            elif cp.selecting:
                scores = control_mod.score(ctl)
                if scn is not None:
                    scores = jnp.where(ws.live, scores, -jnp.inf)
                sel_idx = control_mod.select_topk(
                    scores, cp.select_k, key=k_sel, epsilon=cp.epsilon,
                    live=None if scn is None else ws.live,
                    candidate_frac=cp.candidate_frac,
                    candidate_shards=cp.candidate_shards)
            else:
                sel_idx = None
            if sel_idx is not None:
                selected = jnp.zeros((C,), bool).at[sel_idx].set(True)
            else:
                selected = jnp.ones((C,), bool)
            if scn is not None:
                # churned-out clients are absent: they deliver nothing
                # (and are never observed by the reliability EMAs below)
                delivered = delivered & ws.live
            active = selected & delivered
        else:
            selected = delivered = active = jnp.ones((C,), bool)
            if scn is not None:
                delivered = ws.live
                active = selected & delivered

        # (3)+(4) selective aggregation (the paper's contribution) on the
        # flat (C, rows, LANE) arena — one packed buffer, one kernel sweep
        u = arena.pack_cohort(grads)
        if cp is not None and cp.per_client_lr:
            u = u * ctl.lr_scale[:, None, None]
        if scn is not None and scn.byzantine is not None:
            # corruption BEFORE wire compression and θ scoring — the
            # server receives (and the filter judges) the corrupted update
            u = u * ws.byz_factor[:, None, None]
        if cp is not None and cp.quantize:
            # int8 + error feedback on the wire; only clients that
            # actually participate quantize / carry residuals
            restored, residual = compression.compress_cohort(
                u, ctl.ef[:C])
            u = jnp.where(active[:, None, None], restored, u)
            ctl = ctl._replace(ef=ctl.ef.at[:C].set(
                jnp.where(active[:, None, None], residual, ctl.ef[:C])))
        # norms AFTER the quantize round-trip — what the server actually
        # receives, matching the host engines' grad_norm EMAs
        norms = jnp.sqrt(jnp.sum(u * u, axis=(1, 2)))
        if theta is None:
            ratios = jnp.ones((C,), jnp.float32)
            passed = active.astype(jnp.float32)
            mask = passed
        else:
            ratios = alignment.cohort_alignment(
                u, arena.pack_signs(state.ref_sign), arena.n)
            passed = alignment.selection_mask(ratios, theta)
            # bootstrap: round 0 has no reference direction yet -> accept all
            passed = jnp.where(state.step == 0, jnp.ones_like(passed), passed)
            passed = passed * active.astype(jnp.float32)
            # production fallback (deviation from the paper's "server keeps
            # w_g", which deadlocks a per-step trainer): if NO participating
            # client passes θ this round, accept all participants rather
            # than stall. The faithful keep-w_g semantics live in the async
            # simulator path.
            mask = jnp.where(passed.sum() > 0, passed,
                             active.astype(jnp.float32))
        w = mask / jnp.maximum(mask.sum(), 1e-9)
        agg = arena.unpack(
            arena_mod.weighted_sum(u, w, compute_dtype=agg_dtype),
            dtype=jnp.float32)
        any_accepted = mask.sum() > 0

        # (5) optimizer update; hold position if nothing was accepted
        lr_now = lr_schedule(state.step) if lr_schedule else None
        new_params, new_opt = optimizer.update(agg, state.opt_state,
                                               state.params, lr_now=lr_now)
        keep = lambda new, old: jax.tree.map(
            lambda n, o: jnp.where(any_accepted, n, o), new, old)
        new_params = keep(new_params, state.params)
        new_opt = keep(new_opt, state.opt_state)
        new_ref = jax.tree.map(
            lambda a, r: jnp.where(any_accepted,
                                   jnp.sign(a).astype(jnp.int8), r),
            agg, state.ref_sign)

        # (5b) hierarchical topology: leaf-pod accumulation of the SAME
        # weighted cohort deltas the aggregation consumed + due syncs
        topo = state.topology
        if topo_rt is not None:
            topo = topo_rt.step(topo, state.step, u, w)

        # (6) control-plane statistics for the next round's selection
        if cp is not None:
            cohort = jnp.arange(C)
            sent = mask > 0
            hint = (jnp.asarray(cp.round_time_hint, jnp.float32)
                    if cp.round_time_hint else jnp.ones((C,), jnp.float32))
            obs_mask = (selected if scn is None else selected & ws.live)
            ctl = control_mod.observe(ctl, cohort, mask=obs_mask,
                                      delivered=delivered, passed=sent,
                                      round_time=hint, ema=cp.ema)
            ctl = control_mod.grad_norm_update(ctl, cohort, norms, active)
            if cp.per_client_lr:
                ctl = control_mod.lr_scale_update(ctl, cohort, norms,
                                                  active)
            ctl = control_mod.staleness_update(ctl, cohort, sent)

        update_bytes = (jnp.float32(wire_bytes) if wire_bytes
                        else _update_bytes(state.params))
        n_sel = (selected if scn is None
                 else selected & ws.live).sum().astype(jnp.float32)
        metrics = {
            "loss": loss.mean(),
            # pre-fallback pass fraction over the selected cohort (the
            # paper's acceptance-rate metric; == passed.mean() when the
            # control plane is off)
            "accept_rate": passed.sum() / jnp.maximum(n_sel, 1.0),
            "alignment_mean": ratios.mean(),
            # per-client transmit mask (post-fallback) — the api runner
            # needs it for per-client transfer-time accounting
            "mask": mask,
            "selected": selected.astype(jnp.float32),
            "delivered": delivered.astype(jnp.float32),
            # client->server bytes actually transmitted this round (the
            # paper's communication-overhead metric, §V-D); filtered
            # clients are charged their 1-bit skip beacon, matching the
            # event-driven simulator; unselected / dropped clients send
            # nothing at all
            "bytes_sent": (mask.sum() * update_bytes
                           + ((active.astype(jnp.float32) - mask).sum()
                              * beacon_bytes)),
            "bytes_baseline": jnp.float32(C) * _update_bytes(state.params),
        }
        run = {"accepted": state.metrics["accepted"] + mask.sum(),
               "rounds": state.metrics["rounds"] + 1.0}
        return FLState(new_params, new_opt, new_ref, state.step + 1, run,
                       ctl, ws, topo), metrics

    return step


def build_fl_train_step(cfg, optimizer=None, theta: Optional[float] = 0.65,
                        lr_schedule=None, donate: bool = True,
                        beacon_bytes: float = 0.125,
                        control_plane: Optional[ControlPlane] = None,
                        scenario=None, drift_dirs=None,
                        label_key: str = "y", topology=None, comm=None,
                        num_clients: Optional[int] = None):
    """jit'd step(state, batch) -> (state, metrics)."""
    step = make_raw_step(cfg, optimizer, theta, lr_schedule,
                         beacon_bytes=beacon_bytes,
                         control_plane=control_plane,
                         scenario=scenario, drift_dirs=drift_dirs,
                         label_key=label_key, topology=topology,
                         comm=comm, num_clients=num_clients)
    if donate:
        return jax.jit(step, donate_argnums=(0,))
    return jax.jit(step)


def init_seed_batched_state(seeds, cfg, optimizer=None) -> FLState:
    """Stack per-seed ``init_state`` results along a leading seed axis.

    The returned ``FLState`` has every leaf shaped ``(S, ...)`` and is
    consumed by :func:`build_seed_batched_step` — S independent
    replicas, one compiled program (``run_sweep``'s vectorized
    multi-seed path). Control planes are not supported: their PRNG seed
    is compile-time static, so replicas would share draws.
    """
    states = [init_state(jax.random.PRNGKey(int(s)), cfg, optimizer)
              for s in seeds]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def build_seed_batched_step(cfg, optimizer=None,
                            theta: Optional[float] = 0.65,
                            lr_schedule=None, beacon_bytes: float = 0.125):
    """jit(vmap) of the raw FL step over a leading seed axis.

    ``step(batched_state, batch)`` with batch leaves ``(S, C, B, ...)``
    advances S independent FL runs in ONE dispatch; metrics come back
    seed-stacked (every leaf gains a leading S dim).
    """
    step = make_raw_step(cfg, optimizer, theta, lr_schedule,
                         beacon_bytes=beacon_bytes)
    return jax.jit(jax.vmap(step))


def _update_bytes(params) -> jnp.ndarray:
    n = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    return jnp.float32(n)


# ---------------------------------------------------------------------------
# serving / prefill steps (used by the dry-run for the inference shapes)
# ---------------------------------------------------------------------------

def build_prefill_step(cfg):
    def step(params, batch):
        return api.prefill(params, batch, cfg)
    return step


def build_serve_step(cfg):
    def step(params, cache, batch):
        return api.decode_step(params, cache, batch, cfg)
    return step
