"""Baseline FL strategies the paper compares against (Table II, Fig. 4).

All are expressed as ``StrategyConfig`` presets over the same simulation
engine, so comparisons isolate the STRATEGY (not incidental implementation
differences). Faithfulness notes:

  fedavg  — McMahan et al. [10]: synchronous, full participation, no
            filtering. The paper's "Sync (Baseline)".
  cmfl    — Luping et al. [5]: clients upload only updates RELEVANT to
            global convergence, measured by sign agreement with the
            previous global update — synchronous, same alignment test as
            ours but WITHOUT async/selection/dynamic-batch (so the delta
            vs "ours" is exactly the paper's claimed combination effect).
  acfl    — Yan et al. [11] CriticalFL: client selection favours clients
            with large early-training gradient norms ("critical learning
            periods"); synchronous, no filtering.
  fedl2p  — Lee et al. [4]: personalization — per-client learned LR
            scaling (simplified meta-rule), synchronous, no filtering.
  ours    — async + θ-filter + adaptive selection + dynamic batch +
            Weibull checkpointing (the paper's framework).
"""
from __future__ import annotations

from repro.core.async_engine import StrategyConfig


def fedavg(batch_size=64, lr=5e-3, local_epochs=1) -> StrategyConfig:
    return StrategyConfig(mode="sync", theta=None, selection=False,
                          dynamic_batch=False, checkpointing=False,
                          batch_size=batch_size, lr=lr,
                          local_epochs=local_epochs)


def cmfl(batch_size=64, lr=5e-3, theta=0.65, local_epochs=1) -> StrategyConfig:
    return StrategyConfig(mode="sync", theta=theta, selection=False,
                          dynamic_batch=False, checkpointing=False,
                          batch_size=batch_size, lr=lr,
                          local_epochs=local_epochs)


def acfl(batch_size=64, lr=5e-3, select_fraction=0.7,
         local_epochs=1) -> StrategyConfig:
    return StrategyConfig(mode="sync", theta=None, selection=True,
                          select_fraction=select_fraction,
                          grad_norm_selection=True, dynamic_batch=False,
                          checkpointing=False, batch_size=batch_size,
                          lr=lr, local_epochs=local_epochs)


def fedl2p(batch_size=64, lr=5e-3, local_epochs=1) -> StrategyConfig:
    return StrategyConfig(mode="sync", theta=None, selection=False,
                          dynamic_batch=False, checkpointing=False,
                          per_client_lr=True, batch_size=batch_size,
                          lr=lr, local_epochs=local_epochs)


def ours(batch_size=64, lr=5e-3, theta=0.65, local_epochs=1,
         dynamic_batch=True, select_fraction=1.0) -> StrategyConfig:
    return StrategyConfig(mode="async", theta=theta, selection=True,
                          select_fraction=select_fraction,
                          dynamic_batch=dynamic_batch, checkpointing=True,
                          batch_size=batch_size, lr=lr,
                          local_epochs=local_epochs)


PRESETS = {"fedavg": fedavg, "cmfl": cmfl, "acfl": acfl,
           "fedl2p": fedl2p, "ours": ours}
