"""DEPRECATED shim — the baseline strategies moved to ``repro.api.strategies``.

The five paper baselines (Table II, Fig. 4) are now first-class registry
entries; prefer::

    from repro.api import get_strategy
    strategy = get_strategy("ours").build(batch_size=128)

or, declaratively, ``ExperimentSpec(strategy="ours", ...)``. This module
re-exports the factory functions and ``PRESETS`` mapping unchanged so
existing imports keep working.
"""
from __future__ import annotations

from repro.api.strategies import (PRESETS, acfl, cmfl, fedavg, fedl2p,
                                  ours)

__all__ = ["PRESETS", "acfl", "cmfl", "fedavg", "fedl2p", "ours"]
