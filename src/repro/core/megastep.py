"""Compiled cohort megastep: one dispatch per simulated round.

The event-driven simulator used to train selected clients one at a time —
a separate jitted dispatch, host→device batch transfer, and per-leaf
``float(jnp.vdot(...))`` sync for EVERY client EVERY round: exactly the
per-tensor launch storm the paper profiles away (Tables V-VI). This
module collapses all of it into two compiled dispatches per round:

``build_cohort_step``  — stacks the cohort's fixed-shape batches into
    ``(C, steps, B, ...)`` and runs one jitted vmap-of-scan that returns,
    in a single call: per-client parameter deltas already packed into the
    flat ``(C, rows, LANE)`` arena, mean losses, sign-alignment ratios vs
    the reference direction, update L2 norms, and the updated batched
    error-feedback arena (int8 wire compression, when enabled). The only
    host transfer per round is the small (C,) metric vectors.

``build_apply_update`` — server aggregation as one weighted sum over the
    arena (Pallas ``masked_agg`` on TPU, jnp oracle on CPU): both sync
    FedAvg over the senders and FedBuff-style staleness-discounted async
    buffering are ``w_g ← w_anchor + Σ_i w_i·Δ_i`` for host-chosen
    weights, so one kernel serves both modes. Also returns the new
    reference sign (-2 padding sentinel) for the next round's θ filter.

Timing, selection, dropout and byte accounting stay event-driven in
Python, consuming these batched device results (core/async_engine.py).

``build_scanned_rounds`` goes one step further (the device-resident
control plane): selection, dynamic batch adaptation, dropout, timing and
staleness-weighted aggregation ALL run as pure-JAX state transitions
(core/control.py), so ``rounds_per_dispatch`` rounds execute inside ONE
jitted ``lax.scan`` — dispatches per simulated round drop from O(1)
toward O(1/R). Selection is a masked fixed-width cohort: a stable top-k
+ ε-greedy pick on device, with per-client arena slabs (error-feedback
buffers) fetched by a one-hot gather (Pallas kernel on TPU, jnp oracle
on CPU — kernels/gather.py via kernels/arena.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import aggregation, alignment, compression, control
from repro.kernels import arena as arena_ops
from repro.models import api


def _train_cohort(cfg, opt, arena, params, batches, lr_scale):
    """The cohort's local training as ONE vmap-of-scan — shared by the
    per-round cohort step and the scanned control plane so the two
    compiled paths can never drift apart. Returns the per-client deltas
    packed into the (C, rows, lane) arena and (C,) mean losses."""

    def train_one(client_batches, scale):
        opt_state = opt.init(params)

        def step(carry, batch):
            p, s = carry
            loss, grads = jax.value_and_grad(
                lambda q: api.loss_fn(q, batch, cfg))(p)
            grads = jax.tree.map(lambda g: g * scale, grads)
            p, s = opt.update(grads, s, p)
            return (p, s), loss

        (p, _), losses = jax.lax.scan(step, (params, opt_state),
                                      client_batches)
        return p, losses.mean()

    new_params, losses = jax.vmap(train_one)(batches, lr_scale)
    deltas = arena.pack_cohort(jax.tree.map(
        lambda n, o: (n - o).astype(jnp.float32), new_params, params))
    return deltas, losses


def build_cohort_step(cfg, opt, arena, theta=None, quantize: bool = False):
    """Returns jitted ``step(params_mat, batches, lr_scale, byz, ref_mat,
    ef, idx, *, has_ref) -> (deltas, losses, ratios, norms, new_ef)``.

    params_mat: (rows, lane) f32 arena of the round-start globals.
    batches:    pytree, leaves (C, steps, B, ...) — the stacked cohort.
    lr_scale:   (C,) per-client LR scaling (FedL2P personalization).
    byz:        (C,) per-client update multipliers (byzantine scenario
                clients: ±scale; None -> everyone honest), applied BEFORE
                wire compression and θ scoring — the server receives the
                corrupted update.
    ref_mat:    (rows, lane) int8 reference sign (None until it exists).
    ef, idx:    (N, rows, lane) EF arena + (C,) client ids (quantize only).
    has_ref:    static — round 0 has no reference direction; ratios are 1.
    """
    @functools.partial(jax.jit, static_argnames=("has_ref",))
    def cohort_step(params_mat, batches, lr_scale, byz, ref_mat, ef, idx, *,
                    has_ref):
        params = arena.unpack(params_mat)
        deltas, losses = _train_cohort(cfg, opt, arena, params, batches,
                                       lr_scale)
        if byz is not None:
            deltas = deltas * byz[:, None, None]
        new_ef = ef
        if quantize:
            restored, residual = compression.compress_cohort(
                deltas, jnp.take(ef, idx, axis=0))
            new_ef = ef.at[idx].set(residual)
            deltas = restored

        norms = jnp.sqrt(jnp.sum(deltas * deltas, axis=(1, 2)))
        if has_ref and theta is not None:
            ratios = alignment.cohort_alignment(deltas, ref_mat, arena.n)
        else:
            ratios = jnp.ones(deltas.shape[:1], jnp.float32)
        return deltas, losses, ratios, norms, new_ef

    return cohort_step


def build_apply_update(arena):
    """Returns jitted ``apply(params_mat, deltas_groups, weight_groups) ->
    (new_params_mat, new_ref_mat)``.

    ``deltas_groups`` / ``weight_groups`` are tuples (one entry per batch
    shape group this round — heterogeneous step counts quantize to a few
    power-of-two groups); weights are host-computed: mask/|S| for sync,
    α(τ)/N for async senders, 0 for filtered clients.
    """

    @jax.jit
    def apply_update(params_mat, deltas_groups, weight_groups):
        agg = None
        for d, w in zip(deltas_groups, weight_groups):
            part = arena_ops.weighted_sum(d, w)
            agg = part if agg is None else agg + part
        new_mat = params_mat + agg
        return new_mat, arena.sign_ref(new_mat, params_mat)

    return apply_update


# ---------------------------------------------------------------------------
# device-resident control plane: R rounds per dispatch (lax.scan)
# ---------------------------------------------------------------------------

def build_scanned_rounds(cfg, opt, arena, st, comm, *, num_clients: int,
                         select_k: int, steps_phys: int, batch_phys: int,
                         rounds_per_dispatch: int, param_bytes: float,
                         wire_bytes=None, epsilon: float = 0.1,
                         ema: float = 0.8, recovery_time: float = 0.2,
                         restart_time: float = 1.0, schedule=None,
                         scenario=None, drift_dirs=None,
                         drift_label: str = "y", candidate_frac=None,
                         candidate_shards: int = 8, topology=None,
                         eval_fn=None, eval_every: int = 1,
                         jit: bool = True, donate=None):
    """Compile ``rounds_per_dispatch`` full FL rounds — {select → train
    cohort → θ-filter → staleness-weighted arena aggregate → control
    update} — into one jitted ``lax.scan``.

    The entire server control plane lives in a ``ControlState`` of
    ``(N,)`` device arrays (core/control.py); selection produces a FIXED
    width-``select_k`` cohort (top-k + ε-greedy on device), so
    dropout-varying rounds reuse a single trace and the per-round launch
    + transfer overhead the paper profiles (Tables V-VI) is amortized
    over R rounds. Event accounting (arrival times, quorum clock,
    barrier idle, bytes) is computed with the same formulas the
    event-driven engine uses, as vector arithmetic inside the scan.

    Semantics vs the event-driven reference (documented deviations):
      * batch sampling / dropout / ε-exploration draw from a JAX PRNG
        (per-round ``fold_in`` keys, so trajectories are independent of
        the dispatch grouping R — ``rounds_per_dispatch=8`` is
        bit-identical to ``=1``), not the host numpy Generators;
      * every cohort client trains on the static (steps_phys,
        batch_phys) shape; ``dynamic_batch`` adapts the ControlState's
        power-of-two assignments and drives the simulated straggler
        timing exactly (the §IV-A effect), while gradient math keeps the
        fixed physical shape — the price of a single trace;
      * the Weibull checkpoint-interval refit (which never feeds back
        into the trajectory) is skipped; failures are counted per round.

    Returns ``run(params_mat, ref_mat, ref_valid, ctl, ws, topo, data,
    sizes, speed, latency, dropout_p, base_key, round0, acc) ->
    (carry, metrics)`` where ``metrics`` is a dict of ``(R,)`` per-round
    series and ``carry`` the updated ``(params_mat, ref_mat, ref_valid,
    ctl, ws, topo, acc)``. ``ws`` is the dynamic-world
    ``scenario.WorldState`` (the 0-width placeholder when no scenario is
    attached — it passes through untouched); its transitions fold keys
    from the absolute round index, so world trajectories are independent
    of the dispatch grouping R. ``topo`` is the hierarchical
    ``topology.TopologyState`` carry (None when ``topology`` — a
    ``TopologyRuntime`` — is not attached); its sync cadence is a closed
    form on the absolute round index, so it is likewise R-independent.
    ``acc`` is the (sim_time, comm_time, idle_time, bytes_sent) f32
    accumulator vector.

    Whole-experiment fusion (``eval_fn`` not None): evaluation joins the
    scan carry instead of breaking the dispatch stream. ``eval_fn`` must
    be a traceable ``(params_tree, eval_data) -> accuracy`` function; the
    carry gains a ``prev_acc`` f32 scalar (NaN before the first eval) and
    ``run`` three trailing arguments ``(prev_acc, eval_mark, eval_data)``
    — ``eval_mark`` is the absolute round index forced to evaluate (the
    engine's eval_final semantics; -1 disables) and ``eval_data`` the
    device-resident eval batch, passed explicitly (not closed over) so
    the whole ``run`` can be vmapped over a seed axis with per-seed eval
    arrays. Rounds where ``r % eval_every == 0`` (or ``r == eval_mark``)
    evaluate inside a ``lax.cond`` — the untaken branch costs nothing —
    and every round's metrics carry the latest accuracy (the loop
    engine's carry-forward semantics). Eval keys off the absolute round
    index, so fused accuracy is independent of the dispatch grouping R.

    ``jit=False`` returns the raw python callable (for a caller-side
    ``jax.jit(jax.vmap(run, ...))`` over seeds); ``donate`` controls
    buffer donation of the carry operands through the jitted path —
    default: donate whenever the platform honors donation (not CPU).
    """
    from repro.core import scenario as scenario_mod
    from repro.core.schedule import ScheduleSpec
    sched = schedule if schedule is not None else ScheduleSpec.from_strategy(st)
    scn = scenario if scenario_mod.is_active(scenario) else None
    dirs = (jnp.asarray(drift_dirs)
            if (scn is not None and scn.drift is not None) else None)
    N, K, R = int(num_clients), int(select_k), int(rounds_per_dispatch)
    E = int(eval_every)
    theta_on = st.theta is not None
    payload = float(wire_bytes if (st.quantize_updates and wire_bytes)
                    else param_bytes)
    beacon = float(comm.beacon_bytes)

    def round_body(carry, r, data, sizes, speed, latency, dropout_p,
                   base_key, eval_mark=None, eval_data=None):
        if eval_fn is not None:
            (params_mat, ref_mat, ref_valid, ctl, ws, topo, acc,
             prev_acc) = carry
        else:
            params_mat, ref_mat, ref_valid, ctl, ws, topo, acc = carry
        sim_t, comm_t, idle_t, bytes_s = acc
        key = jax.random.fold_in(base_key, r)
        k_eps, k_pick, k_drop, k_data = jax.random.split(key, 4)

        # --- dynamic world: this round's WorldState ---------------------
        if scn is not None:
            ws = scenario_mod.world_step(ws, r, scn, N)

        # --- selection: fixed-width top-k cohort ------------------------
        # (churned-out clients score -inf so they are only picked when
        # fewer than K clients are live; those slots carry zero weight)
        if st.grad_norm_selection:
            gn = (ctl.grad_norm if scn is None
                  else jnp.where(ws.live, ctl.grad_norm, -jnp.inf))
            cohort = jnp.argsort(-gn, stable=True)[:K]
        elif st.selection and K < N:
            scores = control.score(ctl)
            if scn is not None:
                scores = jnp.where(ws.live, scores, -jnp.inf)
            # two-stage: the sharded candidate pre-filter runs on the
            # live-masked scores (candidate_frac=None -> single-stage,
            # 1.0 -> all-True mask, bit-identical either way)
            cohort = control.two_stage_select(
                scores, K, candidate_frac=candidate_frac,
                candidate_shards=candidate_shards, epsilon=epsilon,
                eps_u=jax.random.uniform(k_eps, (K,)),
                pick_u=jax.random.uniform(k_pick, (K,)),
                live=None if scn is None else ws.live)
        else:
            cohort = jnp.arange(K)
        live_c = (jnp.ones((K,), bool) if scn is None else ws.live[cohort])
        # --- dropout draws (§IV-C fault model) --------------------------
        drop_p = dropout_p[cohort]
        if scn is not None and scn.dropout is not None:
            drop_p = drop_p * ws.dropout_scale
        failed = jax.random.uniform(k_drop, (K,)) < drop_p
        if scn is not None:
            failed = failed & live_c      # absent clients cannot fail
        if st.checkpointing:
            active = live_c
            delay = jnp.where(
                failed, jnp.where(ctl.has_ckpt[cohort],
                                  jnp.float32(recovery_time),
                                  jnp.float32(restart_time)), 0.0)
        else:
            active = ~failed & live_c
            delay = jnp.zeros((K,), jnp.float32)

        # --- cohort batches: on-device gather + index sampling ----------
        sz = sizes[cohort]
        idx = jax.random.randint(k_data, (K, steps_phys, batch_phys), 0,
                                 sz[:, None, None])
        batch = {name: leaf[cohort[:, None, None], idx]
                 for name, leaf in data.items()}
        if dirs is not None:
            batch = scenario_mod.apply_drift(batch, ws.drift_amp, dirs,
                                             drift_label)

        # --- local training: vmap-of-scan over the cohort ---------------
        params = arena.unpack(params_mat)
        lr_scale = (ctl.lr_scale[cohort] if st.per_client_lr
                    else jnp.ones((K,), jnp.float32))
        deltas, losses = _train_cohort(cfg, opt, arena, params, batch,
                                       lr_scale)
        if scn is not None and scn.byzantine is not None:
            # corruption BEFORE wire compression and θ scoring
            deltas = deltas * ws.byz_factor[cohort][:, None, None]
        new_ef = ctl.ef
        if st.quantize_updates:
            ef_cohort = arena_ops.cohort_gather(ctl.ef, cohort)
            restored, residual = compression.compress_cohort(
                deltas, ef_cohort)
            new_ef = ctl.ef.at[cohort].set(
                jnp.where(active[:, None, None], residual, ef_cohort))
            deltas = restored
        ctl = ctl._replace(ef=new_ef)

        norms = jnp.sqrt(jnp.sum(deltas * deltas, axis=(1, 2)))
        if theta_on:
            ratios = alignment.cohort_alignment(deltas, ref_mat, arena.n)
            passed = jnp.where(ref_valid, ratios >= st.theta, True)
        else:
            passed = jnp.ones((K,), bool)
        sent = active & passed

        # --- event accounting (the engine's timing model, vectorized) ---
        b_eff = jnp.minimum(
            (ctl.batch[cohort] if st.dynamic_batch else batch_phys), sz)
        steps_t = control.local_steps(sz, b_eff, st.local_epochs,
                                      st.max_samples_per_round)
        b_eff = b_eff.astype(jnp.float32)
        steps_f = steps_t.astype(jnp.float32)
        train_t = ((steps_f * comm.t_launch
                    + steps_f * b_eff * comm.t_sample)
                   / jnp.maximum(speed[cohort], 1e-3))
        msg_bytes = jnp.where(sent, payload, beacon)
        if scn is not None and scn.links is not None:
            # link-quality walk re-prices this round's transfer
            transfer = (latency[cohort] * ws.lat_scale[cohort]
                        + msg_bytes / (comm.bandwidth
                                       * ws.bw_scale[cohort]))
        else:
            transfer = latency[cohort] + msg_bytes / comm.bandwidth
        arrive = delay + train_t + transfer          # rel. to round start
        n_active = active.sum().astype(jnp.int32)
        n_sent = sent.sum().astype(jnp.int32)
        comm_t = comm_t + jnp.sum(jnp.where(active, transfer, 0.0))
        bytes_s = bytes_s + jnp.sum(jnp.where(active, msg_bytes, 0.0))

        # --- aggregation weights: sync barrier / async quorum -----------
        if sched.is_sync:
            barrier = jnp.max(jnp.where(active, arrive, -jnp.inf))
            sim_t = jnp.where(n_active > 0, sim_t + barrier, sim_t)
            idle_t = idle_t + jnp.sum(
                jnp.where(active, barrier - arrive, 0.0))
            w = sent.astype(jnp.float32) \
                / jnp.maximum(n_sent.astype(jnp.float32), 1.0)
            updates_applied = n_sent
        else:
            t_act = jnp.where(active, arrive, jnp.inf)
            q_idx = jnp.maximum(
                0, jnp.ceil(sched.quorum * n_active.astype(jnp.float32))
                .astype(jnp.int32) - 1)
            sim_t = jnp.where(n_active > 0,
                              sim_t + jnp.sort(t_act)[q_idx], sim_t)
            rank = jnp.argsort(jnp.argsort(t_act, stable=True),
                               stable=True)
            tau = jnp.maximum(0, rank - q_idx)
            alphas = aggregation.staleness_weight(tau, sched.alpha0)
            applied_mask = sent
            if sched.max_staleness is not None:
                # semi-async: bounded staleness — arrivals beyond the
                # cutoff transmitted (bytes already charged) but dropped
                applied_mask = sent & (tau <= sched.max_staleness)
            n_applied = applied_mask.sum().astype(jnp.int32)
            w = jnp.where(applied_mask, alphas, 0.0) \
                / jnp.maximum(n_applied.astype(jnp.float32), 1.0)
            updates_applied = n_applied

        # --- one weighted arena sum applies the round ------------------
        new_mat = params_mat + arena_ops.weighted_sum(deltas, w)
        applied = updates_applied > 0
        if theta_on:
            sref = arena.sign_ref(new_mat, params_mat)
            ref_mat = jnp.where(applied, sref, ref_mat)
            ref_valid = ref_valid | applied
        params_mat = new_mat

        # --- hierarchical topology: leaf accumulation + due syncs -------
        if topology is not None:
            topo = topology.step(topo, r, deltas, w,
                                 topology.pod_of[cohort])

        # --- control-plane transitions (core/control.py) ----------------
        ctl = control.observe_round(ctl, cohort, failed=failed,
                                    active=active, passed=sent,
                                    round_time=arrive, ema=ema)
        ctl = control.grad_norm_update(ctl, cohort, norms, active)
        if st.per_client_lr:
            ctl = control.lr_scale_update(ctl, cohort, norms, active)
        if st.dynamic_batch:
            ctl = control.batch_feedback(ctl, cohort, arrive, active)
        if st.checkpointing:
            ctl = control.checkpoint_update(ctl, cohort, active)
        ctl = control.staleness_update(ctl, cohort, sent)

        loss_mean = (jnp.sum(jnp.where(active, losses, 0.0))
                     / jnp.maximum(n_active.astype(jnp.float32), 1.0))
        # under churn the paper's acceptance-rate denominator is the
        # participating cohort (the host engines' len(selected)), not
        # the static cohort width
        denom = (jnp.float32(K) if scn is None or scn.churn is None
                 else jnp.maximum(live_c.sum().astype(jnp.float32), 1.0))
        metrics = {
            "sim_time": sim_t, "comm_time": comm_t, "idle_time": idle_t,
            "bytes_sent": bytes_s,
            "updates_applied": updates_applied,
            "accept_rate": (n_sent.astype(jnp.float32) / denom),
            "loss": loss_mean,
            "n_failures": failed.sum().astype(jnp.int32),
        }
        acc = jnp.stack([sim_t, comm_t, idle_t, bytes_s])

        # --- fused eval: accuracy joins the scan carry ------------------
        if eval_fn is not None:
            do = (r % E == 0) | (r == eval_mark)
            prev_acc = jax.lax.cond(
                do,
                lambda m: jnp.asarray(
                    eval_fn(arena.unpack(m), eval_data), jnp.float32),
                lambda m: prev_acc,
                params_mat)
            metrics["accuracy"] = prev_acc
            return (params_mat, ref_mat, ref_valid, ctl, ws, topo, acc,
                    prev_acc), metrics
        return (params_mat, ref_mat, ref_valid, ctl, ws, topo, acc), metrics

    if eval_fn is None:
        def run_impl(params_mat, ref_mat, ref_valid, ctl, ws, topo, data,
                     sizes, speed, latency, dropout_p, base_key, round0,
                     acc):
            body = functools.partial(round_body, data=data, sizes=sizes,
                                     speed=speed, latency=latency,
                                     dropout_p=dropout_p, base_key=base_key)
            rounds = round0 + jnp.arange(R, dtype=jnp.int32)
            carry0 = (params_mat, ref_mat, ref_valid, ctl, ws, topo, acc)
            return jax.lax.scan(lambda c, r: body(c, r), carry0, rounds)
    else:
        def run_impl(params_mat, ref_mat, ref_valid, ctl, ws, topo, data,
                     sizes, speed, latency, dropout_p, base_key, round0,
                     acc, prev_acc, eval_mark, eval_data):
            body = functools.partial(round_body, data=data, sizes=sizes,
                                     speed=speed, latency=latency,
                                     dropout_p=dropout_p, base_key=base_key,
                                     eval_mark=eval_mark,
                                     eval_data=eval_data)
            rounds = round0 + jnp.arange(R, dtype=jnp.int32)
            carry0 = (params_mat, ref_mat, ref_valid, ctl, ws, topo, acc,
                      prev_acc)
            return jax.lax.scan(lambda c, r: body(c, r), carry0, rounds)

    if not jit:
        return run_impl
    return jax.jit(run_impl, donate_argnums=scan_donate_argnums(
        fused=eval_fn is not None, donate=donate))


def scan_donate_argnums(*, fused: bool, donate=None):
    """Donation set for the scanned ``run``: the carry operands
    (arena, reference sign, control state, world/topology state, the
    accounting accumulator — plus ``prev_acc`` when eval is fused) are
    consumed and rebound from the scan output by every caller, so their
    input buffers can be reused in place. The read-only population
    stacks (data/sizes/speed/latency/dropout_p) and the PRNG key are
    never donated. CPU ignores donation with a warning, so the default
    donates only where the platform honors it.
    """
    if donate is None:
        donate = jax.default_backend() != "cpu"
    if not donate:
        return ()
    nums = (0, 1, 2, 3, 4, 5, 13)          # carry operands
    return nums + ((14,) if fused else ())  # + prev_acc
