"""Compiled cohort megastep: one dispatch per simulated round.

The event-driven simulator used to train selected clients one at a time —
a separate jitted dispatch, host→device batch transfer, and per-leaf
``float(jnp.vdot(...))`` sync for EVERY client EVERY round: exactly the
per-tensor launch storm the paper profiles away (Tables V-VI). This
module collapses all of it into two compiled dispatches per round:

``build_cohort_step``  — stacks the cohort's fixed-shape batches into
    ``(C, steps, B, ...)`` and runs one jitted vmap-of-scan that returns,
    in a single call: per-client parameter deltas already packed into the
    flat ``(C, rows, LANE)`` arena, mean losses, sign-alignment ratios vs
    the reference direction, update L2 norms, and the updated batched
    error-feedback arena (int8 wire compression, when enabled). The only
    host transfer per round is the small (C,) metric vectors.

``build_apply_update`` — server aggregation as one weighted sum over the
    arena (Pallas ``masked_agg`` on TPU, jnp oracle on CPU): both sync
    FedAvg over the senders and FedBuff-style staleness-discounted async
    buffering are ``w_g ← w_anchor + Σ_i w_i·Δ_i`` for host-chosen
    weights, so one kernel serves both modes. Also returns the new
    reference sign (-2 padding sentinel) for the next round's θ filter.

Timing, selection, dropout and byte accounting stay event-driven in
Python, consuming these batched device results (core/async_engine.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import alignment, compression
from repro.kernels import arena as arena_ops
from repro.models import api


def build_cohort_step(cfg, opt, arena, theta=None, quantize: bool = False):
    """Returns jitted ``step(params_mat, batches, lr_scale, ref_mat, ef,
    idx, *, has_ref) -> (deltas, losses, ratios, norms, new_ef)``.

    params_mat: (rows, lane) f32 arena of the round-start globals.
    batches:    pytree, leaves (C, steps, B, ...) — the stacked cohort.
    lr_scale:   (C,) per-client LR scaling (FedL2P personalization).
    ref_mat:    (rows, lane) int8 reference sign (None until it exists).
    ef, idx:    (N, rows, lane) EF arena + (C,) client ids (quantize only).
    has_ref:    static — round 0 has no reference direction; ratios are 1.
    """
    @functools.partial(jax.jit, static_argnames=("has_ref",))
    def cohort_step(params_mat, batches, lr_scale, ref_mat, ef, idx, *,
                    has_ref):
        params = arena.unpack(params_mat)

        def train_one(client_batches, scale):
            opt_state = opt.init(params)

            def step(carry, batch):
                p, s = carry
                loss, grads = jax.value_and_grad(
                    lambda q: api.loss_fn(q, batch, cfg))(p)
                grads = jax.tree.map(lambda g: g * scale, grads)
                p, s = opt.update(grads, s, p)
                return (p, s), loss

            (p, _), losses = jax.lax.scan(step, (params, opt_state),
                                          client_batches)
            return p, losses.mean()

        new_params, losses = jax.vmap(train_one)(batches, lr_scale)
        deltas = arena.pack_cohort(jax.tree.map(
            lambda n, o: (n - o).astype(jnp.float32), new_params, params))

        new_ef = ef
        if quantize:
            restored, residual = compression.compress_cohort(
                deltas, jnp.take(ef, idx, axis=0))
            new_ef = ef.at[idx].set(residual)
            deltas = restored

        norms = jnp.sqrt(jnp.sum(deltas * deltas, axis=(1, 2)))
        if has_ref and theta is not None:
            ratios = alignment.cohort_alignment(deltas, ref_mat, arena.n)
        else:
            ratios = jnp.ones(deltas.shape[:1], jnp.float32)
        return deltas, losses, ratios, norms, new_ef

    return cohort_step


def build_apply_update(arena):
    """Returns jitted ``apply(params_mat, deltas_groups, weight_groups) ->
    (new_params_mat, new_ref_mat)``.

    ``deltas_groups`` / ``weight_groups`` are tuples (one entry per batch
    shape group this round — heterogeneous step counts quantize to a few
    power-of-two groups); weights are host-computed: mask/|S| for sync,
    α(τ)/N for async senders, 0 for filtered clients.
    """

    @jax.jit
    def apply_update(params_mat, deltas_groups, weight_groups):
        agg = None
        for d, w in zip(deltas_groups, weight_groups):
            part = arena_ops.weighted_sum(d, w)
            agg = part if agg is None else agg + part
        new_mat = params_mat + agg
        return new_mat, arena.sign_ref(new_mat, params_mat)

    return apply_update
