"""Serving driver: batched anomaly scoring through the ``repro.serve``
engine (the paper's detector), or a batched prefill + decode loop for
the LM-family architectures.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch anomaly-mlp \
      --batch 256 --requests 2048
  PYTHONPATH=src python -m repro.launch.serve --arch anomaly-mlp \
      --from-checkpoint run.ckpt
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --prompt-len 32 --decode-steps 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import api


def serve_lm(cfg, batch: int, prompt_len: int, decode_steps: int, seed=0):
    rng = np.random.default_rng(seed)
    params = api.init_params(jax.random.PRNGKey(seed), cfg)
    toks = prompt_len - (cfg.num_patches if cfg.family == "vlm" else 0)
    prompt = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(batch, toks)))}
    if cfg.family == "vlm":
        prompt["patch_embeds"] = jnp.zeros(
            (batch, cfg.num_patches, cfg.d_model), cfg.compute_dtype)
    if cfg.family == "audio":
        prompt["enc_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_seq, cfg.d_model)),
            cfg.compute_dtype)

    t0 = time.time()
    prefill = jax.jit(lambda p, b: api.prefill(p, b, cfg))
    logits, cache = prefill(params, prompt)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    # pad the cache to prompt_len + decode_steps for the decode loop
    total = prompt_len + decode_steps
    full = api.init_cache(cfg, batch, total)
    cache = jax.tree.map(
        lambda dst, src: jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), (0,) * dst.ndim)
        if dst.ndim == src.ndim and dst.shape != src.shape else src,
        full, cache)
    cache["step"] = jnp.asarray(prompt_len, jnp.int32)

    decode = jax.jit(lambda p, c, b: api.decode_step(p, c, b, cfg))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(decode_steps):
        logits, cache = decode(params, cache, {"tokens": tok})
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0
    print(f"prefill: {batch}x{prompt_len} in {t_prefill:.2f}s; "
          f"decode: {decode_steps} steps in {t_decode:.2f}s "
          f"({batch*decode_steps/max(t_decode,1e-9):.1f} tok/s)")
    return jnp.concatenate(out, axis=1)


def serve_anomaly(cfg, batch: int, seed=0, requests: int = 0,
                  checkpoint: str = None, queue_limit: int = None,
                  deadline_ms: float = None):
    """Batched flow scoring via ``repro.serve.ServeEngine`` — request
    queue, power-of-two batch buckets, hot-swappable model slot,
    p50/p99 latency accounting. ``checkpoint`` serves a trained global
    model from an ``ExperimentSession.checkpoint()`` artifact (sidecar-
    validated); otherwise parameters initialize fresh. ``queue_limit``
    and ``deadline_ms`` turn on the engine's admission control; shed /
    expired requests show up in the health line."""
    from repro.data import synthetic
    from repro.serve import ModelSlot, ServeEngine, health_snapshot

    max_batch = 1 << max(0, int(batch) - 1).bit_length()   # next pow2
    if checkpoint:
        slot = ModelSlot(api.init_params(jax.random.PRNGKey(seed), cfg),
                         model=cfg.name)
        slot.publish_checkpoint(checkpoint)
    else:
        slot = ModelSlot(api.init_params(jax.random.PRNGKey(seed), cfg),
                         model=cfg.name)
    engine = ServeEngine(slot, cfg, max_batch=max_batch,
                         queue_limit=queue_limit, deadline_ms=deadline_ms)
    n = requests or max_batch * 4
    X, _y = synthetic.make_unsw_like(seed, n, cfg.num_features,
                                     cfg.num_classes)
    responses = []
    for i in range(0, n, max_batch):
        engine.submit_many(X[i:i + max_batch], best_effort=True)
        responses.extend(engine.pump())
    health = health_snapshot(engine)
    stats = engine.shutdown()
    anomaly_rate = float(np.mean(
        [np.argmax(r.probs) != 0 for r in responses])) if responses else 0.0
    version = responses[-1].model_version if responses else 0
    print(f"scored {stats.served} flows in {stats.busy_seconds*1e3:.1f} ms "
          f"({stats.flows_per_sec:.0f} flows/s, p50 {stats.p50_ms:.2f} ms, "
          f"p99 {stats.p99_ms:.2f} ms, model v{version}); "
          f"flagged {anomaly_rate:.1%} as attack classes")
    print(f"health: {health.status} (shed {health.shed}, "
          f"deadline_miss {health.deadline_miss}, "
          f"dispatch_errors {health.dispatch_errors}, "
          f"degraded_mode {health.degraded_mode})")
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="anomaly-mlp",
                    choices=registry.list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--requests", type=int, default=0,
                    help="anomaly serving: total flows to score "
                         "(default 4 batches)")
    ap.add_argument("--from-checkpoint", default=None, metavar="PATH",
                    help="anomaly serving: hot-load the global model "
                         "from an ExperimentSession checkpoint "
                         "(validated against its sidecar metadata)")
    ap.add_argument("--queue-limit", type=int, default=None,
                    help="anomaly serving: bound the request queue; "
                         "overflow is shed at admission")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="anomaly serving: per-request deadline; expired "
                         "requests answer NaN and count deadline_miss")
    args = ap.parse_args(argv)
    cfg = registry.get_config(args.arch, smoke=args.smoke)
    if cfg.family == "mlp":
        serve_anomaly(cfg, args.batch, requests=args.requests,
                      checkpoint=args.from_checkpoint,
                      queue_limit=args.queue_limit,
                      deadline_ms=args.deadline_ms)
    else:
        serve_lm(cfg, args.batch, args.prompt_len, args.decode_steps)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
