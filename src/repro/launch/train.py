"""Production federated trainer driver.

Runs the mesh-mapped FL train step (per-client grads + masked selective
aggregation) on synthetic data. On this CPU container use --smoke configs;
on a real TPU slice the same entry point runs the production mesh.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 20 --clients 4
  PYTHONPATH=src python -m repro.launch.train --arch anomaly-mlp \
      --steps 50 --clients 8 --theta 0.65
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.core import fl_step
from repro.data import synthetic
from repro.optim import adamw as optim_mod
from repro.optim import schedule


def make_batch_fn(cfg, clients: int, per_client: int, seq: int, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.family == "mlp":
        X, y = synthetic.make_unsw_like(seed, 8192, cfg.num_features,
                                        cfg.num_classes)

        def nxt():
            idx = rng.integers(0, len(X), size=(clients, per_client))
            return {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}
        return nxt

    toks = seq - (cfg.num_patches if cfg.family == "vlm" else 0)

    def nxt():
        t, l = synthetic.make_lm_tokens(int(rng.integers(1 << 30)),
                                        clients * per_client, toks,
                                        cfg.vocab_size)
        batch = {
            "tokens": jnp.asarray(t.reshape(clients, per_client, toks)),
            "labels": jnp.asarray(l.reshape(clients, per_client, toks)),
        }
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (clients, per_client, cfg.num_patches, cfg.d_model),
                cfg.compute_dtype)
        if cfg.family == "audio":
            batch["enc_embeds"] = jnp.asarray(rng.normal(size=(
                clients, per_client, cfg.encoder_seq, cfg.d_model)),
                cfg.compute_dtype)
        return batch
    return nxt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="anomaly-mlp",
                    choices=registry.list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--per-client-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--theta", type=float, default=0.65)
    ap.add_argument("--no-filter", action="store_true",
                    help="synchronous FedAvg baseline")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = registry.get_config(args.arch, smoke=args.smoke)
    optimizer = optim_mod.for_config(cfg, lr=args.lr)
    sched = schedule.cosine(args.lr, warmup_steps=5, total_steps=args.steps)
    theta = None if args.no_filter else args.theta

    state = fl_step.init_state(jax.random.PRNGKey(0), cfg, optimizer)
    step = fl_step.build_fl_train_step(cfg, optimizer, theta=theta,
                                       lr_schedule=sched)
    next_batch = make_batch_fn(cfg, args.clients, args.per_client_batch,
                               args.seq)
    ckpt = CheckpointManager(args.ckpt_dir, total_time=600.0)

    t0 = time.time()
    for i in range(args.steps):
        state, metrics = step(state, next_batch())
        if i % args.log_every == 0 or i == args.steps - 1:
            # per-client leaves (e.g. the (C,) transmit mask) aren't scalars
            m = {k: float(v) for k, v in metrics.items() if v.ndim == 0}
            print(f"step {i:4d} loss={m['loss']:.4f} "
                  f"accept={m['accept_rate']:.2f} "
                  f"align={m['alignment_mean']:.3f} "
                  f"sent={m['bytes_sent']/1e6:.2f}MB "
                  f"(baseline {m['bytes_baseline']/1e6:.2f}MB) "
                  f"[{time.time()-t0:.1f}s]")
        ckpt.maybe_save(state.params, now=time.time() - t0)
    print(f"done: {args.steps} rounds in {time.time()-t0:.1f}s; "
          f"checkpoints={ckpt.saves}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
