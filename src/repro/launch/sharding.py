"""PartitionSpec rules for every parameter / batch / cache pytree.

Strategy (DESIGN.md §4):
  * weights: tensor-parallel over "model" on their widest eligible dim,
    replicated over client axes ("pod","data") — every FL client needs
    full weights;
  * MoE expert tensors with cfg.expert_parallel: expert dim over "data"
    (expert parallelism) + ff dim over "model";
  * optimizer state mirrors its parameter's spec (adafactor's factored
    row/col vectors drop the corresponding spec entry);
  * training batch: leading client dim over cfg.client_axes; per-client
    batch dim over "data" when "data" is not a client axis (arctic);
  * decode caches: batch over "data" (when divisible), sequence/window
    over "model" (KV heads are often < 16, so head-sharding would split
    head_dim — sequence sharding is the uniform, always-divisible rule);
    SSM states shard heads/channels over "model".

Dims are only sharded when evenly divisible by the mesh axis size —
``_maybe`` falls back to replication otherwise (e.g. vocab 32001).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import api

_STACK_KEYS = {"layers", "enc_layers", "dec_layers"}


def _axis_size(mesh, name):
    return mesh.shape[name] if name in mesh.axis_names else 1


def _maybe(mesh, axis, dim):
    """axis name if dim divides evenly, else None (replicated)."""
    n = _axis_size(mesh, axis)
    return axis if (n > 1 and dim % n == 0) else None


def _path_names(path):
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
    return names


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

# column-parallel (shard LAST dim over model): input projections
_COL = {"wq", "wk", "wv", "wg", "wu", "w1", "Wr", "Wk", "Wv", "Wg", "Win",
        "Wdt2", "conv_w", "lm_head", "patch_proj"}
# row-parallel (shard SECOND-TO-LAST dim over model): output projections
_ROW = {"wo", "wd", "w2", "Wo", "Wout", "Wdt1", "WB", "WC", "A_log"}
# last-dim sharded vectors
_VEC = {"bq", "bk", "bv", "b1", "dt_bias", "D", "conv_b"}
# always replicated (norms, scalar-ish, small loras, router)
_REP = {"w", "b", "mus", "mu_base", "mu_k", "mu_r", "w0", "u", "gn_w",
        "gn_b", "W1", "W2", "dw1", "dw2", "router", "b2", "count", "scale",
        "good_steps", "step"}


def _param_rule(cfg, names, shape, mesh, mode="train"):
    name = names[-1] if names else ""
    stacked = any(n in _STACK_KEYS for n in names)
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape
    nd = len(body)

    def spec(*entries):
        return P(*(lead + tuple(entries)))

    # --- MoE expert tensors: (E, d, ff) / (E, ff, d) -----------------------
    if "moe" in names and name in {"wg", "wu", "wd"} and nd == 3:
        if not cfg.expert_parallel and mode == "train":
            # §Perf iteration D2: REPLICATE small expert banks for TRAINING.
            # TP-sharding the ff dim makes GSPMD replicate the client dim
            # around the backward contraction psum (~1 TB/device on
            # granite-moe); replication cuts the train-step all-reduce 32x.
            # Serving re-shards (mode="serve" keeps ff-sharded TP, which
            # measured 3x better on prefill where there is no backward).
            return spec(None, None, None)
        e_axis = (_maybe(mesh, "data", body[0])
                  if cfg.expert_parallel else None)
        if name in {"wg", "wu"}:
            return spec(e_axis, None, _maybe(mesh, "model", body[2]))
        return spec(e_axis, _maybe(mesh, "model", body[1]), None)

    if name == "embed":
        # NEVER vocab-shard the embedding table: the token lookup is a
        # batched gather, and GSPMD rewrites gathers over a sharded dim as
        # one-hot matmuls (+3x compute measured on granite-moe). d-sharding
        # keeps the lookup local. (§Perf iteration D, refinement)
        v, d = body
        return spec(None, _maybe(mesh, "model", d))
    if name == "lm_head":
        # vocab-shard the head: a plain matmul — no gather — so vocab
        # sharding here is pure win (kills the (B,S,V) fp32 logits
        # all-reduce); the xent consumes sharded-V logits via one-hot
        # contraction (layers.softmax_xent).
        d, v = body
        if _maybe(mesh, "model", v):
            return spec(None, "model")
        return spec(_maybe(mesh, "model", d), None)
    if name in _REP:
        return spec(*([None] * nd))
    if name in _COL and nd >= 2:
        return spec(*([None] * (nd - 1) + [_maybe(mesh, "model", body[-1])]))
    if name in _ROW and nd >= 2:
        return spec(*([None] * (nd - 2)
                      + [_maybe(mesh, "model", body[-2]), None]))
    if name in _VEC and nd == 1:
        return spec(_maybe(mesh, "model", body[-1]))
    # mlp detector leaves (w0,b0,...) and anything unknown: replicate
    return spec(*([None] * nd))


def param_pspecs(cfg, mesh, mode: str = "train"):
    """Pytree of PartitionSpec matching api.init_params(cfg) structure.
    mode: "train" | "serve" — non-EP MoE expert banks are replicated for
    training but TP-sharded for serving (see _param_rule)."""
    shapes = jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0), cfg))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_rule(cfg, _path_names(path), leaf.shape,
                                       mesh, mode),
        shapes)


def state_pspecs(cfg, mesh, optimizer):
    """FLState spec: params/opt/ref_sign sharded, counters replicated.

    The optimizer state is mapped STRUCTURALLY: adamw's m/v/master and
    sgd's mom mirror the param tree exactly; adafactor's factored stats
    drop the corresponding spec entry (row stat: last dim; col stat:
    second-to-last dim)."""
    from repro.core import fl_step
    pspecs = param_pspecs(cfg, mesh)
    pshapes = jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0), cfg))
    oshapes = jax.eval_shape(optimizer.init, pshapes)

    def factored_stat_spec(spec, sds, stat):
        entries = tuple(spec)
        if "r" in stat:   # factored: r drops last dim, c drops dim -2
            return {"r": P(*entries[:-1]),
                    "c": P(*(entries[:-2] + entries[-1:]))}
        return {"v": spec}

    ospecs = {}
    for key, sub in oshapes.items():
        if key == "count":
            ospecs[key] = P()
        elif key == "stats":   # adafactor
            ospecs[key] = jax.tree.map(
                factored_stat_spec, pspecs, pshapes, sub,
                is_leaf=lambda x: isinstance(x, P))
        else:                  # m / v / master / mom mirror params
            ospecs[key] = pspecs
    metrics_spec = {"accepted": P(), "rounds": P()}
    return fl_step.FLState(pspecs, ospecs, pspecs, P(), metrics_spec)


# --------------------------------------------------------------------------
# batch / cache rules
# --------------------------------------------------------------------------

def train_batch_pspecs(cfg, mesh, batch_shapes):
    """Leading dim = clients over cfg.client_axes; dim1 over spare axis."""
    client_axes = tuple(a for a in cfg.client_axes if a in mesh.axis_names)
    lead = client_axes if client_axes else None
    spare = "data" if "data" not in (client_axes or ()) else None

    def rule(path, leaf):
        nd = leaf.ndim
        entries = [lead] + [None] * (nd - 1)
        if spare and nd >= 2 and leaf.shape[1] % _axis_size(mesh, spare) == 0:
            entries[1] = spare
        return P(*entries)

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def _batch_axes(mesh, dim):
    """Largest prefix of ('pod','data') that divides ``dim`` (§Perf
    iteration F: leaving the pod axis idle on decode shapes made GSPMD
    replicate-and-reduce the whole cache across pods)."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if n > 1 and dim % n == 0:
        return tuple(axes) if len(axes) > 1 else axes[0]
    return _maybe(mesh, "data", dim)


def infer_batch_pspecs(mesh, batch_shapes):
    """Prefill/decode token batches: batch dim over ('pod','data')."""
    def rule(path, leaf):
        if leaf.ndim == 0:
            return P()
        b = _batch_axes(mesh, leaf.shape[0])
        return P(*([b] + [None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def cache_pspecs(cfg, mesh, cache_shapes):
    """Decode caches: (L, B, S, ...) KV -> batch over data, seq over model;
    SSM states -> heads/channels over model."""
    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        if name == "step" or leaf.ndim <= 1:
            return P()
        if name in {"k", "v", "xk", "xv"}:      # (L, B, S, K, hd)
            _, b, s = leaf.shape[:3]
            return P(None, _batch_axes(mesh, b),
                     _maybe(mesh, "model", s), None, None)
        if name == "S":                          # rwkv (L, B, H, hd, hd)
            _, b, h = leaf.shape[:3]
            return P(None, _batch_axes(mesh, b),
                     _maybe(mesh, "model", h), None, None)
        if name in {"tshift", "cshift"}:         # (L, B, d)
            _, b, d = leaf.shape
            return P(None, _batch_axes(mesh, b), _maybe(mesh, "model", d))
        if name == "h":                          # hybrid (L, B, di, n)
            _, b, di, _n = leaf.shape
            return P(None, _batch_axes(mesh, b),
                     _maybe(mesh, "model", di), None)
        if name == "conv":                       # (L, B, taps, di)
            _, b, _t, di = leaf.shape
            return P(None, _batch_axes(mesh, b), None,
                     _maybe(mesh, "model", di))
        return P(*([None] * leaf.ndim))
    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


# --------------------------------------------------------------------------
# population-plane rules (ControlState / WorldState / per-client scalars)
# --------------------------------------------------------------------------

def population_pspecs(tree, mesh, num_clients: int):
    """Shard every ``(num_clients, ...)``-leading leaf over "data".

    Covers ``core.control.ControlState``, ``core.scenario.WorldState``
    and any bare per-client scalar array (pass-rate EMAs, staleness
    counters, FedDyn-style slots). Leaves whose leading dim is NOT the
    population — scalars, (K,)-cohort slots, the ``(N+1, rows, lane)``
    error-feedback arena with its dummy-row layout, 0-width placeholders
    — replicate. Falls back to replication when the population does not
    divide the "data" axis evenly (``_maybe``)."""
    n = int(num_clients)

    def rule(leaf):
        shape = getattr(leaf, "shape", ())
        if len(shape) >= 1 and shape[0] == n and _maybe(mesh, "data", n):
            return P(*(("data",) + (None,) * (len(shape) - 1)))
        return P(*((None,) * len(shape)))

    return jax.tree.map(rule, tree)


def shard_population(tree, mesh, num_clients: int):
    """device_put the population pytree under ``population_pspecs``."""
    specs = population_pspecs(tree, mesh, num_clients)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, specs)


def to_named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
