"""Production mesh construction (deliverable e, MULTI-POD DRY-RUN §1).

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state. The dry-run launcher sets
``--xla_force_host_platform_device_count=512`` BEFORE importing jax;
smoke tests and benchmarks see the single real CPU device.

Hardware model (TPU v5e, used by the roofline analysis):
  197 TFLOP/s bf16 per chip · 819 GB/s HBM · ~50 GB/s/link ICI.
"""
from __future__ import annotations

import numpy as np

import jax

PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link


def _make_mesh(shape, axes, devices=None):
    """jax.make_mesh across jax versions: AxisType/axis_types only exist
    from jax 0.5; on 0.4.x every axis is Auto already."""
    kwargs = {}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axes)
    if devices is not None:
        kwargs["devices"] = devices
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return _make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            f"launch via repro.launch.dryrun (forces 512 host devices)")
    # more devices than needed (e.g. 512 forced, single-pod 256): subset
    return _make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Single-device mesh for smoke tests of the sharded code path."""
    return _make_mesh(shape, axes, devices=jax.devices()[:1])


def client_axes_in_mesh(cfg, mesh) -> tuple:
    """The subset of cfg.client_axes present in this mesh."""
    return tuple(a for a in cfg.client_axes if a in mesh.axis_names)


def num_clients(cfg, mesh) -> int:
    axes = client_axes_in_mesh(cfg, mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return max(n, 1)
