"""Production mesh construction (deliverable e, MULTI-POD DRY-RUN §1).

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state. The dry-run launcher sets
``--xla_force_host_platform_device_count=512`` BEFORE importing jax;
smoke tests and benchmarks see the single real CPU device.

Hardware model (TPU v5e, used by the roofline analysis):
  197 TFLOP/s bf16 per chip · 819 GB/s HBM · ~50 GB/s/link ICI.
"""
from __future__ import annotations

import numpy as np

import jax

PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link


def _make_mesh(shape, axes, devices=None):
    """jax.make_mesh across jax versions: AxisType/axis_types only exist
    from jax 0.5; on 0.4.x every axis is Auto already."""
    kwargs = {}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axes)
    if devices is not None:
        kwargs["devices"] = devices
    return jax.make_mesh(shape, axes, **kwargs)


def fold_mesh_shape(n: int, *, multi_pod: bool = False) -> tuple:
    """Fold ``n`` devices into the largest valid mesh shape.

    "model" takes the largest power-of-two divisor of ``n`` up to the
    canonical 16 (tensor parallelism wants a power of two; anything
    wider than 16 splits head dims); "data" absorbs the rest. multi_pod
    peels a leading pod=2, so it needs an even device count.
    """
    n = int(n)
    if n < 1:
        raise RuntimeError(f"cannot build a mesh from {n} devices")
    shape = ()
    if multi_pod:
        if n % 2:
            raise RuntimeError(
                f"multi_pod mesh needs an even device count, have {n} "
                f"devices — drop multi_pod or launch via "
                f"repro.launch.dryrun (forces 512 host devices)")
        shape, n = (2,), n // 2
        if n < 1:
            raise RuntimeError(
                "multi_pod mesh needs >= 2 devices, have 2·0")
    model = 1
    while model * 2 <= min(16, n) and n % (model * 2) == 0:
        model *= 2
    return shape + (n // model, model)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return _make_mesh(shape, axes)
    if len(devices) > n:
        # more devices than needed (e.g. 512 forced, single-pod 256)
        return _make_mesh(shape, axes, devices=devices[:n])
    # generic fallback: fold whatever this host provides into the
    # largest valid (data, model) shape (fold_mesh_shape raises with
    # the device count when no valid fold exists, e.g. multi_pod odd)
    return _make_mesh(fold_mesh_shape(len(devices), multi_pod=multi_pod),
                      axes, devices=devices)


def make_population_mesh(devices=None):
    """1-D population mesh: every device on the "data" axis (model=1).

    The population plane ((num_clients,) control/world arrays,
    core/population.py) shards over "data" only — it has no model axis
    to fill, so unlike the production grid ANY device count is a valid
    shape."""
    devices = list(jax.devices()) if devices is None else list(devices)
    return _make_mesh((len(devices), 1), ("data", "model"),
                      devices=devices)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Single-device mesh for smoke tests of the sharded code path."""
    return _make_mesh(shape, axes, devices=jax.devices()[:1])


def topology_pspec(mesh, min_pods: int = None):
    """PartitionSpec for a topology accumulator plane ``(pods, rows,
    lane)`` (repro.topology.engine.TopologyState.accum): shard the
    leading pod axis over "data" when the plane is tall enough to
    split evenly-ish (``min_pods`` defaults to the data-axis size),
    replicate otherwise — small upper-tier planes (often 1 root pod)
    don't benefit from sharding."""
    from jax.sharding import PartitionSpec
    if "data" not in mesh.axis_names:
        return PartitionSpec()
    if min_pods is not None and min_pods < mesh.shape["data"]:
        return PartitionSpec()
    return PartitionSpec("data")


def client_axes_in_mesh(cfg, mesh) -> tuple:
    """The subset of cfg.client_axes present in this mesh."""
    return tuple(a for a in cfg.client_axes if a in mesh.axis_names)


def num_clients(cfg, mesh) -> int:
    axes = client_axes_in_mesh(cfg, mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return max(n, 1)
