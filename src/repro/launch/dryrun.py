import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input shape) on the production
mesh — 16×16 ("data","model") single-pod and 2×16×16 ("pod","data",
"model") two-pod — using ShapeDtypeStruct inputs (no allocation), prints
memory/cost analysis, and appends roofline rows to a JSONL results file.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k --mesh single                            # one combo
  PYTHONPATH=src python -m repro.launch.dryrun --list           # plan only

The two env-var lines above MUST stay the first statements in this module:
jax locks the device count at first init.
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import registry
from repro.configs.shapes import SHAPES
from repro.core import fl_step
from repro.launch import mesh as mesh_mod
from repro.launch import sharding
from repro.models import api
from repro.optim import adamw as optim_mod
from repro.roofline import analysis, hlo_census

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun_results.jsonl")


def plan(args):
    combos = []
    archs = [args.arch] if args.arch else registry.ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": False, "multi": True}
    if args.mesh != "both":
        meshes = {args.mesh: meshes[args.mesh]}
    for a in archs:
        for s in shapes:
            if s == "long_500k" and a in registry.LONG_CTX_SKIP:
                continue
            for mname, mp in meshes.items():
                combos.append((a, s, mname, mp))
    return combos


def _completed(path):
    done = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                if line.strip():
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
    return done


def lower_one(arch: str, shape_name: str, multi_pod: bool, verbose=True):
    cfg = registry.config_for_shape(arch, shape_name)
    shape = SHAPES[shape_name]
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.size
    optimizer = optim_mod.for_config(cfg)

    if shape.kind == "train":
        C = mesh_mod.num_clients(cfg, mesh)
        specs = api.input_specs(cfg, shape, num_clients=C)
        state_shapes = jax.eval_shape(
            lambda: fl_step.init_state(jax.random.PRNGKey(0), cfg, optimizer))
        state_spec = sharding.state_pspecs(cfg, mesh, optimizer)
        batch_spec = sharding.train_batch_pspecs(cfg, mesh, specs["batch"])
        step = fl_step.make_raw_step(cfg, optimizer, theta=0.65)
        jitted = jax.jit(
            step,
            in_shardings=(sharding.to_named(mesh, state_spec),
                          sharding.to_named(mesh, batch_spec)),
            out_shardings=(sharding.to_named(mesh, state_spec), None),
            donate_argnums=(0,))
        lowered = jitted.lower(state_shapes, specs["batch"])
    elif shape.kind == "prefill":
        specs = api.input_specs(cfg, shape)
        pshapes = jax.eval_shape(
            lambda: api.init_params(jax.random.PRNGKey(0), cfg))
        pspec = sharding.param_pspecs(cfg, mesh, mode="serve")
        bspec = sharding.infer_batch_pspecs(mesh, specs["batch"])
        step = fl_step.build_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=(
            sharding.to_named(mesh, pspec), sharding.to_named(mesh, bspec)))
        lowered = jitted.lower(pshapes, specs["batch"])
    else:  # decode
        specs = api.input_specs(cfg, shape)
        pshapes = jax.eval_shape(
            lambda: api.init_params(jax.random.PRNGKey(0), cfg))
        pspec = sharding.param_pspecs(cfg, mesh, mode="serve")
        bspec = sharding.infer_batch_pspecs(mesh, specs["batch"])
        cspec = sharding.cache_pspecs(cfg, mesh, specs["cache"])
        step = fl_step.build_serve_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(sharding.to_named(mesh, pspec),
                          sharding.to_named(mesh, cspec),
                          sharding.to_named(mesh, bspec)),
            out_shardings=(None, sharding.to_named(mesh, cspec)),
            donate_argnums=(1,))
        lowered = jitted.lower(pshapes, specs["cache"], specs["batch"])

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):       # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_stats = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:
        mem_stats = None
    hlo = compiled.as_text()
    census = hlo_census.analyze(hlo)
    roof = analysis.analyze(arch, shape, mesh_name, chips, cost, census,
                            cfg, memory_stats=mem_stats)
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: "
              f"compiled in {compile_s:.1f}s")
        print(f"  memory_analysis: {mem_stats}")
        print(f"  cost_analysis: flops={cost.get('flops')} "
              f"bytes={cost.get('bytes accessed')}")
        print(f"  collectives: {census['per_op_bytes']}")
        print("  " + roof.as_row())
    return roof


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    choices=registry.ASSIGNED_ARCHS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--results", default=os.path.abspath(RESULTS))
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="re-run combos already in the results file")
    args = ap.parse_args(argv)

    combos = plan(args)
    if args.list:
        for c in combos:
            print(*c[:3])
        return 0
    os.makedirs(os.path.dirname(args.results), exist_ok=True)
    done = set() if args.force else _completed(args.results)
    failures = []
    for arch, shape_name, mesh_name, mp in combos:
        key = (arch, shape_name, "2x16x16" if mp else "16x16")
        if key in done:
            print(f"[dryrun] skip (cached): {key}")
            continue
        try:
            roof = lower_one(arch, shape_name, mp)
            analysis.save_jsonl(args.results, [roof])
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape_name, mesh_name, repr(e)))
        finally:
            jax.clear_caches()   # keep a long sweep's RSS bounded
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        return 1
    print("\n[dryrun] all combos lowered + compiled successfully")
    return 0


if __name__ == "__main__":
    sys.exit(main())
