"""First-class, pluggable FL strategies (the algorithm-object idiom).

A *strategy* is a named object that knows how to build the engine-level
``StrategyConfig`` for a run. Strategies live in a string-keyed registry
so experiment specs can reference them declaratively::

    spec = ExperimentSpec(strategy="ours",
                          strategy_kwargs={"batch_size": 128})

and user code can add its own without touching this package::

    @register_strategy("fedavg-big")
    def fedavg_big(batch_size=1024, **kw):
        return STRATEGY_REGISTRY["fedavg"].build(batch_size=batch_size, **kw)

The five paper baselines (Table II, Fig. 4) are registered here; their
faithfulness notes live with each factory. ``repro.core.baselines`` is a
deprecation shim re-exporting these.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Union

from repro.core.async_engine import StrategyConfig


def _finish(cfg: StrategyConfig, overrides: Dict) -> StrategyConfig:
    """Apply remaining StrategyConfig field overrides (lets callers pass
    any engine knob — quorum, max_samples_per_round, ... — through a
    preset without the preset enumerating every field)."""
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


class Strategy:
    """Base class for pluggable strategies.

    Subclasses override :meth:`build` to return the ``StrategyConfig``
    the engines consume; ``defaults`` are merged under call-site kwargs.
    """

    name: str = "strategy"
    description: str = ""
    defaults: Dict = {}

    def build(self, **overrides) -> StrategyConfig:
        kwargs = {**self.defaults, **overrides}
        return StrategyConfig(**kwargs)

    def __repr__(self):
        return f"<Strategy {self.name!r}>"


class _FunctionStrategy(Strategy):
    """Wraps a plain factory function ``f(**kw) -> StrategyConfig``."""

    def __init__(self, name: str, fn: Callable[..., StrategyConfig],
                 description: str = ""):
        self.name = name
        self.fn = fn
        self.description = description or (fn.__doc__ or "").strip()

    def build(self, **overrides) -> StrategyConfig:
        return self.fn(**overrides)


STRATEGY_REGISTRY: Dict[str, Strategy] = {}


def register_strategy(name: str, description: str = ""):
    """Decorator registering a strategy under ``name``.

    Accepts a ``Strategy`` subclass, a ``Strategy`` instance, or a plain
    factory function returning a ``StrategyConfig``. Returns the
    decorated object unchanged so it stays importable.
    """

    def deco(obj):
        if isinstance(obj, type) and issubclass(obj, Strategy):
            strat = obj()
            strat.name = name
        elif isinstance(obj, Strategy):
            strat = obj
            strat.name = name
        elif callable(obj):
            strat = _FunctionStrategy(name, obj, description)
        else:
            raise TypeError(
                f"register_strategy({name!r}): expected a Strategy class, "
                f"Strategy instance or factory function, got {type(obj)}")
        if description:
            strat.description = description
        STRATEGY_REGISTRY[name] = strat
        return obj

    return deco


def get_strategy(name: str) -> Strategy:
    try:
        return STRATEGY_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; registered: "
            f"{sorted(STRATEGY_REGISTRY)}") from None


def list_strategies() -> List[str]:
    return sorted(STRATEGY_REGISTRY)


def resolve_strategy(strategy: Union[str, Strategy, StrategyConfig,
                                     Callable[..., StrategyConfig]],
                     **overrides) -> StrategyConfig:
    """Normalize any accepted strategy form to a ``StrategyConfig``."""
    import dataclasses

    if isinstance(strategy, StrategyConfig):
        return (dataclasses.replace(strategy, **overrides)
                if overrides else strategy)
    if isinstance(strategy, str):
        return get_strategy(strategy).build(**overrides)
    if isinstance(strategy, Strategy):
        return strategy.build(**overrides)
    if callable(strategy):                    # bare factory function
        return strategy(**overrides)
    raise TypeError(f"cannot resolve strategy from {type(strategy)}")


# ---------------------------------------------------------------------------
# The paper's baselines (Table II, Fig. 4) — faithfulness notes inline.
# ---------------------------------------------------------------------------

@register_strategy("fedavg", "McMahan et al. [10]: synchronous, full "
                   "participation, no filtering — the paper's Sync baseline")
def fedavg(batch_size=64, lr=5e-3, local_epochs=1,
           **overrides) -> StrategyConfig:
    return _finish(StrategyConfig(mode="sync", theta=None, selection=False,
                                  dynamic_batch=False, checkpointing=False,
                                  batch_size=batch_size, lr=lr,
                                  local_epochs=local_epochs), overrides)


@register_strategy("cmfl", "Luping et al. [5]: upload only updates whose "
                   "sign agrees with the previous global update — "
                   "synchronous, same alignment test, no async/selection")
def cmfl(batch_size=64, lr=5e-3, theta=0.65, local_epochs=1,
         **overrides) -> StrategyConfig:
    return _finish(StrategyConfig(mode="sync", theta=theta, selection=False,
                                  dynamic_batch=False, checkpointing=False,
                                  batch_size=batch_size, lr=lr,
                                  local_epochs=local_epochs), overrides)


@register_strategy("acfl", "Yan et al. [11] CriticalFL: selection favours "
                   "large early-training gradient norms; synchronous")
def acfl(batch_size=64, lr=5e-3, select_fraction=0.7, local_epochs=1,
         **overrides) -> StrategyConfig:
    return _finish(StrategyConfig(mode="sync", theta=None, selection=True,
                                  select_fraction=select_fraction,
                                  grad_norm_selection=True,
                                  dynamic_batch=False, checkpointing=False,
                                  batch_size=batch_size, lr=lr,
                                  local_epochs=local_epochs), overrides)


@register_strategy("fedl2p", "Lee et al. [4]: per-client learned LR scaling "
                   "(simplified meta-rule); synchronous, no filtering")
def fedl2p(batch_size=64, lr=5e-3, local_epochs=1,
           **overrides) -> StrategyConfig:
    return _finish(StrategyConfig(mode="sync", theta=None, selection=False,
                                  dynamic_batch=False, checkpointing=False,
                                  per_client_lr=True, batch_size=batch_size,
                                  lr=lr, local_epochs=local_epochs),
                   overrides)


@register_strategy("ours", "the paper's framework: async + θ-filter + "
                   "adaptive selection + dynamic batch + Weibull ckpt")
def ours(batch_size=64, lr=5e-3, theta=0.65, local_epochs=1,
         dynamic_batch=True, select_fraction=1.0,
         **overrides) -> StrategyConfig:
    return _finish(StrategyConfig(mode="async", theta=theta, selection=True,
                                  select_fraction=select_fraction,
                                  dynamic_batch=dynamic_batch,
                                  checkpointing=True, batch_size=batch_size,
                                  lr=lr, local_epochs=local_epochs),
                   overrides)


# legacy name->factory mapping (kept for core.baselines / benchmarks shims)
PRESETS = {"fedavg": fedavg, "cmfl": cmfl, "acfl": acfl,
           "fedl2p": fedl2p, "ours": ours}
