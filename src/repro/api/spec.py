"""Declarative experiment specification — the single public entry point.

An ``ExperimentSpec`` names everything a paper experiment varies (model,
data/partition, client world, communication model, strategy, engine,
rounds, seed) and ``run_experiment(spec)`` executes it on either engine:

  engine="sim"   — the event-driven heterogeneous-client simulator
                   (repro.core.async_engine.FederatedSimulation)
  engine="spmd"  — the compiled one-round-per-step SPMD path
                   (repro.core.fl_step), with the same CommModel applied
                   analytically for time/byte accounting

Both return the normalized ``ExperimentResult`` / ``RoundRecord`` schema,
so benchmark tables are spec sweeps instead of hand-wired setups.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Union

from repro.api import strategies as strategies_mod
from repro.api import world as world_mod
from repro.core.async_engine import CommModel, StrategyConfig
from repro.core.scenario import ScenarioSpec, resolve_scenario
from repro.core.schedule import ScheduleSpec, resolve_schedule
from repro.topology.spec import TopologySpec, resolve_topology

ENGINES = ("sim", "spmd")
DATASETS = ("auto", "unsw", "road", "lm")
PARTITIONS = ("dirichlet", "iid")
PROFILES = ("heterogeneous", "uniform")


@dataclasses.dataclass(frozen=True)
class SpecIssue:
    """One validation violation: the field, its offending value, a hint."""
    field: str
    value: Any
    hint: str

    def __str__(self):
        return f"{self.field}={self.value!r}: {self.hint}"


class SpecError(ValueError):
    """Raised by ``ExperimentSpec.validate()`` with EVERY violation at
    once (``.issues``), not just the first — a sweep over hundreds of
    generated specs should surface all problems in one round trip."""

    def __init__(self, issues: List[SpecIssue]):
        self.issues = list(issues)
        detail = "; ".join(str(i) for i in self.issues)
        super().__init__(
            f"invalid ExperimentSpec — {len(self.issues)} problem"
            f"{'s' if len(self.issues) != 1 else ''}: {detail}")


@dataclasses.dataclass
class DataSpec:
    dataset: str = "auto"             # auto | unsw | road | lm (auto infers
                                      # from the model config)
    n_samples: int = 20000
    eval_samples: int = 4000
    partition: str = "dirichlet"
    alpha: float = 0.5                # Dirichlet concentration (lower=skewed)
    seq_len: int = 128                # lm datasets only
    factory: Optional[Callable[[int, int], Any]] = None
    # factory(seed, n) -> (X, y) or {"x": ..., "y": ...} overrides `dataset`
    samples_per_client: Optional[int] = None
    # non-resident worlds only (WorldSpec.resident=False): each client's
    # shard is synthesized lazily at this fixed size, so `n_samples` (a
    # population-wide total) never has to be materialized


@dataclasses.dataclass
class WorldSpec:
    num_clients: int = 10
    profile: str = "heterogeneous"    # heterogeneous | uniform
    dropout_p: float = 0.0
    speed_sigma: float = 0.6          # lognormal speed spread (stragglers)
    profile_seed_offset: int = 1      # profiles seeded at seed + offset
    resident: bool = True             # False -> client shards are NOT
                                      # materialized up front: build_world
                                      # returns a LazyWorld that
                                      # synthesizes each selected client's
                                      # data on demand (host memory scales
                                      # with the cohort, not the
                                      # population; needs
                                      # data.samples_per_client)


@dataclasses.dataclass
class ExperimentSpec:
    model: Union[str, Any] = "anomaly-mlp"     # config name or ArchConfig
    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    world: WorldSpec = dataclasses.field(default_factory=WorldSpec)
    comm: Optional[CommModel] = None           # None -> CommModel() defaults
    strategy: Union[str, StrategyConfig, Any] = "ours"
    strategy_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schedule: Union[str, ScheduleSpec, None] = None
    # the server-coordination axis (core/schedule.py): None derives the
    # schedule from the strategy's legacy ``mode`` field (the shim that
    # keeps every preset working); "sync" | "async" | "semi-async" or a
    # full ScheduleSpec overrides it — e.g. fedavg under an async quorum,
    # or "ours" with a bounded-staleness semi-async server
    scenario: Union[str, ScenarioSpec, None] = None
    # the dynamic-world axis (core/scenario.py): None -> the world stays
    # frozen at round 0 (the historical behavior); a preset name
    # ("drift", "churn", "flaky-links", "byzantine", ...) or a full
    # ScenarioSpec composes per-round transitions — concept drift, client
    # churn, link-quality walks, dropout regime switches, byzantine
    # updates — identically on every execution path of both engines
    topology: Union[str, TopologySpec, None] = None
    # the hierarchical-federation axis (repro.topology): None (or a
    # single-tier spec, which normalizes to None) -> today's flat star,
    # bit-identically; a preset name ("edge-region-global",
    # "two-tier-pods") or a full TopologySpec attaches an
    # accumulate-and-sync tier tree — leaf pods accumulate their
    # clients' weighted deltas every round, tier boundaries sync upward
    # on their cadence with per-tier θ vetoes, and inter-tier bytes are
    # priced per tier link — on every execution path of both engines
    engine: str = "sim"
    rounds: int = 5
    seed: int = 0
    eval_every: int = 1                        # evaluate every k-th round
                                               # (+ the final round); >1
                                               # skips the eval dispatch on
                                               # off-rounds of long runs
    megastep: bool = True                      # sim engine: one compiled
                                               # cohort dispatch per round
                                               # (False -> the reference
                                               # per-client loop)
    rounds_per_dispatch: Optional[int] = None  # sim engine: device-resident
                                               # control plane — R rounds of
                                               # {select, train, θ-filter,
                                               # aggregate, control update}
                                               # per compiled lax.scan
                                               # dispatch (core/control.py).
                                               # None -> host control plane
                                               # (the pinned reference paths)
    fused_eval: bool = False                   # sim engine, scanned path:
                                               # evaluation joins the
                                               # lax.scan carry (eval_every
                                               # cadence inside the scan, no
                                               # per-dispatch host readback)
                                               # — needs rounds_per_dispatch
                                               # and the default eval
    eval_fn: Optional[Callable] = None         # custom eval(params, batch)
    lr_schedule: Optional[Callable] = None     # spmd engine only
    candidate_frac: Optional[float] = None     # two-stage selection: each
                                               # of `candidate_shards`
                                               # logical population shards
                                               # pre-filters its top
                                               # ceil(frac·shard) scores
                                               # and only the union feeds
                                               # the exact masked top-k.
                                               # None -> legacy single-
                                               # stage; 1.0 is bit-
                                               # identical to it on every
                                               # execution path
    candidate_shards: int = 8                  # logical shards of the
                                               # stage-1 pre-filter (the
                                               # mesh "data" axis at scale)
    optimizer: Union[str, Any, None] = None    # spmd engine only:
                                               # None -> per-round SGD (the
                                               # sim's semantics); or
                                               # "sgd"|"adamw"|"adafactor"
                                               # or a prebuilt Optimizer

    # ------------------------------------------------------------------
    # resolution helpers
    # ------------------------------------------------------------------
    def resolve_model(self):
        if not isinstance(self.model, str):
            return self.model                  # already an ArchConfig
        from repro.configs import anomaly_mlp
        named = {"anomaly-mlp": anomaly_mlp.CONFIG,
                 "anomaly-mlp-road": anomaly_mlp.ROAD_CONFIG,
                 "anomaly-mlp-smoke": anomaly_mlp.SMOKE}
        if self.model in named:
            return named[self.model]
        from repro.configs import registry
        return registry.get_config(self.model)

    def resolve_strategy(self) -> StrategyConfig:
        return strategies_mod.resolve_strategy(self.strategy,
                                               **self.strategy_kwargs)

    def resolve_schedule(self) -> ScheduleSpec:
        return resolve_schedule(self.schedule, self.resolve_strategy())

    def resolve_comm(self) -> CommModel:
        return self.comm or CommModel()

    def resolve_scenario(self) -> Optional[ScenarioSpec]:
        return resolve_scenario(self.scenario)

    def resolve_topology(self) -> Optional[TopologySpec]:
        return resolve_topology(self.topology)

    def strategy_name(self) -> str:
        if isinstance(self.strategy, str):
            return self.strategy
        return getattr(self.strategy, "name", "<custom>")

    def build_world(self) -> world_mod.World:
        return world_mod.build_world(self)

    # ------------------------------------------------------------------
    # validation
    # ------------------------------------------------------------------
    def validate(self) -> "ExperimentSpec":
        """Raise :class:`SpecError` listing EVERY violation (field name,
        offending value, hint) — not just the first one found."""
        issues: List[SpecIssue] = []
        if self.engine not in ENGINES:
            issues.append(SpecIssue(
                "engine", self.engine,
                f"unknown engine; expected one of {ENGINES}"))
        if self.rounds < 1:
            issues.append(SpecIssue("rounds", self.rounds,
                                    "rounds must be >= 1"))
        if self.eval_every < 1:
            issues.append(SpecIssue("eval_every", self.eval_every,
                                    "eval_every must be >= 1"))
        if self.rounds_per_dispatch is not None:
            if self.rounds_per_dispatch < 1:
                issues.append(SpecIssue(
                    "rounds_per_dispatch", self.rounds_per_dispatch,
                    "rounds_per_dispatch must be >= 1"))
            if self.engine != "sim":
                issues.append(SpecIssue(
                    "rounds_per_dispatch", self.rounds_per_dispatch,
                    "rounds_per_dispatch is a sim-engine knob (the spmd "
                    "step is already one compiled round)"))
            if not self.megastep:
                issues.append(SpecIssue(
                    "megastep", self.megastep,
                    "rounds_per_dispatch requires megastep=True (the "
                    "scanned path runs on the parameter arena)"))
        if self.fused_eval:
            if self.rounds_per_dispatch is None:
                issues.append(SpecIssue(
                    "fused_eval", self.fused_eval,
                    "fused_eval folds evaluation into the scanned "
                    "lax.scan carry — set rounds_per_dispatch"))
            if self.engine != "sim":
                issues.append(SpecIssue(
                    "fused_eval", self.fused_eval,
                    "fused_eval is a sim-engine knob (the scanned "
                    "control plane)"))
            if self.eval_fn is not None:
                issues.append(SpecIssue(
                    "fused_eval", self.fused_eval,
                    "fused_eval traces evaluation inside the compiled "
                    "scan; custom eval_fn callables are not guaranteed "
                    "traceable — drop one of the two"))
        if self.world.num_clients < 1:
            issues.append(SpecIssue("world.num_clients",
                                    self.world.num_clients,
                                    "world.num_clients must be >= 1"))
        if self.candidate_frac is not None and not (
                0.0 < self.candidate_frac <= 1.0):
            issues.append(SpecIssue(
                "candidate_frac", self.candidate_frac,
                "candidate_frac must be in (0, 1] (1.0 reproduces "
                "single-stage selection bit-exactly; None disables the "
                "pre-filter)"))
        if self.candidate_shards < 1:
            issues.append(SpecIssue(
                "candidate_shards", self.candidate_shards,
                "candidate_shards must be >= 1"))
        if not self.world.resident:
            if self.data.samples_per_client is None:
                issues.append(SpecIssue(
                    "world.resident", self.world.resident,
                    "non-resident worlds need data.samples_per_client "
                    "(each client's shard is synthesized lazily at a "
                    "fixed size)"))
            elif self.data.samples_per_client < 1:
                issues.append(SpecIssue(
                    "data.samples_per_client", self.data.samples_per_client,
                    "samples_per_client must be >= 1"))
            if self.engine == "spmd":
                issues.append(SpecIssue(
                    "world.resident", self.world.resident,
                    "engine='spmd' stacks every client's batch into one "
                    "compiled step — non-resident data needs the sim "
                    "engine's cohort dispatch"))
            if self.rounds_per_dispatch is not None:
                issues.append(SpecIssue(
                    "world.resident", self.world.resident,
                    "the scanned control plane gathers client data "
                    "device-side, so the population must be resident — "
                    "drop rounds_per_dispatch for lazy worlds"))
            if self.data.factory is not None:
                issues.append(SpecIssue(
                    "data.factory", self.data.factory,
                    "non-resident worlds synthesize per-client shards "
                    "from the seeded generators; a whole-population "
                    "factory cannot be materialized lazily"))
        if self.data.dataset not in DATASETS and self.data.factory is None:
            issues.append(SpecIssue(
                "data.dataset", self.data.dataset,
                f"unknown dataset; expected one of {DATASETS} or a "
                "factory"))
        if self.data.partition not in PARTITIONS:
            issues.append(SpecIssue(
                "data.partition", self.data.partition,
                f"unknown partition; expected one of {PARTITIONS}"))
        if self.world.profile not in PROFILES:
            issues.append(SpecIssue(
                "world.profile", self.world.profile,
                f"unknown profile; expected one of {PROFILES}"))
        scenario = None
        try:
            scenario = self.resolve_scenario()
        except ValueError as e:
            issues.append(SpecIssue("scenario", self.scenario, str(e)))
        if scenario is not None:
            issues.extend(SpecIssue(f, v, h)
                          for f, v, h in scenario.issues())
            if scenario.drift is not None:
                issues.extend(self._validate_drift())
            if (scenario.byzantine is not None
                    and scenario.byzantine.n_byz >= self.world.num_clients):
                issues.append(SpecIssue(
                    "scenario.byzantine.n_byz", scenario.byzantine.n_byz,
                    f"needs at least one honest client (world has "
                    f"{self.world.num_clients}); the θ-filter has no "
                    "honest majority to form a reference otherwise"))
        topology = None
        try:
            topology = self.resolve_topology()
        except (ValueError, TypeError) as e:
            issues.append(SpecIssue("topology", self.topology, str(e)))
        if topology is not None:
            issues.extend(SpecIssue(f, v, h)
                          for f, v, h in topology.issues())
        strategy = schedule = None
        try:
            strategy = self.resolve_strategy()
        except (ValueError, TypeError) as e:
            issues.append(SpecIssue("strategy", self.strategy_name(),
                                    str(e)))
        if strategy is not None:
            try:
                schedule = self.resolve_schedule()
            except TypeError as e:
                issues.append(SpecIssue("schedule", self.schedule, str(e)))
        if schedule is not None:
            issues.extend(SpecIssue(f, v, h) for f, v, h
                          in schedule.issues())
            if self.engine == "spmd":
                issues.extend(self._validate_spmd(strategy, schedule))
        if issues:
            raise SpecError(issues)
        return self

    def _validate_drift(self) -> List[SpecIssue]:
        """Label-conditional feature drift needs feature/label batches —
        token (lm) datasets have no per-sample class direction."""
        if self.data.factory is not None:
            return []          # user factory: checked at batch time
        try:
            cfg = self.resolve_model()
        except Exception:
            return []          # model issues surface on their own
        if world_mod._dataset_kind(self.data, cfg) == "lm":
            return [SpecIssue(
                "scenario.drift", self.data.dataset,
                "label-conditional feature drift needs a feature/label "
                "dataset ('unsw'/'road'); token datasets are unsupported")]
        return []

    def _validate_spmd(self, st: StrategyConfig,
                       schedule: ScheduleSpec) -> List[SpecIssue]:
        """The compiled path is a synchronous cohort step. Selection,
        dropout, per-client LR scaling and quantized updates are all
        handled by the device-resident control plane as cohort MASKING
        (core/control.py routed through core/fl_step.py), so only knobs
        that genuinely need the event-driven simulator are rejected."""
        issues = []
        if not schedule.is_sync:
            issues.append(SpecIssue(
                "schedule.kind", schedule.kind,
                "engine='spmd' does not support asynchronous schedules — "
                "the quorum clock is event-driven (use engine='sim')"))
        if st.dynamic_batch:
            issues.append(SpecIssue(
                "strategy.dynamic_batch", st.dynamic_batch,
                "engine='spmd' does not support dynamic_batch (per-round "
                "shape changes would retrace the compiled step)"))
        return issues
