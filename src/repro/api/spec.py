"""Declarative experiment specification — the single public entry point.

An ``ExperimentSpec`` names everything a paper experiment varies (model,
data/partition, client world, communication model, strategy, engine,
rounds, seed) and ``run_experiment(spec)`` executes it on either engine:

  engine="sim"   — the event-driven heterogeneous-client simulator
                   (repro.core.async_engine.FederatedSimulation)
  engine="spmd"  — the compiled one-round-per-step SPMD path
                   (repro.core.fl_step), with the same CommModel applied
                   analytically for time/byte accounting

Both return the normalized ``ExperimentResult`` / ``RoundRecord`` schema,
so benchmark tables are spec sweeps instead of hand-wired setups.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Union

from repro.api import strategies as strategies_mod
from repro.api import world as world_mod
from repro.core.async_engine import CommModel, StrategyConfig

ENGINES = ("sim", "spmd")
DATASETS = ("auto", "unsw", "road", "lm")
PARTITIONS = ("dirichlet", "iid")
PROFILES = ("heterogeneous", "uniform")


@dataclasses.dataclass
class DataSpec:
    dataset: str = "auto"             # auto | unsw | road | lm (auto infers
                                      # from the model config)
    n_samples: int = 20000
    eval_samples: int = 4000
    partition: str = "dirichlet"
    alpha: float = 0.5                # Dirichlet concentration (lower=skewed)
    seq_len: int = 128                # lm datasets only
    factory: Optional[Callable[[int, int], Any]] = None
    # factory(seed, n) -> (X, y) or {"x": ..., "y": ...} overrides `dataset`


@dataclasses.dataclass
class WorldSpec:
    num_clients: int = 10
    profile: str = "heterogeneous"    # heterogeneous | uniform
    dropout_p: float = 0.0
    speed_sigma: float = 0.6          # lognormal speed spread (stragglers)
    profile_seed_offset: int = 1      # profiles seeded at seed + offset


@dataclasses.dataclass
class ExperimentSpec:
    model: Union[str, Any] = "anomaly-mlp"     # config name or ArchConfig
    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    world: WorldSpec = dataclasses.field(default_factory=WorldSpec)
    comm: Optional[CommModel] = None           # None -> CommModel() defaults
    strategy: Union[str, StrategyConfig, Any] = "ours"
    strategy_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    engine: str = "sim"
    rounds: int = 5
    seed: int = 0
    eval_every: int = 1                        # evaluate every k-th round
                                               # (+ the final round); >1
                                               # skips the eval dispatch on
                                               # off-rounds of long runs
    megastep: bool = True                      # sim engine: one compiled
                                               # cohort dispatch per round
                                               # (False -> the reference
                                               # per-client loop)
    rounds_per_dispatch: Optional[int] = None  # sim engine: device-resident
                                               # control plane — R rounds of
                                               # {select, train, θ-filter,
                                               # aggregate, control update}
                                               # per compiled lax.scan
                                               # dispatch (core/control.py).
                                               # None -> host control plane
                                               # (the pinned reference paths)
    eval_fn: Optional[Callable] = None         # custom eval(params, batch)
    lr_schedule: Optional[Callable] = None     # spmd engine only
    optimizer: Union[str, Any, None] = None    # spmd engine only:
                                               # None -> per-round SGD (the
                                               # sim's semantics); or
                                               # "sgd"|"adamw"|"adafactor"
                                               # or a prebuilt Optimizer

    # ------------------------------------------------------------------
    # resolution helpers
    # ------------------------------------------------------------------
    def resolve_model(self):
        if not isinstance(self.model, str):
            return self.model                  # already an ArchConfig
        from repro.configs import anomaly_mlp
        named = {"anomaly-mlp": anomaly_mlp.CONFIG,
                 "anomaly-mlp-road": anomaly_mlp.ROAD_CONFIG,
                 "anomaly-mlp-smoke": anomaly_mlp.SMOKE}
        if self.model in named:
            return named[self.model]
        from repro.configs import registry
        return registry.get_config(self.model)

    def resolve_strategy(self) -> StrategyConfig:
        return strategies_mod.resolve_strategy(self.strategy,
                                               **self.strategy_kwargs)

    def resolve_comm(self) -> CommModel:
        return self.comm or CommModel()

    def strategy_name(self) -> str:
        if isinstance(self.strategy, str):
            return self.strategy
        return getattr(self.strategy, "name", "<custom>")

    def build_world(self) -> world_mod.World:
        return world_mod.build_world(self)

    # ------------------------------------------------------------------
    # validation
    # ------------------------------------------------------------------
    def validate(self) -> "ExperimentSpec":
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; "
                             f"expected one of {ENGINES}")
        if self.rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        if self.eval_every < 1:
            raise ValueError(
                f"eval_every must be >= 1, got {self.eval_every}")
        if self.rounds_per_dispatch is not None:
            if self.rounds_per_dispatch < 1:
                raise ValueError("rounds_per_dispatch must be >= 1, got "
                                 f"{self.rounds_per_dispatch}")
            if self.engine != "sim":
                raise ValueError("rounds_per_dispatch is a sim-engine "
                                 "knob (the spmd step is already one "
                                 "compiled round)")
            if not self.megastep:
                raise ValueError("rounds_per_dispatch requires "
                                 "megastep=True")
        if self.world.num_clients < 1:
            raise ValueError("world.num_clients must be >= 1, got "
                             f"{self.world.num_clients}")
        if self.data.dataset not in DATASETS and self.data.factory is None:
            raise ValueError(f"unknown dataset {self.data.dataset!r}; "
                             f"expected one of {DATASETS} or a factory")
        if self.data.partition not in PARTITIONS:
            raise ValueError(f"unknown partition {self.data.partition!r}; "
                             f"expected one of {PARTITIONS}")
        if self.world.profile not in PROFILES:
            raise ValueError(f"unknown profile {self.world.profile!r}; "
                             f"expected one of {PROFILES}")
        strategy = self.resolve_strategy()     # raises on unknown names
        if self.engine == "spmd":
            self._validate_spmd(strategy)
        return self

    def _validate_spmd(self, st: StrategyConfig) -> None:
        """The compiled path is a synchronous cohort step. Selection,
        dropout, per-client LR scaling and quantized updates are all
        handled by the device-resident control plane as cohort MASKING
        (core/control.py routed through core/fl_step.py), so only knobs
        that genuinely need the event-driven simulator are rejected."""
        unsupported = []
        if st.mode != "sync":
            unsupported.append("mode='async' (use engine='sim')")
        if st.dynamic_batch:
            unsupported.append("dynamic_batch (per-round shape changes "
                               "would retrace the compiled step)")
        if unsupported:
            raise ValueError("engine='spmd' does not support: "
                             + "; ".join(unsupported))
