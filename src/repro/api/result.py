"""Normalized result schema shared by BOTH engines.

Every experiment — event-driven simulation (``engine="sim"``) or compiled
SPMD round loop (``engine="spmd"``) — returns one ``ExperimentResult``
holding per-round ``RoundRecord``s with identical field meaning:

  sim_time    simulated end-to-end seconds so far (CommModel units)
  comm_time   cumulative transfer seconds
  idle_time   cumulative barrier-idle seconds (sync semantics)
  bytes_sent  cumulative client->server bytes, 1-bit skip beacons included
  accept_rate fraction of selected clients whose update passed the filter

In the degenerate configuration (equal speeds, zero latency, theta=None,
one local step) the two engines produce identical records (tested in
tests/test_api.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional


@dataclasses.dataclass
class RoundRecord:
    round: int
    sim_time: float
    comm_time: float
    idle_time: float
    bytes_sent: float
    updates_applied: int
    accept_rate: float
    accuracy: float
    loss: float


ROUND_FIELDS = tuple(f.name for f in dataclasses.fields(RoundRecord))


@dataclasses.dataclass
class ExperimentResult:
    engine: str
    strategy: str                     # registry name, or "<custom>"
    rounds: int
    seed: int
    records: List[RoundRecord]
    cfg: Any = None                   # resolved ArchConfig
    params: Any = None                # final global parameters
    eval_arrays: Any = None           # held-out eval split
    num_clients: int = 0
    param_bytes: int = 0              # one full update's wire size
    wall_time: float = 0.0            # real container seconds

    @property
    def final(self) -> Optional[RoundRecord]:
        return self.records[-1] if self.records else None

    def series(self, field: str) -> List[float]:
        return [getattr(r, field) for r in self.records]

    def to_rows(self):
        """Per-round rows in ROUND_FIELDS order (CSV-friendly)."""
        return [[getattr(r, f) for f in ROUND_FIELDS] for r in self.records]

    @property
    def bytes_baseline(self) -> float:
        """Full-participation upload volume for the same world/rounds."""
        return float(self.num_clients) * self.param_bytes * self.rounds

    def auc_roc(self) -> float:
        """Binary-ised AUC-ROC on the eval split (attack vs Normal).

        Only meaningful for the mlp detector family.
        """
        import jax
        import jax.numpy as jnp

        from repro.models import mlp_detector

        ev = jax.tree.map(jnp.asarray, self.eval_arrays)
        probs = mlp_detector.predict(self.params, ev["x"], self.cfg)
        scores = 1.0 - probs[:, 0]                 # P(not Normal)
        labels = (ev["y"] != 0).astype(jnp.float32)
        return float(mlp_detector.auc_roc(scores, labels))
