"""ExperimentSession — the resumable, streaming experiment driver.

``run_experiment`` is a one-shot call; the paper's statistical apparatus
(repeated runs, Mann-Whitney validation) and any long-lived deployment
need a *driver*: open an experiment, advance it round by round, observe
records as they happen, checkpoint mid-flight, resume bit-identically.

    session = ExperimentSession.open(spec)
    for record in session.stream(spec.rounds):     # RoundRecord stream
        print(record.round, record.accuracy)
    session.checkpoint("run.ckpt")                 # full device state
    ...
    session = ExperimentSession.restore("run.ckpt")
    session.run(10)                                # continues exactly

Resume bit-exactness: a checkpoint serializes the COMPLETE state of the
underlying engine — parameters (arena matrix or pytree), optimizer
state, the device ``ControlState``, every numpy Generator position
(engine, loaders, selector) and the scanned path's PRNG key / absolute
round counter — so a restored session's subsequent records and final
parameters are bit-identical to an uninterrupted run on BOTH engines,
including ``rounds_per_dispatch > 1`` (tests/test_session.py).
Checkpoints do NOT store training data; worlds rebuild deterministically
from the spec's seed. Restoring onto a spec whose trajectory-relevant
fields differ raises :class:`CheckpointMismatchError` naming them. One
nuance under ``eval_every > 1``: each ``run()`` call evaluates its own
final round (so ``result.final`` is always measured), which means a
checkpoint boundary adds one accuracy SAMPLE at the boundary round —
the trajectory and every other record field are unaffected, and with
the default ``eval_every=1`` the record series is bit-identical too.

Callbacks: ``session.add_callback(fn)`` registers ``fn(record)``; return
``False`` (or call ``session.request_stop()``) to stop the run early.
``run(n)`` computes its rounds as one engine batch (fastest; callbacks
observe records afterwards, early-stop takes effect at batch end), while
``stream(n)`` computes dispatch-sized chunks and reacts between chunks.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

from repro import faults
from repro.api import runner as runner_mod
from repro.api.result import ExperimentResult, RoundRecord
from repro.api.spec import ExperimentSpec
from repro.checkpoint.io import CheckpointCorruptError

CHECKPOINT_FORMAT = 1

# spec fields that identify a trajectory — a checkpoint refuses to
# restore onto a spec that changes any of these (see _spec_fingerprint).
# `rounds` is NOT one of them: the round budget is a session argument,
# and extending a restored run is exactly what sessions are for.
_FINGERPRINT_DOC = ("engine", "model", "strategy", "schedule", "scenario",
                    "topology", "data", "world", "comm", "seed",
                    "eval_every", "megastep", "rounds_per_dispatch",
                    "fused_eval", "optimizer", "lr_schedule", "eval_fn")


def sidecar_path(ckpt_path: str) -> str:
    """The JSON metadata file written next to every session checkpoint
    (``<ckpt>.meta.json``) — fingerprint, round counter, wall time —
    so consumers (``repro.serve.swap``) can validate provenance and
    staleness WITHOUT unpickling or rebuilding the checkpoint."""
    return ckpt_path + ".meta.json"


def read_sidecar(ckpt_path: str) -> Dict[str, Any]:
    """Load the checkpoint's sidecar metadata dict. Raises
    FileNotFoundError with a pointed message when the checkpoint
    predates sidecar metadata (re-write it with ``checkpoint()``)."""
    path = sidecar_path(ckpt_path)
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"no sidecar metadata at {path} — the checkpoint predates "
            "sidecar support (or was moved without it); re-write it via "
            "ExperimentSession.checkpoint(), which emits both files")
    with open(path) as f:
        return json.load(f)


def _read_verified_payload(path: str) -> Dict[str, Any]:
    """Read a session checkpoint, verifying its sidecar content digest
    BEFORE unpickling (ISSUE 7): a truncated file, bit-flipped payload,
    stripped sidecar or stale digest raises
    :class:`~repro.checkpoint.io.CheckpointCorruptError` naming the
    offending path — pickle never sees untrusted bytes. Sidecars written
    before digest support (no ``sha256`` field) are accepted as legacy.
    """
    faults.check_active("ckpt_read")
    with open(path, "rb") as f:
        blob = f.read()
    sc = sidecar_path(path)
    if not os.path.exists(sc):
        raise CheckpointCorruptError(
            path, f"missing sidecar {sc!r} — cannot verify integrity "
                  "(re-write via ExperimentSession.checkpoint(), which "
                  "emits both files)")
    try:
        with open(sc) as f:
            meta = json.load(f)
    except Exception as e:
        raise CheckpointCorruptError(
            path, f"unreadable sidecar {sc!r} "
                  f"({type(e).__name__}: {e})") from e
    want = meta.get("sha256")
    if want is not None:
        got = hashlib.sha256(blob).hexdigest()
        if got != want:
            raise CheckpointCorruptError(
                path, f"content digest mismatch (sidecar sha256 {want!r} "
                      f"!= computed {got!r})")
    try:
        payload = pickle.loads(blob)
    except Exception as e:
        raise CheckpointCorruptError(
            path, f"undecodable payload ({type(e).__name__}: {e})") from e
    if not isinstance(payload, dict):
        raise CheckpointCorruptError(
            path, f"payload decodes to {type(payload).__name__}, "
                  "not a checkpoint dict")
    return payload


def latest_good_checkpoint(directory: str,
                           exclude=()) -> Optional[str]:
    """Newest digest-verified session checkpoint in ``directory`` —
    ``*.ckpt`` files ranked by their sidecar's ``written_at`` (newest
    first), skipping ``exclude`` paths and anything whose digest (or
    pickle decode) fails. The recovery source behind
    ``ExperimentSession.restore(..., fallback=True)`` and
    ``ModelSlot.publish_checkpoint(..., fallback=True)``."""
    excl = {os.path.abspath(p) for p in exclude}
    cands = []
    try:
        names = os.listdir(directory or ".")
    except OSError:
        return None
    for name in names:
        if not name.endswith(".ckpt"):
            continue
        p = os.path.join(directory or ".", name)
        if os.path.abspath(p) in excl:
            continue
        try:
            meta = read_sidecar(p)
        except (OSError, ValueError):
            continue
        cands.append((float(meta.get("written_at", 0.0)), p))
    for _t, p in sorted(cands, reverse=True):
        try:
            _read_verified_payload(p)
            return p
        except (CheckpointCorruptError, OSError, faults.InjectedFault):
            continue
    return None


class CheckpointMismatchError(ValueError):
    """Restoring a checkpoint onto a spec describing a different
    trajectory. ``.mismatches`` maps field -> (checkpoint, requested)."""

    def __init__(self, mismatches: Dict[str, tuple]):
        self.mismatches = dict(mismatches)
        detail = "; ".join(f"{k}: checkpoint={a!r} vs spec={b!r}"
                           for k, (a, b) in self.mismatches.items())
        super().__init__(
            "checkpoint does not match the spec it is being restored "
            f"onto — differing fields: {detail}")


def _spec_fingerprint(spec: ExperimentSpec) -> Dict[str, Any]:
    """Plain-data identity of the trajectory a spec describes.

    Callables (data factory, eval_fn, lr_schedule, optimizer objects)
    cannot be content-compared across processes — they contribute a
    stable presence/type marker only, never a repr with a memory
    address (which would spuriously mismatch a faithfully
    reconstructed spec in a new process)."""
    def _marker(obj):
        if obj is None or isinstance(obj, str):
            return obj
        return type(obj).__name__        # stable across processes

    cfg = spec.resolve_model()
    data = dataclasses.asdict(spec.data)
    data["factory"] = spec.data.factory is not None   # presence only
    scenario = spec.resolve_scenario()
    topology = spec.resolve_topology()
    return {
        "engine": spec.engine,
        "model": getattr(cfg, "name", str(spec.model)),
        "strategy": dataclasses.asdict(spec.resolve_strategy()),
        "schedule": dataclasses.asdict(spec.resolve_schedule()),
        "scenario": (None if scenario is None
                     else dataclasses.asdict(scenario)),
        "topology": (None if topology is None
                     else dataclasses.asdict(topology)),
        "data": data,
        "world": dataclasses.asdict(spec.world),
        "comm": dataclasses.asdict(spec.resolve_comm()),
        "seed": spec.seed,
        "eval_every": spec.eval_every,
        "megastep": spec.megastep,
        "rounds_per_dispatch": spec.rounds_per_dispatch,
        "fused_eval": spec.fused_eval,
        "optimizer": _marker(spec.optimizer),
        "lr_schedule": spec.lr_schedule is not None,
        "eval_fn": spec.eval_fn is not None,
    }


class _SimDriver:
    """Session driver for engine='sim' — wraps FederatedSimulation."""

    engine = "sim"

    def __init__(self, spec: ExperimentSpec):
        self.spec = spec
        self.sim = runner_mod.build_simulation(spec)

    def run_rounds(self, n: int, eval_final: bool = True
                   ) -> List[RoundRecord]:
        prev = len(self.sim.history)
        self.sim.run(n, eval_final=eval_final)
        return [runner_mod.record_from_metrics(m)
                for m in self.sim.history[prev:]]

    def state_dict(self) -> dict:
        return self.sim.state_dict()

    def load_state_dict(self, state: dict) -> None:
        self.sim.load_state_dict(state)

    def client_pass_rates(self):
        return self.sim.client_pass_rates()

    def result(self, records, wall_time: float = 0.0) -> ExperimentResult:
        return ExperimentResult(
            engine="sim", strategy=self.spec.strategy_name(),
            rounds=len(records), seed=self.spec.seed,
            records=list(records), cfg=self.sim.cfg,
            params=self.sim.params, eval_arrays=self.sim.eval_arrays,
            num_clients=self.sim.num_clients,
            param_bytes=self.sim.param_bytes, wall_time=wall_time)


class ExperimentSession:
    """Open with :meth:`open` or :meth:`restore` — not the constructor."""

    def __init__(self, spec: ExperimentSpec, driver):
        self.spec = spec
        self._driver = driver
        self.records: List[RoundRecord] = []
        self.callbacks: List[Callable[[RoundRecord], Any]] = []
        self._stopped = False
        self._wall = 0.0

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def open(cls, spec: ExperimentSpec) -> "ExperimentSession":
        spec.validate()
        t0 = time.time()
        if spec.engine == "sim":
            driver = _SimDriver(spec)
        else:
            driver = runner_mod.SpmdDriver(spec)
        session = cls(spec, driver)
        session._wall += time.time() - t0
        return session

    @classmethod
    def restore(cls, path: str,
                spec: Optional[ExperimentSpec] = None, *,
                fallback: bool = False) -> "ExperimentSession":
        """Rebuild a session from :meth:`checkpoint` output and continue
        bit-identically. ``spec`` is only needed when the checkpointed
        spec contained unpicklable callables (eval_fn / data factory /
        lr_schedule); when given, it must describe the SAME trajectory.

        The payload's content digest (sidecar ``sha256``) is verified
        before unpickling — a corrupt artifact raises
        :class:`~repro.checkpoint.io.CheckpointCorruptError` instead of
        pickle garbage. ``fallback=True`` degrades to the newest
        digest-verified ``*.ckpt`` in the same directory
        (:func:`latest_good_checkpoint`); only when none survives does
        the original corruption error surface."""
        try:
            payload = _read_verified_payload(path)
        except (CheckpointCorruptError, OSError, faults.InjectedFault):
            if not fallback:
                raise
            good = latest_good_checkpoint(os.path.dirname(path),
                                          exclude=(path,))
            if good is None:
                raise
            payload = _read_verified_payload(good)
        if payload.get("format") != CHECKPOINT_FORMAT:
            raise ValueError(
                f"unknown session checkpoint format "
                f"{payload.get('format')!r} (expected {CHECKPOINT_FORMAT})")
        if spec is None:
            spec = payload["spec"]
            if spec is None:
                raise ValueError(
                    "this checkpoint does not embed its spec (it held "
                    "unpicklable callables); pass the original spec: "
                    "ExperimentSession.restore(path, spec=...)")
        theirs, ours = payload["fingerprint"], _spec_fingerprint(spec)
        mismatches = {k: (theirs.get(k), ours.get(k))
                      for k in sorted(set(theirs) | set(ours))
                      if theirs.get(k) != ours.get(k)}
        if mismatches:
            raise CheckpointMismatchError(mismatches)
        session = cls.open(spec)
        session._driver.load_state_dict(payload["driver"])
        session.records = [RoundRecord(**r) for r in payload["records"]]
        session._wall = payload["wall_time"]
        return session

    # ------------------------------------------------------------------
    # driving
    # ------------------------------------------------------------------
    @property
    def rounds_done(self) -> int:
        return len(self.records)

    @property
    def stopped(self) -> bool:
        return self._stopped

    def request_stop(self) -> None:
        """Ask the session to stop after the current round/chunk —
        callable from inside a callback (the early-stop hook)."""
        self._stopped = True

    def add_callback(self, fn: Callable[[RoundRecord], Any]) -> None:
        """Register ``fn(record)``, fired for every new RoundRecord in
        order; returning ``False`` requests an early stop."""
        self.callbacks.append(fn)

    def _fire(self, records: List[RoundRecord]) -> None:
        for rec in records:
            for cb in self.callbacks:
                if cb(rec) is False:
                    self._stopped = True

    def _remaining(self, rounds: Optional[int]) -> int:
        if rounds is not None:
            return max(0, int(rounds))
        return max(0, self.spec.rounds - self.rounds_done)

    def run(self, rounds: Optional[int] = None) -> List[RoundRecord]:
        """Advance ``rounds`` more rounds (default: the spec's remaining
        budget) as ONE engine batch and return their records."""
        n = self._remaining(rounds)
        if n == 0 or self._stopped:
            return []
        t0 = time.time()
        new = self._driver.run_rounds(n)
        self._wall += time.time() - t0
        self.records.extend(new)
        self._fire(new)
        return new

    def stream(self, rounds: Optional[int] = None) -> Iterator[RoundRecord]:
        """Yield records as they are produced. Chunk size follows the
        engine's dispatch granularity (``rounds_per_dispatch`` on the
        scanned sim path, else 1), so streaming keeps the compiled-path
        amortization; early stop takes effect between chunks. The
        ``eval_every`` cadence is absolute, and only the FINAL round of
        the whole stream gets the extra end-of-run evaluation — the
        accuracy series is identical to a single ``run(n)`` batch."""
        n = self._remaining(rounds)
        chunk = self.spec.rounds_per_dispatch or 1
        done = 0
        while done < n and not self._stopped:
            step = min(chunk, n - done)
            t0 = time.time()
            new = self._driver.run_rounds(step,
                                          eval_final=(done + step >= n))
            self._wall += time.time() - t0
            self.records.extend(new)
            done += len(new)
            self._fire(new)
            yield from new

    def __iter__(self) -> Iterator[RoundRecord]:
        return self.stream()

    def result(self) -> ExperimentResult:
        """The normalized ExperimentResult over everything run so far."""
        return self._driver.result(self.records, wall_time=self._wall)

    def client_pass_rates(self):
        """(num_clients,) per-client θ pass-rate EMAs the server control
        plane has learned so far — the diagnostics surface behind the
        differential harness's byzantine-rejection assert (raises on the
        spmd engine when its control plane is inactive)."""
        return self._driver.client_pass_rates()

    # ------------------------------------------------------------------
    # persistence
    # ------------------------------------------------------------------
    def checkpoint(self, path: str) -> str:
        """Serialize the full session state to ``path`` (atomic write).
        The training data is NOT stored — worlds rebuild from the seed.

        A small JSON sidecar (:func:`sidecar_path`: ``<path>.meta.json``)
        records the spec fingerprint, the absolute round counter and
        wall time, so serving-side consumers (``repro.serve.swap``) can
        reject stale or mismatched models with a clear error without
        unpickling the full checkpoint."""
        fingerprint = _spec_fingerprint(self.spec)
        try:
            pickle.dumps(self.spec)
            spec_blob = self.spec
        except Exception:
            spec_blob = None          # unpicklable callables in the spec
        payload = {
            "format": CHECKPOINT_FORMAT,
            "fingerprint": fingerprint,
            "spec": spec_blob,
            "records": [dataclasses.asdict(r) for r in self.records],
            "wall_time": self._wall,
            "driver": self._driver.state_dict(),
        }
        blob = pickle.dumps(payload)
        # fault-checked BEFORE any byte lands: an injected write error
        # never damages the artifact (or sidecar) already at `path`
        faults.check_active("ckpt_write")
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)   # a crash never corrupts the checkpoint
        meta = {
            "format": CHECKPOINT_FORMAT,
            "model": fingerprint["model"],
            "engine": fingerprint["engine"],
            "seed": fingerprint["seed"],
            "rounds_done": self.rounds_done,
            "wall_time": self._wall,
            "written_at": time.time(),
            # content digest of the payload bytes — restore() verifies
            # this before unpickling (CheckpointCorruptError otherwise)
            "sha256": hashlib.sha256(blob).hexdigest(),
            "payload_bytes": len(blob),
            # tuples inside dataclass asdicts become JSON lists; the
            # sidecar is provenance metadata, not an equality oracle —
            # exact fingerprint matching stays in restore()
            "fingerprint": fingerprint,
        }
        mtmp = sidecar_path(path) + ".tmp"
        with open(mtmp, "w") as f:
            json.dump(meta, f, indent=2, default=str)
            f.write("\n")
        os.replace(mtmp, sidecar_path(path))
        return path
