"""Nonparametric statistics for multi-seed experiment comparisons.

The paper validates its headline claim with a Mann-Whitney U test over
repeated runs (Table VII: ours vs each baseline, H1 "ours stochastically
larger", α=0.05). This module is the dependency-free implementation
``run_sweep``'s :class:`SweepResult` reports are built on:

``mann_whitney_u``   — asymptotic U test with average-rank ties, tie
                       variance correction and continuity correction;
                       matches ``scipy.stats.mannwhitneyu(
                       method="asymptotic")`` (pinned in tests when
                       scipy is importable).
``median_iqr`` et al — the median/IQR summaries the paper's tables use
                       (medians, not means: run distributions are small
                       and skewed).

Pure numpy on purpose: the tier-1 suite and the sweep path must not
depend on scipy (benchmarks may still use it).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

ALTERNATIVES = ("two-sided", "greater", "less")


@dataclasses.dataclass(frozen=True)
class MannWhitneyResult:
    u: float                  # U statistic of sample a
    p_value: float
    alternative: str
    n_a: int
    n_b: int

    def significant(self, alpha: float = 0.05) -> bool:
        return self.p_value < alpha

    def __str__(self):
        return (f"U={self.u:.1f} p={self.p_value:.4g} "
                f"({self.alternative}, n={self.n_a}/{self.n_b})")


def rankdata(x: np.ndarray) -> np.ndarray:
    """Average ranks (1-based), ties sharing their mean rank."""
    x = np.asarray(x, dtype=np.float64)
    order = np.argsort(x, kind="stable")
    ranks = np.empty(len(x), np.float64)
    sx = x[order]
    i = 0
    while i < len(sx):
        j = i
        while j + 1 < len(sx) and sx[j + 1] == sx[i]:
            j += 1
        ranks[order[i:j + 1]] = 0.5 * (i + j) + 1.0
        i = j + 1
    return ranks


def _normal_sf(z: float) -> float:
    return 0.5 * math.erfc(z / math.sqrt(2.0))


def mann_whitney_u(a: Sequence[float], b: Sequence[float],
                   alternative: str = "two-sided") -> MannWhitneyResult:
    """Mann-Whitney U test of sample ``a`` vs ``b``.

    ``alternative="greater"`` tests H1 "a stochastically larger than b"
    (the paper's direction for ours-vs-baseline). Asymptotic normal
    p-value with tie and continuity corrections — exact enough for the
    >= 5-seed sweeps this repo runs, and dependency-free.
    """
    if alternative not in ALTERNATIVES:
        raise ValueError(f"unknown alternative {alternative!r}; "
                         f"expected one of {ALTERNATIVES}")
    a = np.asarray(list(a), np.float64)
    b = np.asarray(list(b), np.float64)
    n1, n2 = len(a), len(b)
    if n1 == 0 or n2 == 0:
        raise ValueError(f"both samples need data (got n={n1}/{n2})")
    combined = np.concatenate([a, b])
    ranks = rankdata(combined)
    r1 = float(ranks[:n1].sum())
    u1 = r1 - n1 * (n1 + 1) / 2.0       # U of sample a

    n = n1 + n2
    mean = n1 * n2 / 2.0
    _, counts = np.unique(combined, return_counts=True)
    tie_term = float((counts.astype(np.float64) ** 3 - counts).sum())
    var = n1 * n2 / 12.0 * ((n + 1) - tie_term / (n * (n - 1.0)))
    if var <= 0:                         # all observations identical
        p = 1.0
    else:
        sd = math.sqrt(var)
        if alternative == "greater":
            p = _normal_sf((u1 - mean - 0.5) / sd)
        elif alternative == "less":
            p = _normal_sf((mean - u1 - 0.5) / sd)
        else:
            p = min(1.0, 2.0 * _normal_sf((abs(u1 - mean) - 0.5) / sd))
    return MannWhitneyResult(u=u1, p_value=float(np.clip(p, 0.0, 1.0)),
                             alternative=alternative, n_a=n1, n_b=n2)


# ---------------------------------------------------------------------------
# summaries (the paper's tables report medians over repeated runs)
# ---------------------------------------------------------------------------

def median_iqr(x: Iterable[float]) -> Tuple[float, float, float]:
    """(median, q1, q3) with linear interpolation."""
    arr = np.asarray(list(x), np.float64)
    if arr.size == 0:
        return (float("nan"),) * 3
    q1, med, q3 = np.percentile(arr, [25.0, 50.0, 75.0])
    return float(med), float(q1), float(q3)


def summarize(samples: Dict[str, Sequence[float]]) -> List[List]:
    """[group, n, median, q1, q3] rows for a dict of sample arrays."""
    rows = []
    for name, vals in samples.items():
        med, q1, q3 = median_iqr(vals)
        rows.append([name, len(list(vals)), med, q1, q3])
    return rows
