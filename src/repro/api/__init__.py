"""Public experiment API: declarative specs, pluggable strategies, one
facade over both engines — plus sessions, schedules and sweeps.

    from repro.api import ExperimentSpec, run_experiment

    result = run_experiment(ExperimentSpec(strategy="ours", rounds=8))
    print(result.final.accuracy, result.final.bytes_sent)

Resumable driving and multi-seed statistics:

    from repro.api import ExperimentSession, run_sweep

    session = ExperimentSession.open(spec)
    for record in session.stream(8):
        ...
    session.checkpoint("run.ckpt")

    sweep = run_sweep(spec, axes={"strategy": ["ours", "fedavg"],
                                  "seed": range(5)})
    print(sweep.mann_whitney_u("strategy", "ours", "fedavg").p_value)
"""
from repro.api.result import (ROUND_FIELDS, ExperimentResult, RoundRecord)
from repro.api.runner import (build_spmd_components, run_experiment,
                              run_scanned_seed_batch, run_spmd_seed_batch,
                              seed_vectorizable)
from repro.api.session import (CheckpointMismatchError, ExperimentSession)
from repro.api.spec import (DataSpec, ExperimentSpec, SpecError, SpecIssue,
                            WorldSpec)
from repro.api.stats import MannWhitneyResult, mann_whitney_u, median_iqr
from repro.api.strategies import (PRESETS, STRATEGY_REGISTRY, Strategy,
                                  get_strategy, list_strategies,
                                  register_strategy, resolve_strategy)
from repro.api.sweep import SweepPoint, SweepResult, run_sweep
from repro.api.world import World, build_world
from repro.core.async_engine import (ClientProfile, CommModel,
                                     StrategyConfig)
from repro.core.scenario import (SCENARIO_PRESETS, ByzantineSpec, ChurnSpec,
                                 DriftSpec, DropoutSchedule, LinkSpec,
                                 ScenarioSpec, WorldState, resolve_scenario)
from repro.core.schedule import ScheduleSpec
from repro.topology.spec import (TOPOLOGY_PRESETS, TierSpec, TopologySpec,
                                 resolve_topology)

__all__ = [
    "ByzantineSpec", "CheckpointMismatchError", "ChurnSpec",
    "ClientProfile", "CommModel", "DataSpec", "DriftSpec",
    "DropoutSchedule", "ExperimentResult", "ExperimentSession",
    "ExperimentSpec", "LinkSpec", "MannWhitneyResult", "PRESETS",
    "ROUND_FIELDS", "RoundRecord", "SCENARIO_PRESETS", "STRATEGY_REGISTRY",
    "ScenarioSpec", "ScheduleSpec", "SpecError", "SpecIssue", "Strategy",
    "StrategyConfig", "SweepPoint", "SweepResult", "TOPOLOGY_PRESETS",
    "TierSpec", "TopologySpec", "World", "WorldSpec",
    "WorldState", "build_spmd_components", "build_world", "get_strategy",
    "list_strategies", "mann_whitney_u", "median_iqr",
    "register_strategy", "resolve_scenario", "resolve_strategy",
    "resolve_topology", "run_experiment", "run_scanned_seed_batch",
    "run_spmd_seed_batch", "run_sweep", "seed_vectorizable",
]
