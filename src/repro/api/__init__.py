"""Public experiment API: declarative specs, pluggable strategies, one
facade over both engines.

    from repro.api import ExperimentSpec, run_experiment

    result = run_experiment(ExperimentSpec(strategy="ours", rounds=8))
    print(result.final.accuracy, result.final.bytes_sent)
"""
from repro.api.result import (ROUND_FIELDS, ExperimentResult, RoundRecord)
from repro.api.runner import build_spmd_components, run_experiment
from repro.api.spec import DataSpec, ExperimentSpec, WorldSpec
from repro.api.strategies import (PRESETS, STRATEGY_REGISTRY, Strategy,
                                  get_strategy, list_strategies,
                                  register_strategy, resolve_strategy)
from repro.api.world import World, build_world
from repro.core.async_engine import (ClientProfile, CommModel,
                                     StrategyConfig)

__all__ = [
    "ClientProfile", "CommModel", "DataSpec", "ExperimentResult",
    "ExperimentSpec", "PRESETS", "ROUND_FIELDS", "RoundRecord",
    "STRATEGY_REGISTRY", "Strategy", "StrategyConfig", "World",
    "WorldSpec", "build_spmd_components", "build_world", "get_strategy",
    "list_strategies", "register_strategy", "resolve_strategy",
    "run_experiment",
]
