"""Engine drivers behind the experiment API.

``run_experiment(spec)`` is now a thin wrapper over
``ExperimentSession`` (api/session.py) — open, run to the spec's round
budget, collect the result. The engine-specific machinery lives here:

``build_simulation(spec)``  — the event-driven ``FederatedSimulation``
    (heterogeneous timing, dropout, async/semi-async quorum,
    checkpointing — the paper's apparatus), constructed from a spec.

``SpmdDriver``              — stepping driver for the compiled
    ``fl_step`` path: one jitted step per round over a (C, B, ...)
    cohort batch, with the SAME CommModel applied analytically for
    sync-barrier timing and byte accounting, so both engines emit the
    normalized ``RoundRecord`` schema. Exposes ``run_rounds`` /
    ``state_dict`` / ``load_state_dict`` for session streaming and
    bit-exact checkpoint/resume.

``run_spmd_seed_batch``     — the vectorized multi-seed path used by
    ``run_sweep``: same-shape replicas over S seeds advance as ONE
    vmapped, seed-stacked ``FLState`` (the seed axis folded into the
    cohort dispatch), so an S-seed sweep pays one compiled dispatch per
    round instead of S.

Degenerate parity: with uniform profiles, zero latency, theta=None and
one local step (``max_samples_per_round == batch_size``), the two engines
produce identical round records — the sim runs one SGD step per client
and FedAvg-averages the resulting parameters, which equals the spmd
path's SGD step on the client-mean gradient (momentum is reset per round
in the sim's local runs, so the spmd engine uses momentum=0).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.result import ExperimentResult, RoundRecord
from repro.api.spec import ExperimentSpec
from repro.core import async_engine as ae
from repro.core import compression, fl_step
from repro.core import megastep as megastep_mod
from repro.core import scenario as scenario_mod
from repro.data.loader import ArrayLoader
from repro.kernels import arena as arena_mod
from repro.models import api as model_api
from repro.optim import adamw as optim_mod


def run_experiment(spec: ExperimentSpec) -> ExperimentResult:
    """One-shot facade: open a session, run ``spec.rounds``, return the
    normalized result. For streaming, callbacks, checkpoint/resume or
    sweeps use ``ExperimentSession`` / ``run_sweep`` directly."""
    from repro.api.session import ExperimentSession

    session = ExperimentSession.open(spec)
    session.run(spec.rounds)
    return session.result()


# ---------------------------------------------------------------------------
# engine="sim"
# ---------------------------------------------------------------------------

def build_simulation(spec: ExperimentSpec) -> "ae.FederatedSimulation":
    """The event-driven simulation an ``engine='sim'`` spec describes."""
    cfg = spec.resolve_model()
    world = spec.build_world()
    return ae.FederatedSimulation(cfg, world.client_arrays,
                                  world.eval_arrays,
                                  spec.resolve_strategy(), world.profiles,
                                  comm=spec.resolve_comm(), seed=spec.seed,
                                  eval_fn=spec.eval_fn,
                                  eval_every=spec.eval_every,
                                  megastep=spec.megastep,
                                  rounds_per_dispatch=spec.rounds_per_dispatch,
                                  schedule=spec.resolve_schedule(),
                                  scenario=spec.resolve_scenario(),
                                  candidate_frac=spec.candidate_frac,
                                  candidate_shards=spec.candidate_shards,
                                  topology=spec.resolve_topology(),
                                  fused_eval=spec.fused_eval)


def record_from_metrics(m: "ae.RoundMetrics") -> RoundRecord:
    return RoundRecord(round=m.round, sim_time=m.sim_time,
                       comm_time=m.comm_time, idle_time=m.idle_time,
                       bytes_sent=m.bytes_sent,
                       updates_applied=m.updates_applied,
                       accept_rate=m.accept_rate, accuracy=m.accuracy,
                       loss=m.loss)


# ---------------------------------------------------------------------------
# engine="spmd"
# ---------------------------------------------------------------------------

def _resolve_optimizer(spec: ExperimentSpec, st):
    opt = spec.optimizer
    if opt is None or opt == "sgd":
        # momentum=0 mirrors the simulator's per-round optimizer reset,
        # which is what makes the degenerate sim/spmd parity exact
        return optim_mod.sgd(st.lr, momentum=0.0)
    if isinstance(opt, str):
        if opt == "adamw":
            return optim_mod.adamw(st.lr)
        if opt == "adafactor":
            return optim_mod.adafactor(st.lr)
        raise ValueError(f"unknown optimizer {opt!r}; expected "
                         "'sgd', 'adamw', 'adafactor' or an Optimizer")
    return opt


def _spmd_control_plane(spec: ExperimentSpec, st, world,
                        round_time_hint=()) -> "fl_step.ControlPlane":
    """Device control-plane options for the compiled path: selection,
    dropout, per-client LR and wire quantization as cohort masking."""
    C = world.num_clients if world is not None else spec.world.num_clients
    k = C
    if st.grad_norm_selection or (st.selection and st.select_fraction < 1.0):
        k = max(1, int(st.select_fraction * C))
    dropout = ()
    if world is not None and any(p.dropout_p > 0 for p in world.profiles):
        dropout = tuple(float(p.dropout_p) for p in world.profiles)
    elif spec.world.dropout_p > 0:
        dropout = (float(spec.world.dropout_p),) * C
    return fl_step.ControlPlane(
        num_clients=C, select_k=k,
        candidate_frac=spec.candidate_frac,
        candidate_shards=spec.candidate_shards,
        grad_norm_selection=st.grad_norm_selection,
        dropout_p=dropout, quantize=st.quantize_updates,
        per_client_lr=st.per_client_lr,
        round_time_hint=tuple(float(t) for t in round_time_hint),
        seed=spec.seed)


def build_spmd_components(spec: ExperimentSpec, world=None,
                          round_time_hint=()):
    """(cfg, strategy, optimizer, state, jitted step) for custom loops —
    the supported way to reach the compiled path from user code (used by
    examples/hierarchical_pods.py). Strategies that use selection /
    dropout / quantized updates / per-client LR get the device control
    plane attached automatically (fl_step.ControlPlane)."""
    cfg = spec.resolve_model()
    st = spec.resolve_strategy()
    comm = spec.resolve_comm()
    opt = _resolve_optimizer(spec, st)
    cp = _spmd_control_plane(spec, st, world, round_time_hint)
    C = cp.num_clients
    if not cp.active():
        cp = None
    scn = spec.resolve_scenario()
    dirs = None
    if scn is not None and scn.drift is not None:
        dirs = scenario_mod.drift_directions(scn.drift, cfg.num_classes,
                                             cfg.num_features)
    topo = spec.resolve_topology()
    state = fl_step.init_state(jax.random.PRNGKey(spec.seed), cfg, opt,
                               control_plane=cp, scenario=scn,
                               num_clients=C, topology=topo, comm=comm)
    # donate the previous FLState through the compiled step — without it
    # every dispatch copies the full parameter arena (the driver rebinds
    # self.state from the step output, so the input buffers are dead)
    step = fl_step.build_fl_train_step(cfg, opt, theta=st.theta,
                                       lr_schedule=spec.lr_schedule,
                                       donate=donate_default(),
                                       beacon_bytes=comm.beacon_bytes,
                                       control_plane=cp,
                                       scenario=scn, drift_dirs=dirs,
                                       topology=topo, comm=comm,
                                       num_clients=C)
    return cfg, st, opt, state, step


def donate_default() -> bool:
    """Donate input buffers to compiled steps wherever the platform
    honors donation (CPU silently ignores it with a warning). Every
    driver below rebinds its state from the step's output before any
    other use, and checkpointing reads the live post-step state
    (``jax.device_get`` in ``state_dict``), so donation is safe."""
    return jax.default_backend() != "cpu"


def _build_eval(cfg, eval_fn):
    if eval_fn is not None:
        return jax.jit(eval_fn)
    return model_api.build_default_eval(cfg)


def _account_comm_round(profiles, comm, steps, n_samples, mask,
                        participating, payload_bytes, acc,
                        lat_scale=None, bw_scale=None) -> None:
    """One sync round's analytic CommModel arithmetic, shared by the
    per-seed driver and the vmapped seed batch: each participating
    client pays train time + transfer (full payload if its update
    passed the mask, else the 1-bit skip beacon); the round advances at
    the barrier (slowest arrival), idle time is the spread below it.
    ``lat_scale``/``bw_scale`` are this round's per-client link-quality
    multipliers (scenario link walks; None -> static links).
    Accumulates into ``acc``'s sim/comm/idle time entries."""
    arrivals = []
    for cid, prof in enumerate(profiles):
        if not participating[cid]:
            continue        # unselected / dropped / churned: silent
        t_train = (steps * comm.t_launch
                   + n_samples * comm.t_sample) / max(prof.speed, 1e-3)
        payload = payload_bytes if mask[cid] > 0 else comm.beacon_bytes
        lat = prof.net_latency * (float(lat_scale[cid])
                                  if lat_scale is not None else 1.0)
        bw = comm.bandwidth * (float(bw_scale[cid])
                               if bw_scale is not None else 1.0)
        transfer = lat + payload / bw
        acc["comm_time"] += transfer
        arrivals.append(t_train + transfer)
    barrier = max(arrivals) if arrivals else 0.0
    acc["sim_time"] += barrier
    acc["idle_time"] += sum(barrier - a for a in arrivals)


def _spmd_loaders(spec: ExperimentSpec, st, world) -> List[ArrayLoader]:
    loaders = [ArrayLoader(arrays, st.batch_size, seed=spec.seed + cid)
               for cid, arrays in enumerate(world.client_arrays)]
    sizes = {l.batch_size for l in loaders}
    if len(sizes) > 1:
        raise ValueError(
            f"engine='spmd' needs one cohort batch shape, but client shard "
            f"sizes clamp batch_size to {sorted(sizes)}; lower "
            f"strategy batch_size or raise data.n_samples")
    return loaders


class SpmdDriver:
    """Stepping driver for the compiled spmd engine.

    Owns the compiled step, the per-client host loaders (the only
    stochastic state outside ``FLState``), and the analytic CommModel
    accounting. ``run_rounds(n)`` advances n rounds and returns their
    ``RoundRecord``s; ``state_dict``/``load_state_dict`` serialize
    (FLState, loader RNG positions, accumulators) so a restored driver
    continues bit-identically to an uninterrupted one.
    """

    def __init__(self, spec: ExperimentSpec):
        self.spec = spec
        self.comm = spec.resolve_comm()
        st = spec.resolve_strategy()
        self.world = spec.build_world()
        self.num_clients = self.world.num_clients
        self.loaders = _spmd_loaders(spec, st, self.world)
        bs = self.loaders[0].batch_size
        # union of the simulator's local steps as ONE cohort gradient
        # step; min across clients keeps the (C, steps*bs, ...) batch
        # rectangular
        self.steps = min(ae.local_step_count(l.n, bs, st)
                         for l in self.loaders)
        self.n_samples = self.steps * bs

        # analytic per-client round time (train + transfer) — the control
        # plane's timeliness signal for reliability-scored selection
        hint = [(self.steps * self.comm.t_launch
                 + self.n_samples * self.comm.t_sample)
                / max(p.speed, 1e-3) + p.net_latency
                for p in self.world.profiles]
        self.cfg, self.st, self._opt, self.state, self.step = \
            build_spmd_components(spec, world=self.world,
                                  round_time_hint=hint)
        self.evaluate = _build_eval(self.cfg, spec.eval_fn)
        self.eval_dev = jax.tree.map(jnp.asarray, self.world.eval_arrays)
        self.param_bytes = sum(x.size * x.dtype.itemsize
                               for x in jax.tree.leaves(self.state.params))
        self.payload_bytes = (compression.arena_wire_bytes(
            arena_mod.ParamArena(self.state.params))
            if self.st.quantize_updates else self.param_bytes)
        scn = spec.resolve_scenario()
        self._has_link_walks = scn is not None and scn.links is not None
        self.round_idx = 0
        self.acc = {"sim_time": 0.0, "comm_time": 0.0, "idle_time": 0.0,
                    "bytes_sent": 0.0}
        self._last_accuracy = float("nan")

    # ------------------------------------------------------------------
    @property
    def params(self):
        return self.state.params

    @property
    def eval_arrays(self):
        return self.world.eval_arrays

    def _draw_batch(self):
        per_client = []
        for loader in self.loaders:
            draws = [loader.sample() for _ in range(self.steps)]
            per_client.append({k: np.concatenate([d[k] for d in draws])
                               for k in draws[0]})
        return {k: jnp.asarray(np.stack([c[k] for c in per_client]))
                for k in per_client[0]}

    def _account(self, rnd: int, m, evaluate: bool) -> RoundRecord:
        mask = np.asarray(m["mask"])
        selected = np.asarray(m["selected"])
        delivered = np.asarray(m["delivered"])
        lat_scale = bw_scale = None
        if self._has_link_walks:
            # the world the compiled step just ran under (FLState.world
            # is post-transition): link walks re-price this round's
            # transfer; churned-out clients already have delivered=0,
            # and without link walks the scales are all-ones — skip the
            # per-round device->host fetch entirely
            wv = scenario_mod.host_view(self.state.world)
            lat_scale, bw_scale = wv["lat_scale"], wv["bw_scale"]
        acc = self.acc
        _account_comm_round(self.world.profiles, self.comm, self.steps,
                            self.n_samples, mask,
                            participating=(selected * delivered) > 0,
                            payload_bytes=self.payload_bytes, acc=acc,
                            lat_scale=lat_scale, bw_scale=bw_scale)
        acc["bytes_sent"] += float(m["bytes_sent"])

        if evaluate:
            self._last_accuracy = float(
                self.evaluate(self.state.params, self.eval_dev))
        return RoundRecord(
            round=rnd, sim_time=acc["sim_time"],
            comm_time=acc["comm_time"], idle_time=acc["idle_time"],
            bytes_sent=acc["bytes_sent"],
            # the COUNT of client updates applied this round (the sim
            # engine's semantics), not a 0/1 any-update flag
            updates_applied=int(mask.sum()),
            accept_rate=float(m["accept_rate"]),
            accuracy=self._last_accuracy, loss=float(m["loss"]))

    def run_rounds(self, n: int, eval_final: bool = True
                   ) -> List[RoundRecord]:
        """Advance n rounds. Evaluation follows the ABSOLUTE eval_every
        cadence; ``eval_final`` additionally evaluates the batch's last
        round (so a completed run's ``result.final`` is measured) —
        session streaming passes False on intermediate chunks to keep
        the accuracy series identical to a single-batch run."""
        records = []
        first, last = self.round_idx, self.round_idx + n - 1
        for rnd in range(first, last + 1):
            batch = self._draw_batch()
            self.state, m = self.step(self.state, batch)
            evaluate = ((rnd % self.spec.eval_every == 0)
                        or (eval_final and rnd == last))
            records.append(self._account(rnd, m, evaluate))
        self.round_idx = last + 1
        return records

    def client_pass_rates(self) -> np.ndarray:
        """(num_clients,) θ pass-rate EMAs from the device control
        plane (see FederatedSimulation.client_pass_rates)."""
        if self.state.control is None:
            raise ValueError(
                "the spmd control plane is inactive (no selection / "
                "dropout / quantize / per-client LR), so no pass-rate "
                "EMAs are tracked")
        return np.asarray(self.state.control.pass_rate)

    # ------------------------------------------------------------------
    # serialization (ExperimentSession.checkpoint/restore)
    # ------------------------------------------------------------------
    def state_dict(self) -> dict:
        return {
            "round_idx": self.round_idx,
            "fl_state": jax.device_get(self.state),
            "loaders": [l.rng.bit_generator.state for l in self.loaders],
            "acc": dict(self.acc),
            "last_accuracy": self._last_accuracy,
        }

    def load_state_dict(self, state: dict) -> None:
        self.round_idx = state["round_idx"]
        self.state = jax.tree.map(jnp.asarray, state["fl_state"])
        if len(state["loaders"]) != len(self.loaders):
            raise ValueError(
                f"checkpoint has {len(state['loaders'])} client loaders, "
                f"this world has {len(self.loaders)}")
        for l, s in zip(self.loaders, state["loaders"]):
            g = np.random.default_rng(0)
            g.bit_generator.state = s
            l.rng = g
        self.acc = dict(state["acc"])
        self._last_accuracy = state["last_accuracy"]

    def result(self, records, wall_time: float = 0.0) -> ExperimentResult:
        return ExperimentResult(
            engine="spmd", strategy=self.spec.strategy_name(),
            rounds=len(records), seed=self.spec.seed, records=list(records),
            cfg=self.cfg, params=self.state.params,
            eval_arrays=self.world.eval_arrays,
            num_clients=self.num_clients, param_bytes=self.param_bytes,
            wall_time=wall_time)


# ---------------------------------------------------------------------------
# vectorized multi-seed execution (run_sweep's spmd fast path)
# ---------------------------------------------------------------------------

def seed_vectorizable(spec: ExperimentSpec, st=None) -> bool:
    """True when same-shape multi-seed replicas of ``spec`` can advance
    as ONE vmapped seed-stacked state: the compiled spmd path with an
    INACTIVE control plane (selection / dropout / quantization /
    per-client LR draw from a per-run PRNG whose seed is compile-time
    static, so replicas would share draws — those sweeps run serially)."""
    if spec.engine != "spmd":
        return False
    st = st or spec.resolve_strategy()
    if st.grad_norm_selection or (st.selection and st.select_fraction < 1.0):
        return False
    if st.quantize_updates or st.per_client_lr:
        return False
    if spec.world.dropout_p > 0:
        return False
    if spec.resolve_scenario() is not None:
        return False        # dynamic worlds run serially (FLState.world)
    if spec.resolve_topology() is not None:
        return False        # per-seed TopologyState: no stacked fast path
    return True


def run_spmd_seed_batch(spec: ExperimentSpec,
                        seeds: Sequence[int]) -> List[ExperimentResult]:
    """Execute ``spec`` at every seed as ONE vmapped seed-stacked run.

    Per-seed worlds (data, partition, eval split) are built on the host
    and stacked along a leading seed axis; parameters and optimizer
    state initialize per seed and advance through
    ``fl_step.build_seed_batched_step`` — one compiled dispatch per
    round for ALL seeds. Requires :func:`seed_vectorizable` specs and
    identical cohort shapes across seeds. Each returned result's
    ``wall_time`` is the whole batch's wall clock (the dispatches are
    shared, so per-seed attribution is meaningless).
    """
    t0 = time.time()
    st = spec.resolve_strategy()
    if not seed_vectorizable(spec, st):
        raise ValueError(
            "spec is not seed-vectorizable (needs engine='spmd' with an "
            "inactive control plane); run the seeds serially instead")
    specs = [dataclasses.replace(spec, seed=int(s)).validate()
             for s in seeds]
    cfg = spec.resolve_model()
    comm = spec.resolve_comm()
    opt = _resolve_optimizer(spec, st)
    worlds = [s.build_world() for s in specs]
    C = worlds[0].num_clients
    loaders = [_spmd_loaders(s, st, w) for s, w in zip(specs, worlds)]
    steps_per_seed = {min(ae.local_step_count(l.n, ls[0].batch_size, st)
                          for l in ls) for ls in loaders}
    if len(steps_per_seed) > 1:
        raise ValueError(
            f"seeds produce different cohort shapes (local steps "
            f"{sorted(steps_per_seed)}); the vmapped sweep needs one — "
            f"raise data.n_samples or run serially")
    steps = steps_per_seed.pop()
    bs = loaders[0][0].batch_size
    n_samples = steps * bs

    state = fl_step.init_seed_batched_state(
        [s.seed for s in specs], cfg, opt)
    vstep = fl_step.build_seed_batched_step(
        cfg, opt, theta=st.theta, lr_schedule=spec.lr_schedule,
        beacon_bytes=comm.beacon_bytes)
    evaluate = _build_eval(cfg, spec.eval_fn)
    veval = jax.jit(jax.vmap(evaluate))
    eval_dev = jax.tree.map(
        lambda *xs: jnp.asarray(np.stack(xs)),
        *[w.eval_arrays for w in worlds])
    param_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(state.params)) // len(specs)

    S = len(specs)
    acc = [{"sim_time": 0.0, "comm_time": 0.0, "idle_time": 0.0,
            "bytes_sent": 0.0} for _ in range(S)]
    last_acc = [float("nan")] * S
    records: List[List[RoundRecord]] = [[] for _ in range(S)]
    # the round loop keeps every metric ON DEVICE — a per-round
    # np.asarray would block the dispatch stream on the transfer (the
    # paper's per-round sync anti-pattern); buffers drain in ONE
    # device_get after the last round, and the dispatch count stays
    # exactly (rounds + eval rounds) — asserted below
    dispatches = 0
    metric_buf, acc_buf = [], {}
    eval_rounds = [rnd for rnd in range(spec.rounds)
                   if (rnd % spec.eval_every == 0)
                   or (rnd == spec.rounds - 1)]
    for rnd in range(spec.rounds):
        stacked = []
        for ls in loaders:
            per_client = []
            for loader in ls:
                draws = [loader.sample() for _ in range(steps)]
                per_client.append({k: np.concatenate([d[k] for d in draws])
                                   for k in draws[0]})
            stacked.append({k: np.stack([c[k] for c in per_client])
                            for k in per_client[0]})
        batch = {k: jnp.asarray(np.stack([s[k] for s in stacked]))
                 for k in stacked[0]}
        state, m = vstep(state, batch)
        dispatches += 1
        metric_buf.append(m)
        if rnd in eval_rounds:
            acc_buf[rnd] = veval(state.params, eval_dev)
            dispatches += 1
    assert dispatches == spec.rounds + len(eval_rounds), \
        "buffered readback must not change the dispatch count"
    metric_buf, acc_buf = jax.device_get((metric_buf, acc_buf))

    for rnd, m in enumerate(metric_buf):
        mask = np.asarray(m["mask"])                       # (S, C)
        for i in range(S):
            a = acc[i]
            # seed_vectorizable guarantees no selection/dropout (all
            # clients participate) and no quantization (full payload)
            _account_comm_round(worlds[i].profiles, comm, steps,
                                n_samples, mask[i],
                                participating=np.ones(C, bool),
                                payload_bytes=param_bytes, acc=a)
            a["bytes_sent"] += float(m["bytes_sent"][i])
            if rnd in acc_buf:
                last_acc[i] = float(acc_buf[rnd][i])
            records[i].append(RoundRecord(
                round=rnd, sim_time=a["sim_time"],
                comm_time=a["comm_time"], idle_time=a["idle_time"],
                bytes_sent=a["bytes_sent"],
                updates_applied=int(mask[i].sum()),
                accept_rate=float(m["accept_rate"][i]),
                accuracy=last_acc[i],
                loss=float(m["loss"][i])))

    elapsed = time.time() - t0
    out = []
    for i, s in enumerate(specs):
        params_i = jax.tree.map(lambda x: x[i], state.params)
        out.append(ExperimentResult(
            engine="spmd", strategy=s.strategy_name(), rounds=s.rounds,
            seed=s.seed, records=records[i], cfg=cfg, params=params_i,
            eval_arrays=worlds[i].eval_arrays, num_clients=C,
            param_bytes=param_bytes, wall_time=elapsed))
    return out


# ---------------------------------------------------------------------------
# vectorized multi-seed execution of the SCANNED sim engine
# ---------------------------------------------------------------------------

def run_scanned_seed_batch(spec: ExperimentSpec,
                           seeds: Sequence[int]) -> List[ExperimentResult]:
    """Execute the scanned sim engine at every seed as ONE vmapped
    dispatch stream (the whole-experiment-fusion analogue of
    :func:`run_spmd_seed_batch`).

    Eval is fused into the scan carry (``fused_eval`` is forced on), so
    an S-seed sweep cell of N rounds costs ``ceil(N / R)`` compiled
    dispatches TOTAL — no per-seed, per-dispatch eval readback breaks
    the stream; per-round metrics buffer on device and drain in one
    ``device_get`` at the end. Per-seed worlds (data, profiles, control
    state, PRNG keys) stack along a leading seed axis; the per-client
    sample capacity pads to the cross-seed maximum, which never changes
    a trajectory because batch index sampling is bounded by each seed's
    true shard sizes. Requires every seed to resolve the same scanned
    trace shape (select_k, steps_phys, batch_phys).
    """
    t0 = time.time()
    if spec.engine != "sim" or not spec.rounds_per_dispatch:
        raise ValueError(
            "run_scanned_seed_batch vectorizes the scanned sim engine — "
            "the spec needs engine='sim' and rounds_per_dispatch")
    specs = [dataclasses.replace(spec, seed=int(s),
                                 fused_eval=True).validate()
             for s in seeds]
    sims = [build_simulation(s) for s in specs]
    for sim in sims:
        sim._scan_setup()
    shapes = {sim._scan_shapes() for sim in sims}
    if len(shapes) > 1:
        raise ValueError(
            f"seeds resolve different scanned trace shapes "
            f"{sorted(shapes)} (select_k, steps_phys, batch_phys must "
            "agree); equalize data sizes across seeds or run serially")
    sim0 = sims[0]
    R = sim0.rounds_per_dispatch
    S = len(sims)

    # --- stack the per-seed device worlds along a leading seed axis ---
    def _pad_cap(a, cap):
        pad = cap - a.shape[1]
        if pad == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[1] = (0, pad)
        return jnp.pad(a, widths)

    keys = list(sims[0]._scan_world[0])
    cap = max(sim._scan_world[0][keys[0]].shape[1] for sim in sims)
    data = {k: jnp.stack([_pad_cap(sim._scan_world[0][k], cap)
                          for sim in sims]) for k in keys}
    sizes, speed, latency, dropout_p = (
        jnp.stack([sim._scan_world[1 + a] for sim in sims])
        for a in range(4))
    stack = lambda xs: jax.tree.map(lambda *ls: jnp.stack(ls), *xs)
    ctl = stack([sim._scan_ctl for sim in sims])
    ws = stack([sim._world_state for sim in sims])
    topo = (stack([sim._topo_state for sim in sims])
            if sim0._topo_state is not None else None)
    params_mat = jnp.stack([sim._params_mat for sim in sims])
    blank_ref = jnp.where(jnp.asarray(sim0._arena.valid_mask()),
                          jnp.int8(0), jnp.int8(-2))
    ref_mat = jnp.stack([blank_ref] * S)
    ref_valid = jnp.stack([sim._scan_ref_valid for sim in sims])
    base_key = jnp.stack([sim._scan_key for sim in sims])
    eval_data = stack([sim._eval_dev for sim in sims])
    acc = jnp.zeros((S, 4), jnp.float32)
    prev_acc = jnp.full((S,), jnp.nan, jnp.float32)

    # --- one jitted vmap of the raw scanned run per chunk width -------
    k_sel, steps_phys, batch_phys = sim0._scan_shapes()
    vruns = {}

    def vrun(Rg):
        if Rg not in vruns:
            raw = megastep_mod.build_scanned_rounds(
                sim0.cfg, sim0.opt, sim0._arena, sim0.strategy, sim0.comm,
                num_clients=sim0.num_clients, select_k=k_sel,
                steps_phys=steps_phys, batch_phys=batch_phys,
                rounds_per_dispatch=Rg, param_bytes=sim0.param_bytes,
                wire_bytes=sim0._wire_bytes,
                recovery_time=sim0.recovery_time,
                restart_time=sim0.restart_time,
                schedule=sim0.schedule, scenario=sim0.scenario,
                drift_dirs=sim0._drift_dirs,
                drift_label=sim0._drift_label or "y",
                candidate_frac=sim0.candidate_frac,
                candidate_shards=sim0.candidate_shards,
                topology=sim0._topo,
                eval_fn=sim0._eval, eval_every=sim0.eval_every,
                jit=False)
            axes = (0, 0, 0, 0, 0, (0 if topo is not None else None),
                    0, 0, 0, 0, 0, 0, None, 0, 0, None, 0)
            vruns[Rg] = jax.jit(
                jax.vmap(raw, in_axes=axes),
                donate_argnums=megastep_mod.scan_donate_argnums(
                    fused=True))
        return vruns[Rg]

    ms_buf = []
    round0 = 0
    while round0 < spec.rounds:
        Rg = min(R, spec.rounds - round0)
        mark = (spec.rounds - 1 if round0 + Rg == spec.rounds else -1)
        carry, ms = vrun(Rg)(
            params_mat, ref_mat, ref_valid, ctl, ws, topo,
            data, sizes, speed, latency, dropout_p, base_key,
            jnp.int32(round0), acc, prev_acc, jnp.int32(mark), eval_data)
        (params_mat, ref_mat, ref_valid, ctl, ws, topo, acc,
         prev_acc) = carry
        ms_buf.append(ms)            # device-side; one readback below
        round0 += Rg
    ms_buf, params_final = jax.device_get((ms_buf, params_mat))

    records: List[List[RoundRecord]] = [[] for _ in range(S)]
    rnd = 0
    for ms in ms_buf:
        Rg = ms["loss"].shape[1]
        for j in range(Rg):
            for i in range(S):
                records[i].append(RoundRecord(
                    round=rnd + j,
                    sim_time=float(ms["sim_time"][i, j]),
                    comm_time=float(ms["comm_time"][i, j]),
                    idle_time=float(ms["idle_time"][i, j]),
                    bytes_sent=float(ms["bytes_sent"][i, j]),
                    updates_applied=int(ms["updates_applied"][i, j]),
                    accept_rate=float(ms["accept_rate"][i, j]),
                    accuracy=float(ms["accuracy"][i, j]),
                    loss=float(ms["loss"][i, j])))
        rnd += Rg

    elapsed = time.time() - t0
    out = []
    for i, (s, sim) in enumerate(zip(specs, sims)):
        out.append(ExperimentResult(
            engine="sim", strategy=s.strategy_name(), rounds=s.rounds,
            seed=s.seed, records=records[i], cfg=sim.cfg,
            params=sim._arena.unpack(jnp.asarray(params_final[i])),
            eval_arrays=sim.eval_arrays, num_clients=sim.num_clients,
            param_bytes=sim.param_bytes, wall_time=elapsed))
    return out
