"""``run_experiment(spec)`` — one facade over both engines.

engine="sim"   builds the client world and drives the event-driven
               ``FederatedSimulation`` (heterogeneous timing, dropout,
               async quorum, checkpointing — the paper's apparatus).

engine="spmd"  drives the compiled ``fl_step`` path: one jitted step per
               round over a (C, B, ...) cohort batch, with the SAME
               CommModel applied analytically for sync-barrier timing and
               byte accounting, so both engines emit the normalized
               ``RoundRecord`` schema.

Degenerate parity: with uniform profiles, zero latency, theta=None and
one local step (``max_samples_per_round == batch_size``), the two engines
produce identical round records — the sim runs one SGD step per client
and FedAvg-averages the resulting parameters, which equals the spmd
path's SGD step on the client-mean gradient (momentum is reset per round
in the sim's local runs, so the spmd engine uses momentum=0).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.result import ExperimentResult, RoundRecord
from repro.api.spec import ExperimentSpec
from repro.core import async_engine as ae
from repro.core import compression, fl_step
from repro.data.loader import ArrayLoader
from repro.kernels import arena as arena_mod
from repro.models import api as model_api
from repro.optim import adamw as optim_mod


def run_experiment(spec: ExperimentSpec) -> ExperimentResult:
    spec.validate()
    t0 = time.time()
    if spec.engine == "sim":
        result = _run_sim(spec)
    else:
        result = _run_spmd(spec)
    result.wall_time = time.time() - t0
    return result


# ---------------------------------------------------------------------------
# engine="sim"
# ---------------------------------------------------------------------------

def _run_sim(spec: ExperimentSpec) -> ExperimentResult:
    cfg = spec.resolve_model()
    strategy = spec.resolve_strategy()
    world = spec.build_world()
    sim = ae.FederatedSimulation(cfg, world.client_arrays, world.eval_arrays,
                                 strategy, world.profiles,
                                 comm=spec.resolve_comm(), seed=spec.seed,
                                 eval_fn=spec.eval_fn,
                                 eval_every=spec.eval_every,
                                 megastep=spec.megastep,
                                 rounds_per_dispatch=spec.rounds_per_dispatch)
    hist = sim.run(spec.rounds)
    records = [RoundRecord(round=m.round, sim_time=m.sim_time,
                           comm_time=m.comm_time, idle_time=m.idle_time,
                           bytes_sent=m.bytes_sent,
                           updates_applied=m.updates_applied,
                           accept_rate=m.accept_rate, accuracy=m.accuracy,
                           loss=m.loss)
               for m in hist]
    return ExperimentResult(engine="sim", strategy=spec.strategy_name(),
                            rounds=spec.rounds, seed=spec.seed,
                            records=records, cfg=cfg, params=sim.params,
                            eval_arrays=world.eval_arrays,
                            num_clients=world.num_clients,
                            param_bytes=sim.param_bytes)


# ---------------------------------------------------------------------------
# engine="spmd"
# ---------------------------------------------------------------------------

def _resolve_optimizer(spec: ExperimentSpec, st):
    opt = spec.optimizer
    if opt is None or opt == "sgd":
        # momentum=0 mirrors the simulator's per-round optimizer reset,
        # which is what makes the degenerate sim/spmd parity exact
        return optim_mod.sgd(st.lr, momentum=0.0)
    if isinstance(opt, str):
        if opt == "adamw":
            return optim_mod.adamw(st.lr)
        if opt == "adafactor":
            return optim_mod.adafactor(st.lr)
        raise ValueError(f"unknown optimizer {opt!r}; expected "
                         "'sgd', 'adamw', 'adafactor' or an Optimizer")
    return opt


def _spmd_control_plane(spec: ExperimentSpec, st, world,
                        round_time_hint=()) -> "fl_step.ControlPlane":
    """Device control-plane options for the compiled path: selection,
    dropout, per-client LR and wire quantization as cohort masking."""
    C = world.num_clients if world is not None else spec.world.num_clients
    k = C
    if st.grad_norm_selection or (st.selection and st.select_fraction < 1.0):
        k = max(1, int(st.select_fraction * C))
    dropout = ()
    if world is not None and any(p.dropout_p > 0 for p in world.profiles):
        dropout = tuple(float(p.dropout_p) for p in world.profiles)
    elif spec.world.dropout_p > 0:
        dropout = (float(spec.world.dropout_p),) * C
    return fl_step.ControlPlane(
        num_clients=C, select_k=k,
        grad_norm_selection=st.grad_norm_selection,
        dropout_p=dropout, quantize=st.quantize_updates,
        per_client_lr=st.per_client_lr,
        round_time_hint=tuple(float(t) for t in round_time_hint),
        seed=spec.seed)


def build_spmd_components(spec: ExperimentSpec, world=None,
                          round_time_hint=()):
    """(cfg, strategy, optimizer, state, jitted step) for custom loops —
    the supported way to reach the compiled path from user code (used by
    examples/hierarchical_pods.py). Strategies that use selection /
    dropout / quantized updates / per-client LR get the device control
    plane attached automatically (fl_step.ControlPlane)."""
    cfg = spec.resolve_model()
    st = spec.resolve_strategy()
    comm = spec.resolve_comm()
    opt = _resolve_optimizer(spec, st)
    cp = _spmd_control_plane(spec, st, world, round_time_hint)
    if not cp.active():
        cp = None
    state = fl_step.init_state(jax.random.PRNGKey(spec.seed), cfg, opt,
                               control_plane=cp)
    step = fl_step.build_fl_train_step(cfg, opt, theta=st.theta,
                                       lr_schedule=spec.lr_schedule,
                                       donate=False,
                                       beacon_bytes=comm.beacon_bytes,
                                       control_plane=cp)
    return cfg, st, opt, state, step


def _build_eval(cfg, eval_fn):
    if eval_fn is not None:
        return jax.jit(eval_fn)
    return model_api.build_default_eval(cfg)


def _run_spmd(spec: ExperimentSpec) -> ExperimentResult:
    comm = spec.resolve_comm()
    st = spec.resolve_strategy()
    world = spec.build_world()
    C = world.num_clients

    loaders = [ArrayLoader(arrays, st.batch_size, seed=spec.seed + cid)
               for cid, arrays in enumerate(world.client_arrays)]
    sizes = {l.batch_size for l in loaders}
    if len(sizes) > 1:
        raise ValueError(
            f"engine='spmd' needs one cohort batch shape, but client shard "
            f"sizes clamp batch_size to {sorted(sizes)}; lower "
            f"strategy batch_size or raise data.n_samples")
    bs = loaders[0].batch_size
    # union of the simulator's local steps as ONE cohort gradient step;
    # min across clients keeps the (C, steps*bs, ...) batch rectangular
    steps = min(ae.local_step_count(l.n, bs, st) for l in loaders)
    n_samples = steps * bs

    # analytic per-client round time (train + transfer) — the control
    # plane's timeliness signal for reliability-scored selection
    hint = [(steps * comm.t_launch + n_samples * comm.t_sample)
            / max(p.speed, 1e-3) + p.net_latency
            for p in world.profiles]
    cfg, st, _opt, state, step = build_spmd_components(
        spec, world=world, round_time_hint=hint)

    evaluate = _build_eval(cfg, spec.eval_fn)
    eval_dev = jax.tree.map(jnp.asarray, world.eval_arrays)
    param_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(state.params))
    payload_bytes = (compression.arena_wire_bytes(
        arena_mod.ParamArena(state.params)) if st.quantize_updates
        else param_bytes)

    sim_time = comm_time = idle_time = bytes_sent = 0.0
    records: List[RoundRecord] = []
    for rnd in range(spec.rounds):
        per_client = []
        for loader in loaders:
            draws = [loader.sample() for _ in range(steps)]
            per_client.append({k: np.concatenate([d[k] for d in draws])
                               for k in draws[0]})
        batch = {k: jnp.asarray(np.stack([c[k] for c in per_client]))
                 for k in per_client[0]}
        state, m = step(state, batch)

        mask = np.asarray(m["mask"])
        selected = np.asarray(m["selected"])
        delivered = np.asarray(m["delivered"])
        participating = (selected * delivered) > 0
        arrivals = []
        for cid in range(C):
            if not participating[cid]:
                continue        # unselected / dropped: silent this round
            prof = world.profiles[cid]
            t_train = (steps * comm.t_launch
                       + n_samples * comm.t_sample) / max(prof.speed, 1e-3)
            payload = payload_bytes if mask[cid] > 0 else comm.beacon_bytes
            transfer = prof.net_latency + payload / comm.bandwidth
            comm_time += transfer
            arrivals.append(t_train + transfer)
        barrier = max(arrivals) if arrivals else 0.0
        sim_time += barrier
        idle_time += sum(barrier - a for a in arrivals)
        bytes_sent += float(m["bytes_sent"])

        if rnd % spec.eval_every == 0 or rnd == spec.rounds - 1:
            acc = float(evaluate(state.params, eval_dev))
        else:
            acc = records[-1].accuracy if records else float("nan")
        records.append(RoundRecord(
            round=rnd, sim_time=sim_time, comm_time=comm_time,
            idle_time=idle_time, bytes_sent=bytes_sent,
            updates_applied=int(mask.sum() > 0),
            accept_rate=float(m["accept_rate"]), accuracy=acc,
            loss=float(m["loss"])))

    return ExperimentResult(engine="spmd", strategy=spec.strategy_name(),
                            rounds=spec.rounds, seed=spec.seed,
                            records=records, cfg=cfg, params=state.params,
                            eval_arrays=world.eval_arrays, num_clients=C,
                            param_bytes=param_bytes)
