"""``run_sweep`` — declarative cross-products over experiment axes.

The paper's tables are sweeps: seeds × strategies (Table II/VII),
thresholds (Table IV), schedules (Fig. 2). Instead of hand-rolled host
loops, declare the axes and let the driver execute the cross-product::

    sweep = run_sweep(base_spec,
                      axes={"strategy": ["ours", "fedavg"],
                            "seed": range(5)})
    cmp = sweep.compare("strategy", "ours", "fedavg",
                        metric="accuracy", alternative="greater")
    print(sweep.report("accuracy"), cmp.p_value)

Axis names are ExperimentSpec fields, dotted sub-spec fields
(``data.alpha``, ``world.num_clients``, ``strategy_kwargs.batch_size``)
or ``schedule`` / ``seed`` / ``strategy``. Values go through
``dataclasses.replace`` so every point is a full, validated spec.

Vectorized multi-seed execution: points that differ ONLY by seed and
describe a seed-vectorizable spmd spec (see
``runner.seed_vectorizable``) run as ONE vmapped seed-stacked state —
the seed axis folds into the cohort dispatch, so an S-seed group pays
one compiled dispatch per round instead of S
(``runner.run_spmd_seed_batch``; throughput tracked in BENCH_sim.json
via ``benchmarks/run.py --sweep``). Everything else runs serially
through ``run_experiment``.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Union

import numpy as np

from repro.api import runner as runner_mod
from repro.api import stats
from repro.api.result import ExperimentResult
from repro.api.spec import ExperimentSpec


def _apply_axis(spec: ExperimentSpec, name: str,
                value: Any) -> ExperimentSpec:
    if "." in name:
        parent, leaf = name.split(".", 1)
        sub = getattr(spec, parent)
        if isinstance(sub, dict):
            sub = {**sub, leaf: value}
        else:
            sub = dataclasses.replace(sub, **{leaf: value})
        return dataclasses.replace(spec, **{parent: sub})
    return dataclasses.replace(spec, **{name: value})


def build_point_spec(spec: ExperimentSpec,
                     overrides: Dict[str, Any]) -> ExperimentSpec:
    for name, value in overrides.items():
        spec = _apply_axis(spec, name, value)
    return spec


@dataclasses.dataclass
class SweepPoint:
    overrides: Dict[str, Any]          # this point's axis assignment
    spec: ExperimentSpec
    result: Optional[ExperimentResult] = None
    vectorized: bool = False           # ran inside a vmapped seed batch

    def value(self, metric) -> float:
        return _metric_value(self.result, metric)


def _metric_value(result: ExperimentResult,
                  metric: Union[str, Callable]) -> float:
    """Resolve a metric spec against a result: a RoundRecord field name
    (read off the FINAL record), "auc" (AUC-ROC on the eval split), or
    a callable ``f(result) -> float``."""
    if callable(metric):
        return float(metric(result))
    if metric == "auc":
        return float(result.auc_roc())
    return float(getattr(result.final, metric))


@dataclasses.dataclass
class SweepResult:
    base_spec: ExperimentSpec
    axes: Dict[str, List[Any]]
    points: List[SweepPoint]
    wall_time: float = 0.0
    vectorized_groups: int = 0         # seed groups run as one vmap

    # ------------------------------------------------------------------
    def filter(self, **where) -> List[SweepPoint]:
        """Points whose overrides match every ``axis=value`` given."""
        out = []
        for p in self.points:
            if all(p.overrides.get(k) == v for k, v in where.items()):
                out.append(p)
        return out

    def values(self, metric="accuracy", **where) -> np.ndarray:
        """Metric samples over the matching points (seed order)."""
        return np.array([p.value(metric) for p in self.filter(**where)])

    # ------------------------------------------------------------------
    # the paper's statistics
    # ------------------------------------------------------------------
    def mann_whitney_u(self, axis: str, a: Any, b: Any,
                       metric="accuracy", alternative: str = "greater",
                       **where) -> stats.MannWhitneyResult:
        """U test of ``axis=a`` vs ``axis=b`` samples (per remaining
        axes' cross-product, usually seeds). ``alternative='greater'``
        is the paper's H1: a stochastically larger than b."""
        va = self.values(metric, **{axis: a}, **where)
        vb = self.values(metric, **{axis: b}, **where)
        return stats.mann_whitney_u(va, vb, alternative=alternative)

    # alias mirroring the SweepResult.compare spelling in docs
    compare = mann_whitney_u

    def _grouped_points(self) -> List[tuple]:
        """(label, non-seed overrides dict, points) per group, in first-
        seen order. The overrides dict carries the REAL axis values —
        labels are display-only, never parsed back."""
        out: List[tuple] = []
        index: Dict[str, int] = {}
        for p in self.points:
            over = {k: v for k, v in p.overrides.items() if k != "seed"}
            label = ", ".join(f"{k}={v}"
                              for k, v in sorted(over.items(),
                                                 key=lambda kv: kv[0])
                              ) or "<base>"
            if label not in index:
                index[label] = len(out)
                out.append((label, over, []))
            out[index[label]][2].append(p)
        return out

    def groups(self, metric="accuracy") -> Dict[str, np.ndarray]:
        """Samples keyed by the non-seed override assignment (display
        labels; use ``filter``/``values`` for programmatic access)."""
        return {label: np.array([p.value(metric) for p in pts])
                for label, _over, pts in self._grouped_points()}

    def summary(self, metric="accuracy") -> List[List]:
        """[group, n, median, q1, q3] rows over the non-seed groups."""
        return stats.summarize(self.groups(metric))

    def report(self, metric="accuracy", baseline: Any = None,
               axis: str = "strategy") -> str:
        """Table II/VII-style comparison report: per-group median [IQR],
        plus Mann-Whitney p vs ``baseline`` along ``axis`` when given."""
        lines = [f"# sweep over {', '.join(self.axes)} — metric={metric}"
                 f" ({len(self.points)} runs, "
                 f"{self.vectorized_groups} vmapped seed group(s))"]
        header = f"{'group':40s} {'n':>3s} {'median':>10s} {'IQR':>21s}"
        pcol = baseline is not None and axis in self.axes
        if pcol:
            header += f" {'p_vs_' + str(baseline):>12s}"
        lines.append(header)
        for label, over, pts in self._grouped_points():
            med, q1, q3 = stats.median_iqr([p.value(metric) for p in pts])
            line = f"{label:40s} {len(pts):>3d} {med:>10.4f} " \
                   f"[{q1:>9.4f},{q3:>9.4f}]"
            if pcol:
                val = over.get(axis)
                if val is None or val == baseline:
                    line += f" {'-':>12s}"
                else:
                    other = {k: v for k, v in over.items() if k != axis}
                    r = self.mann_whitney_u(axis, val, baseline,
                                            metric=metric, **other)
                    line += f" {r.p_value:>12.4g}"
            lines.append(line)
        return "\n".join(lines)


def run_sweep(spec: ExperimentSpec, axes: Dict[str, Iterable[Any]],
              vectorize: Union[bool, str] = "auto",
              progress: Optional[Callable[[SweepPoint], Any]] = None
              ) -> SweepResult:
    """Execute the cross-product of ``axes`` over ``spec``.

    vectorize: "auto" (default) runs every group of points differing
    only by seed as one vmapped seed-stacked spmd state when the spec
    allows it; False forces serial execution; True raises if a group
    that should vectorize cannot.
    ``progress(point)`` is called as each point finishes.
    """
    axes = {k: list(v) for k, v in axes.items()}
    if not axes:
        raise ValueError("axes must name at least one sweep dimension")
    names = list(axes)
    points = [SweepPoint(overrides=dict(zip(names, combo)),
                         spec=build_point_spec(spec,
                                               dict(zip(names, combo))))
              for combo in itertools.product(*axes.values())]
    for p in points:
        p.spec.validate()             # surface ALL bad points up front

    t0 = time.time()
    vectorized_groups = 0
    # group points by their non-seed assignment; each group's seeds can
    # potentially fold into one vmapped dispatch stream
    groups: Dict[str, List[SweepPoint]] = {}
    for p in points:
        key = repr(sorted((k, repr(v)) for k, v in p.overrides.items()
                          if k != "seed"))
        groups.setdefault(key, []).append(p)

    for group in groups.values():
        seeds = [p.spec.seed for p in group]
        can_vmap = (len(group) > 1
                    and len(set(seeds)) == len(seeds)
                    and "seed" in axes
                    and runner_mod.seed_vectorizable(group[0].spec))
        if vectorize is True and not can_vmap and len(group) > 1:
            raise ValueError(
                "vectorize=True but a sweep group cannot run vmapped "
                f"(overrides {group[0].overrides}); use vectorize='auto'")
        if can_vmap and vectorize in (True, "auto"):
            results = runner_mod.run_spmd_seed_batch(group[0].spec, seeds)
            vectorized_groups += 1
            for p, r in zip(group, results):
                p.result, p.vectorized = r, True
                if progress is not None:
                    progress(p)
        else:
            for p in group:
                p.result = runner_mod.run_experiment(p.spec)
                if progress is not None:
                    progress(p)

    return SweepResult(base_spec=spec, axes=axes, points=points,
                       wall_time=time.time() - t0,
                       vectorized_groups=vectorized_groups)
