"""Client-world construction behind ``ExperimentSpec.build_world()``.

Centralizes what every benchmark script used to hand-roll: synthetic
UNSW-NB15 / ROAD surrogates (or a user factory), non-IID Dirichlet or
IID partitioning, and heterogeneous/uniform client profiles. Seeding
matches the historical ``benchmarks.common.make_world`` convention so
migrated scripts reproduce the same numbers: data uses ``seed``, the
eval split uses ``seed + 1``, profiles use ``seed + profile_seed_offset``
(default 1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

from repro.core.async_engine import (ClientProfile, heterogeneous_profiles,
                                     uniform_profiles)
from repro.data import partition, synthetic


@dataclasses.dataclass
class World:
    client_arrays: List[Dict[str, Any]]
    eval_arrays: Dict[str, Any]
    profiles: List[ClientProfile]

    @property
    def num_clients(self) -> int:
        return len(self.client_arrays)


def _dataset_kind(data_spec, cfg) -> str:
    kind = data_spec.dataset
    if kind != "auto":
        return kind
    if getattr(cfg, "family", None) == "mlp":
        return "road" if cfg.name.endswith("road") else "unsw"
    return "lm"


def _make_split(kind: str, data_spec, cfg, seed: int, n: int):
    if data_spec.factory is not None:
        return data_spec.factory(seed, n)
    if kind == "unsw":
        X, y = synthetic.make_unsw_like(seed, n, cfg.num_features,
                                        cfg.num_classes)
        return {"x": X, "y": y}
    if kind == "road":
        X, y = synthetic.make_road_like(seed, n, window=cfg.num_features)
        return {"x": X, "y": y}
    if kind == "lm":
        t, l = synthetic.make_lm_tokens(seed, n, data_spec.seq_len,
                                        cfg.vocab_size)
        return {"tokens": t, "labels": l}
    raise ValueError(f"unknown dataset kind {kind!r}")


def _as_arrays(split) -> Dict[str, Any]:
    if isinstance(split, dict):
        return split
    X, y = split                       # user factory returning (X, y)
    return {"x": X, "y": y}


def build_world(spec) -> World:
    """Build (client shards, eval split, client profiles) from a spec."""
    cfg = spec.resolve_model()
    d, w = spec.data, spec.world
    kind = _dataset_kind(d, cfg)
    if kind == "lm" and d.partition == "dirichlet":
        raise ValueError("dirichlet partition needs class labels; "
                         "use partition='iid' for token datasets")

    train = _as_arrays(_make_split(kind, d, cfg, spec.seed, d.n_samples))
    label_key = "y" if "y" in train else "labels"
    n = len(train[label_key])

    if d.partition == "dirichlet":
        if "y" not in train:
            raise ValueError("dirichlet partition needs class labels; "
                             "use partition='iid' for token datasets")
        parts = partition.dirichlet_partition(train["y"], w.num_clients,
                                              alpha=d.alpha, seed=spec.seed)
    elif d.partition == "iid":
        parts = partition.iid_partition(n, w.num_clients, seed=spec.seed)
    else:
        raise ValueError(f"unknown partition {d.partition!r} "
                         "(expected 'dirichlet' or 'iid')")
    clients = [{k: v[p] for k, v in train.items()} for p in parts]

    eval_arrays = _as_arrays(
        _make_split(kind, d, cfg, spec.seed + 1, d.eval_samples))

    if w.profile == "heterogeneous":
        profiles = heterogeneous_profiles(
            w.num_clients, seed=spec.seed + w.profile_seed_offset,
            dropout_p=w.dropout_p, speed_sigma=w.speed_sigma)
    elif w.profile == "uniform":
        profiles = uniform_profiles(w.num_clients, dropout_p=w.dropout_p)
    else:
        raise ValueError(f"unknown profile {w.profile!r} "
                         "(expected 'heterogeneous' or 'uniform')")
    return World(clients, eval_arrays, profiles)
