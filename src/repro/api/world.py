"""Client-world construction behind ``ExperimentSpec.build_world()``.

Centralizes what every benchmark script used to hand-roll: synthetic
UNSW-NB15 / ROAD surrogates (or a user factory), non-IID Dirichlet or
IID partitioning, and heterogeneous/uniform client profiles. Seeding
matches the historical ``benchmarks.common.make_world`` convention so
migrated scripts reproduce the same numbers: data uses ``seed``, the
eval split uses ``seed + 1``, profiles use ``seed + profile_seed_offset``
(default 1).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Dict, List

from repro.core.async_engine import (ClientProfile, ProfileView,
                                     heterogeneous_profile_arrays,
                                     heterogeneous_profiles,
                                     uniform_profile_arrays,
                                     uniform_profiles)
from repro.data import partition, synthetic


@dataclasses.dataclass
class World:
    client_arrays: List[Dict[str, Any]]
    eval_arrays: Dict[str, Any]
    profiles: List[ClientProfile]

    @property
    def num_clients(self) -> int:
        return len(self.client_arrays)


class LazyClientData:
    """Sequence of per-client array dicts, synthesized on demand.

    ``data[cid]`` calls the materializer (seeded via
    ``partition.client_seed``, so cohort membership never perturbs other
    clients' shards) and keeps a small LRU cache — the engine's
    ``LoaderPool`` holds the cohort's arrays itself, so this cache only
    serves repeated direct probes (e.g. the drift key check)."""

    lazy = True

    def __init__(self, make: Callable[[int], Dict[str, Any]],
                 num_clients: int, cache_size: int = 8):
        self._make = make
        self._n = int(num_clients)
        self._cache: "OrderedDict[int, Dict[str, Any]]" = OrderedDict()
        self.cache_size = max(1, int(cache_size))

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, cid: int) -> Dict[str, Any]:
        cid = int(cid)
        if not 0 <= cid < self._n:
            raise IndexError(f"client {cid} outside population "
                             f"[0, {self._n})")
        hit = self._cache.get(cid)
        if hit is not None:
            self._cache.move_to_end(cid)
            return hit
        arrays = self._make(cid)
        self._cache[cid] = arrays
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
        return arrays


@dataclasses.dataclass
class LazyWorld:
    """Non-resident client world (WorldSpec.resident=False): same duck
    type as :class:`World`, but ``client_arrays`` synthesizes each
    client's shard on first touch and ``profiles`` is an array-backed
    view — host memory scales with the selected cohort (the engine's
    ``LoaderPool`` bound), not the population."""
    client_arrays: LazyClientData
    eval_arrays: Dict[str, Any]
    profiles: ProfileView
    partition: partition.LazyPartition

    lazy = True

    @property
    def num_clients(self) -> int:
        return len(self.client_arrays)


def _dataset_kind(data_spec, cfg) -> str:
    kind = data_spec.dataset
    if kind != "auto":
        return kind
    if getattr(cfg, "family", None) == "mlp":
        return "road" if cfg.name.endswith("road") else "unsw"
    return "lm"


def _make_split(kind: str, data_spec, cfg, seed: int, n: int):
    if data_spec.factory is not None:
        return data_spec.factory(seed, n)
    if kind == "unsw":
        X, y = synthetic.make_unsw_like(seed, n, cfg.num_features,
                                        cfg.num_classes)
        return {"x": X, "y": y}
    if kind == "road":
        X, y = synthetic.make_road_like(seed, n, window=cfg.num_features)
        return {"x": X, "y": y}
    if kind == "lm":
        t, l = synthetic.make_lm_tokens(seed, n, data_spec.seq_len,
                                        cfg.vocab_size)
        return {"tokens": t, "labels": l}
    raise ValueError(f"unknown dataset kind {kind!r}")


def _as_arrays(split) -> Dict[str, Any]:
    if isinstance(split, dict):
        return split
    X, y = split                       # user factory returning (X, y)
    return {"x": X, "y": y}


def build_lazy_world(spec) -> LazyWorld:
    """Non-resident world: per-client shards come from the seeded
    generators via ``LazyPartition.shard(cid)`` — nothing
    population-sized is materialized here. Note the partition axis:
    lazy shards are independent per-client draws from the shared
    synthetic universe (IID across clients); Dirichlet label skew needs
    the global label table and therefore a resident world."""
    cfg = spec.resolve_model()
    d, w = spec.data, spec.world
    kind = _dataset_kind(d, cfg)
    if d.factory is not None:
        raise ValueError("non-resident worlds synthesize per-client "
                         "shards from the seeded generators; a "
                         "whole-population factory cannot be "
                         "materialized lazily")
    if d.samples_per_client is None:
        raise ValueError("non-resident worlds need "
                         "data.samples_per_client")
    part = partition.LazyPartition(w.num_clients, d.samples_per_client,
                                   seed=spec.seed)

    def make(cid: int) -> Dict[str, Any]:
        shard_seed, m = part.shard(cid)
        return _as_arrays(_make_split(kind, d, cfg, shard_seed, m))

    eval_arrays = _as_arrays(
        _make_split(kind, d, cfg, spec.seed + 1, d.eval_samples))
    if w.profile == "heterogeneous":
        prof_arrays = heterogeneous_profile_arrays(
            w.num_clients, seed=spec.seed + w.profile_seed_offset,
            dropout_p=w.dropout_p, speed_sigma=w.speed_sigma)
    elif w.profile == "uniform":
        prof_arrays = uniform_profile_arrays(w.num_clients,
                                             dropout_p=w.dropout_p)
    else:
        raise ValueError(f"unknown profile {w.profile!r} "
                         "(expected 'heterogeneous' or 'uniform')")
    return LazyWorld(LazyClientData(make, w.num_clients), eval_arrays,
                     ProfileView(prof_arrays), part)


def build_world(spec) -> World:
    """Build (client shards, eval split, client profiles) from a spec."""
    cfg = spec.resolve_model()
    d, w = spec.data, spec.world
    kind = _dataset_kind(d, cfg)
    if not w.resident:
        return build_lazy_world(spec)
    if kind == "lm" and d.partition == "dirichlet":
        raise ValueError("dirichlet partition needs class labels; "
                         "use partition='iid' for token datasets")

    train = _as_arrays(_make_split(kind, d, cfg, spec.seed, d.n_samples))
    label_key = "y" if "y" in train else "labels"
    n = len(train[label_key])

    if d.partition == "dirichlet":
        if "y" not in train:
            raise ValueError("dirichlet partition needs class labels; "
                             "use partition='iid' for token datasets")
        parts = partition.dirichlet_partition(train["y"], w.num_clients,
                                              alpha=d.alpha, seed=spec.seed)
    elif d.partition == "iid":
        parts = partition.iid_partition(n, w.num_clients, seed=spec.seed)
    else:
        raise ValueError(f"unknown partition {d.partition!r} "
                         "(expected 'dirichlet' or 'iid')")
    clients = [{k: v[p] for k, v in train.items()} for p in parts]

    eval_arrays = _as_arrays(
        _make_split(kind, d, cfg, spec.seed + 1, d.eval_samples))

    if w.profile == "heterogeneous":
        profiles = heterogeneous_profiles(
            w.num_clients, seed=spec.seed + w.profile_seed_offset,
            dropout_p=w.dropout_p, speed_sigma=w.speed_sigma)
    elif w.profile == "uniform":
        profiles = uniform_profiles(w.num_clients, dropout_p=w.dropout_p)
    else:
        raise ValueError(f"unknown profile {w.profile!r} "
                         "(expected 'heterogeneous' or 'uniform')")
    return World(clients, eval_arrays, profiles)
