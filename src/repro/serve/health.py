"""serve/health — one snapshot unifying every degradation signal.

The launchers (and anything operating the serving stack) should not
have to interrogate four objects to answer "is this deployment
degrading, and how": :func:`snapshot` collects the engine's queue /
shed / deadline / degraded-mode accounting, the slot's model-version
provenance and age, the re-federator's circuit-breaker state and last
outcome, and the drift monitor's trigger state into one plain-data
:class:`HealthSnapshot` with a single ``status`` verdict:

  ``ok``        nothing degrading
  ``degraded``  serving continues but something is bent — overload
                mode active, requests shed or expired, dispatch errors
                absorbed, drift trigger raised, or the last
                re-federation failed
  ``critical``  the re-federation circuit breaker is OPEN (the model
                can no longer refresh — stale-model risk compounds)

Every field is plain data (``to_dict()`` is JSON-ready), so the
snapshot is equally a log line, a metrics export, or an assertion
surface for the chaos suite (``tests/test_faults.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

STATUS_OK = "ok"
STATUS_DEGRADED = "degraded"
STATUS_CRITICAL = "critical"


@dataclasses.dataclass(frozen=True)
class HealthSnapshot:
    """Point-in-time degradation picture of a serving deployment.

    Sources are optional — fields from an absent component hold their
    neutral defaults, so a bare engine (no federator, no monitor) still
    snapshots cleanly."""
    status: str = STATUS_OK
    # engine
    queue_depth: int = 0
    queue_limit: Optional[int] = None
    queue_depth_ema: float = 0.0
    inflight: int = 0
    degraded_mode: bool = False
    shed: int = 0
    deadline_miss: int = 0
    dispatch_errors: int = 0
    served: int = 0
    submitted: int = 0
    dropped: int = 0
    # model slot
    model_version: Optional[int] = None
    model_round: Optional[int] = None
    model_source: Optional[str] = None
    model_age_seconds: Optional[float] = None
    staged_version: Optional[int] = None
    # re-federator
    breaker_state: Optional[str] = None
    consecutive_failures: int = 0
    refederations_completed: int = 0
    refederations_fired: int = 0
    refederation_retries: int = 0
    triggers_skipped: int = 0
    last_refederation: Optional[str] = None     # "ok" | "failed" | None
    last_error: Optional[str] = None
    refederation_busy: bool = False
    # drift monitor
    drift_statistic: Optional[float] = None
    drift_triggered: Optional[bool] = None
    drift_triggers: int = 0

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @property
    def healthy(self) -> bool:
        return self.status == STATUS_OK


def _status(engine_stats, refederator, monitor) -> str:
    if refederator is not None and refederator.breaker_state == "open":
        return STATUS_CRITICAL
    bent = False
    if engine_stats is not None:
        bent |= bool(engine_stats.degraded or engine_stats.shed
                     or engine_stats.deadline_miss or engine_stats.errors
                     or engine_stats.dropped)
    if refederator is not None:
        bent |= refederator.last_outcome == "failed"
        bent |= refederator.breaker_state == "half-open"
    if monitor is not None:
        bent |= bool(monitor.triggered)
    return STATUS_DEGRADED if bent else STATUS_OK


def snapshot(engine=None, refederator=None, slot=None, monitor=None,
             now=time.time) -> HealthSnapshot:
    """Collect a :class:`HealthSnapshot` from whichever components this
    deployment has. ``slot`` defaults to ``engine.slot`` /
    ``refederator.slot`` when omitted; ``monitor`` defaults to
    ``engine.monitor``. ``model_age_seconds`` is wall time since the
    active version's publish (sidecar ``written_at``) when the slot's
    source is a checkpoint path, else None."""
    fields: Dict[str, Any] = {}
    stats = None
    if engine is not None:
        stats = engine.stats()
        fields.update(
            queue_depth=stats.pending, queue_limit=engine.queue_limit,
            queue_depth_ema=stats.queue_depth_ema,
            inflight=stats.inflight, degraded_mode=stats.degraded,
            shed=stats.shed, deadline_miss=stats.deadline_miss,
            dispatch_errors=stats.errors, served=stats.served,
            submitted=stats.submitted, dropped=stats.dropped)
        if monitor is None:
            monitor = engine.monitor
        if slot is None:
            slot = engine.slot
    if slot is None and refederator is not None:
        slot = refederator.slot
    if slot is not None:
        meta = slot.meta
        fields.update(model_version=meta.version,
                      model_round=meta.round_idx,
                      model_source=meta.source,
                      staged_version=slot.staged_version,
                      model_age_seconds=_model_age(meta, now))
    if refederator is not None:
        err = refederator.last_error
        fields.update(
            breaker_state=refederator.breaker_state,
            consecutive_failures=refederator.consecutive_failures,
            refederations_completed=refederator.completed,
            refederations_fired=refederator.fired,
            refederation_retries=refederator.retries,
            triggers_skipped=refederator.skipped,
            last_refederation=refederator.last_outcome,
            last_error=None if err is None else repr(err),
            refederation_busy=refederator.busy)
    if monitor is not None:
        fields.update(drift_statistic=monitor.statistic,
                      drift_triggered=monitor.triggered,
                      drift_triggers=monitor.trigger_count)
    fields["status"] = _status(stats, refederator, monitor)
    return HealthSnapshot(**fields)


def _model_age(meta, now) -> Optional[float]:
    """Age of the served artifact: wall seconds since its sidecar's
    ``written_at`` when the version came from a checkpoint publish."""
    source = meta.source
    if not source or source in ("init", "publish"):
        return None
    try:
        from repro.api import session as session_mod
        sc = session_mod.read_sidecar(source)
        return max(0.0, float(now()) - float(sc["written_at"]))
    except Exception:
        return None
