"""Batched streaming inference engine for the global anomaly detector.

The train side of the repo produces a global model; this is the *serve*
side: a request queue + micro-batching scoring loop that turns
individual flow-scoring requests into fixed-shape batched dispatches.

Design points (ISSUE 6 tentpole):

- **Power-of-two batch buckets.** A micro-batch of ``n`` requests is
  padded up to the next power of two (capped at ``max_batch``), so every
  shape the jitted scorer ever sees is one of ``log2(max_batch)+1``
  buckets — each compiles exactly once and then hits the jit cache.
  Padded tail rows are masked out of responses AND out of the drift
  monitor's statistics.
- **Fused drift monitoring.** When a :class:`~repro.serve.monitor.
  DriftMonitor` is attached, its pure-jnp EMA update runs INSIDE the
  scoring dispatch (one jit per bucket, zero extra dispatches); only the
  scalar statistic comes back to the host for the trigger policy.
- **Hot-swap at batch boundaries.** Every pump acquires
  ``(params, version)`` from the :class:`~repro.serve.swap.ModelSlot`
  ONCE — a batch never mixes models, a staged publish flips in O(1)
  between batches, and every response is stamped with the version that
  scored it. Nothing is ever dropped on a swap: requests queued across
  a publish are scored by whichever model is active when their batch
  runs.
- **Latency/throughput accounting.** Per-request enqueue->response
  latency feeds p50/p99 percentiles (overall and per bucket) and
  flows/sec; ``benchmarks/serve_bench.py`` commits these to
  ``BENCH_serve.json`` behind a CI regression gate.

Graceful degradation (ISSUE 7): traffic bursts and dispatch faults must
bend the engine, never break it —

- **Bounded queue + admission control.** ``queue_limit`` caps the
  request deque; an arrival over the cap is SHED at admission (counted
  in ``ServeStats.shed``, raised as :class:`QueueFullError` from
  :meth:`submit`, returned as ``None`` from :meth:`try_submit`) —
  latency under overload is bounded by queue depth instead of growing
  without limit, and every *accepted* request is still answered.
- **Per-request deadlines.** ``deadline_ms`` (engine default or per
  :meth:`submit`) stamps an expiry; a request whose deadline passes
  while queued is answered with an explicit ``expired=True`` response
  (counted in ``ServeStats.deadline_miss``) instead of being scored
  late or silently dropped.
- **Overload-driven degraded mode.** A queue-depth EMA crossing
  ``degrade_high``·``queue_limit`` flips the engine into degraded mode
  (hysteresis at ``degrade_low``): batches score through the plain
  scorer WITHOUT the fused drift-monitor statistics, shrinking dispatch
  cost exactly when throughput matters most; ``ServeStats.degraded``
  and ``degraded_pumps`` expose it, ``serve/health.py`` aggregates it.
- **Dispatch-fault absorption.** A scoring dispatch that raises
  (including ``repro.faults`` injected scorer faults) re-queues its
  requests AT THE FRONT in order and returns — the batch retries on the
  next pump; only ``max_dispatch_retries`` CONSECUTIVE failures
  re-raise. Accepted requests survive transient scorer faults —
  ``dropped`` stays 0 by construction, now with in-flight accounting
  (:class:`ServeStats`) so it can never transiently go negative either.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import mlp_detector
from repro.serve.swap import ModelSlot


class QueueFullError(RuntimeError):
    """Admission control shed this request: the bounded queue is at
    ``queue_limit``. The request was NEVER accepted — nothing is owed a
    response — and the shed is counted in ``ServeStats.shed``."""


@dataclasses.dataclass(frozen=True)
class Response:
    """One scored request."""
    request_id: int
    probs: np.ndarray          # (num_classes,) class probabilities
    score: float               # anomaly score: 1 - P(class 0 / Normal)
    model_version: int         # ModelSlot version that scored it
    latency: float             # seconds, submit -> response
    expired: bool = False      # deadline passed while queued — probs and
    #                            score are NaN-filled, never model output


@dataclasses.dataclass(frozen=True)
class ServeStats:
    submitted: int             # ACCEPTED requests (shed never counts)
    served: int                # responses returned (scored + expired)
    pending: int
    inflight: int              # popped for a dispatch, not yet answered
    dropped: int               # zero by construction; reported to prove it
    shed: int                  # admission rejections (queue_limit)
    deadline_miss: int         # answered expired (deadline passed queued)
    errors: int                # scoring-dispatch failures (batch retried)
    degraded: bool             # currently in skip-monitor degraded mode
    degraded_pumps: int        # scoring pumps run in degraded mode
    queue_depth_ema: float     # the overload detector's smoothed depth
    swaps: int                 # model flips observed by the scoring loop
    p50_ms: float
    p99_ms: float
    flows_per_sec: float       # scored rows / busy (scoring) seconds
    busy_seconds: float
    by_bucket: Dict[int, dict]  # bucket -> {count, p50_ms, p99_ms,
    #                                        flows_per_sec}


def _percentile(lat: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(lat), q)) if lat else 0.0


class ServeEngine:
    """Request-queue + micro-batching scoring loop.

    The engine is single-consumer (one thread calls :meth:`pump` /
    :meth:`drain`) but multi-producer: :meth:`submit` is thread-safe, as
    is a background thread publishing models into the slot. ``cfg`` is
    an mlp-family ``ArchConfig`` (the paper's detector); ``score_fn``
    overrides the default ``mlp_detector.predict`` scorer with any
    ``(params, x) -> (B, num_classes) probs`` callable.

    Robustness knobs (all optional — defaults preserve the unbounded
    ISSUE-6 behavior): ``queue_limit`` bounds the queue (admission
    shed), ``deadline_ms`` stamps a default per-request expiry,
    ``degrade_high``/``degrade_low`` are the queue-depth-EMA hysteresis
    fractions of ``queue_limit`` for degraded mode, ``injector`` wires a
    ``repro.faults.FaultInjector`` into the scoring dispatch (site
    ``"scorer"``), ``max_dispatch_retries`` caps consecutive dispatch
    failures before the error propagates.
    """

    def __init__(self, slot: ModelSlot, cfg, *, max_batch: int = 256,
                 monitor=None, score_fn: Optional[Callable] = None,
                 now: Callable[[], float] = time.perf_counter,
                 queue_limit: Optional[int] = None,
                 deadline_ms: Optional[float] = None,
                 degrade_high: float = 0.75, degrade_low: float = 0.25,
                 ema_decay: float = 0.9, max_dispatch_retries: int = 8,
                 injector=None):
        if max_batch < 1 or (max_batch & (max_batch - 1)) != 0:
            raise ValueError(
                f"max_batch must be a power of two >= 1, got {max_batch} "
                "(batch buckets are powers of two so every shape hits a "
                "cached jit)")
        if queue_limit is not None and queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, got {queue_limit}")
        if not (0.0 <= degrade_low < degrade_high <= 1.0):
            raise ValueError(
                f"need 0 <= degrade_low < degrade_high <= 1, got "
                f"({degrade_low}, {degrade_high}) — the hysteresis band "
                "that keeps degraded mode from flapping")
        if not (0.0 <= ema_decay < 1.0):
            raise ValueError(f"ema_decay must be in [0, 1), got {ema_decay}")
        if max_dispatch_retries < 1:
            raise ValueError(
                f"max_dispatch_retries must be >= 1, got "
                f"{max_dispatch_retries}")
        self.slot = slot
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.monitor = monitor
        self.now = now
        self._now0 = now()
        self.queue_limit = None if queue_limit is None else int(queue_limit)
        self.deadline_ms = deadline_ms
        self.degrade_high = float(degrade_high)
        self.degrade_low = float(degrade_low)
        self.ema_decay = float(ema_decay)
        self.max_dispatch_retries = int(max_dispatch_retries)
        self.injector = injector
        predict = score_fn or (lambda p, x: mlp_detector.predict(p, x, cfg))

        # the plain scorer always exists: it is the degraded-mode path
        # even when a monitor is attached (skipping the fused drift
        # statistics shrinks the dispatch under overload)
        def _scorer(params, x):
            probs = predict(params, x)
            return probs, 1.0 - probs[:, 0]
        self._scorer_plain = jax.jit(_scorer)
        if monitor is None:
            self._scorer_mon = None
        else:
            # the monitor's state AND reference are arguments (not trace
            # constants) so a post-swap rearm() is honored by buckets
            # that were already compiled
            def _scorer_mon(params, mstate, ref, x, mask):
                probs = predict(params, x)
                scores = 1.0 - probs[:, 0]
                mstate, stat = monitor.step(mstate, ref, x, scores,
                                            mask=mask)
                return probs, scores, mstate, stat
            self._scorer_mon = jax.jit(_scorer_mon)

        self._lock = threading.Lock()
        self._queue: collections.deque = collections.deque()
        self._next_id = 0
        self._closed = False
        self.on_trigger: Optional[Callable[[], Any]] = None

        self.submitted = 0
        self.served = 0
        self.errors = 0
        self.shed = 0
        self.deadline_miss = 0
        self._inflight = 0
        self._degraded = False
        self._degraded_pumps = 0
        self._depth_ema = 0.0
        self._dispatch_failures = 0      # CONSECUTIVE; success resets
        self._busy = 0.0
        self._latencies: List[float] = []
        self._by_bucket: Dict[int, dict] = {}
        self._versions_served: set = set()
        self._swaps_seen = 0
        self._last_version: Optional[int] = None

    # ------------------------------------------------------------------
    # producers
    # ------------------------------------------------------------------
    def _admit(self, x, deadline_ms) -> Optional[int]:
        x = np.asarray(x, np.float32)
        if x.shape != (self.cfg.num_features,):
            raise ValueError(
                f"expected one flow of shape ({self.cfg.num_features},), "
                f"got {x.shape}")
        dl = self.deadline_ms if deadline_ms is None else deadline_ms
        with self._lock:
            if self._closed:
                raise RuntimeError(
                    "ServeEngine is shut down — no new requests accepted")
            if self.queue_limit is not None \
                    and len(self._queue) >= self.queue_limit:
                self.shed += 1
                return None
            rid = self._next_id
            self._next_id += 1
            self.submitted += 1
            t_in = self.now()
            expiry = None if dl is None else t_in + float(dl) / 1e3
            self._queue.append((rid, x, t_in, expiry))
        return rid

    def submit(self, x, *, deadline_ms: Optional[float] = None) -> int:
        """Enqueue one flow (``(num_features,)``) for scoring; returns
        its request id. Raises :class:`QueueFullError` when admission
        control sheds it (bounded queue at ``queue_limit``) and
        RuntimeError after :meth:`shutdown`. ``deadline_ms`` overrides
        the engine-default expiry for this request."""
        rid = self._admit(x, deadline_ms)
        if rid is None:
            raise QueueFullError(
                f"queue at limit ({self.queue_limit}) — request shed "
                "(ServeStats.shed counts it; use try_submit for a "
                "non-raising probe)")
        return rid

    def try_submit(self, x, *,
                   deadline_ms: Optional[float] = None) -> Optional[int]:
        """:meth:`submit` that returns None instead of raising when the
        bounded queue sheds the request — the burst-load producer API."""
        return self._admit(x, deadline_ms)

    def submit_many(self, X, *, best_effort: bool = False,
                    deadline_ms: Optional[float] = None) -> List[int]:
        """Enqueue each row of ``(n, num_features)`` — one request per
        flow (micro-batching regroups them into buckets). With
        ``best_effort=True`` shed rows are skipped (their ids omitted)
        instead of raising :class:`QueueFullError`."""
        out = []
        for row in np.asarray(X, np.float32):
            rid = self._admit(row, deadline_ms)
            if rid is None and not best_effort:
                raise QueueFullError(
                    f"queue at limit ({self.queue_limit}) — request shed "
                    f"after {len(out)} rows (best_effort=True skips "
                    "instead)")
            if rid is not None:
                out.append(rid)
        return out

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    @property
    def degraded(self) -> bool:
        with self._lock:
            return self._degraded

    @property
    def queue_depth_ema(self) -> float:
        with self._lock:
            return self._depth_ema

    # ------------------------------------------------------------------
    # the scoring loop
    # ------------------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        """Smallest power-of-two bucket holding ``n`` requests (<= the
        ``max_batch`` cap, since pumps never take more than that)."""
        if not 1 <= n <= self.max_batch:
            raise ValueError(f"n={n} outside [1, {self.max_batch}]")
        return 1 << (n - 1).bit_length()

    def _expired_responses(self, expired, t_now) -> List[Response]:
        """Answer deadline-missed requests explicitly — NaN payload,
        ``expired=True`` — and account them (served + deadline_miss).
        They are answered, never dropped: zero-drop covers them."""
        if not expired:
            return []
        version = self.slot.meta.version
        nan_probs = np.full((self.cfg.num_classes,), np.nan, np.float32)
        out = [Response(request_id=rid, probs=nan_probs,
                        score=float("nan"), model_version=version,
                        latency=t_now - t_in, expired=True)
               for rid, _x, t_in, _dl in expired]
        with self._lock:
            self.served += len(out)
            self.deadline_miss += len(out)
        return out

    def pump(self) -> List[Response]:
        """Score ONE micro-batch: flip in any staged model, expire
        deadline-missed requests, take up to ``max_batch`` queued
        requests, pad to the power-of-two bucket, dispatch, stamp
        responses. Returns [] when the queue is empty. A dispatch
        failure re-queues the batch at the front and returns the
        expired responses only (retry on the next pump)."""
        t_now = self.now()
        with self._lock:
            depth = len(self._queue)
            self._depth_ema = (self.ema_decay * self._depth_ema
                               + (1.0 - self.ema_decay) * depth)
            if self.queue_limit is not None:
                if (not self._degraded and self._depth_ema
                        > self.degrade_high * self.queue_limit):
                    self._degraded = True
                elif (self._degraded and self._depth_ema
                        < self.degrade_low * self.queue_limit):
                    self._degraded = False
            reqs, expired = [], []
            while self._queue and len(reqs) < self.max_batch:
                entry = self._queue.popleft()
                if entry[3] is not None and t_now > entry[3]:
                    expired.append(entry)
                else:
                    reqs.append(entry)
            self._inflight += len(reqs)
            degraded = self._degraded
            use_monitor = self.monitor is not None and not degraded
            if reqs and degraded:
                self._degraded_pumps += 1
        out = self._expired_responses(expired, t_now)
        if not reqs:
            return out

        t0 = self.now()
        params, meta = self.slot.acquire()
        if self._last_version is not None \
                and meta.version != self._last_version:
            self._swaps_seen += 1
        self._last_version = meta.version
        n = len(reqs)
        bucket = self.bucket_for(n)
        xpad = np.zeros((bucket, self.cfg.num_features), np.float32)
        for i, (_rid, x, _t, _dl) in enumerate(reqs):
            xpad[i] = x
        fired = False
        try:
            if self.injector is not None:
                self.injector.check("scorer")
            if use_monitor:
                mask = np.zeros((bucket,), np.float32)
                mask[:n] = 1.0
                probs, scores, mstate, stat = self._scorer_mon(
                    params, self.monitor.state, self.monitor.reference,
                    jnp.asarray(xpad), jnp.asarray(mask))
            else:
                probs, scores = self._scorer_plain(params,
                                                   jnp.asarray(xpad))
            probs = np.asarray(probs)        # device sync point
            scores = np.asarray(scores)
        except Exception:
            # graceful absorption: the batch goes BACK to the front of
            # the queue in order — accepted requests are never lost to a
            # transient dispatch fault; persistent failure (consecutive
            # > max_dispatch_retries) propagates to the caller
            with self._lock:
                self._queue.extendleft(reversed(reqs))
                self._inflight -= n
                self.errors += 1
                self._dispatch_failures += 1
                give_up = self._dispatch_failures > self.max_dispatch_retries
            if give_up:
                raise
            return out
        t_done = self.now()
        if use_monitor:
            fired = self.monitor.observe(mstate, stat)

        lats = []
        for i, (rid, _x, t_in, _dl) in enumerate(reqs):
            lat = t_done - t_in
            lats.append(lat)
            out.append(Response(request_id=rid, probs=probs[i],
                                score=float(scores[i]),
                                model_version=meta.version, latency=lat))
        dt = t_done - t0
        with self._lock:
            self._dispatch_failures = 0
            self.served += n
            self._inflight -= n
            self._busy += dt
            self._latencies.extend(lats)
            self._versions_served.add(meta.version)
            b = self._by_bucket.setdefault(
                bucket, {"count": 0, "rows": 0, "seconds": 0.0,
                         "latencies": []})
            b["count"] += 1
            b["rows"] += n
            b["seconds"] += dt
            b["latencies"].extend(lats)
        if fired and self.on_trigger is not None:
            self.on_trigger()
        return out

    def drain(self) -> List[Response]:
        """Pump until the queue is empty (requests submitted by other
        threads DURING the drain are served too)."""
        out: List[Response] = []
        while self.pending:
            out.extend(self.pump())
        return out

    def shutdown(self) -> ServeStats:
        """Drain every queued request, then refuse new submissions —
        the zero-dropped-requests guarantee is checkable afterwards as
        ``stats().served == stats().submitted``."""
        while True:
            with self._lock:
                if not self._queue:
                    self._closed = True
                    break
            self.pump()
        return self.stats()

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    def reset_stats(self) -> None:
        """Zero the latency/throughput accounting (e.g. after a warmup
        pass, so compile time stays out of steady-state percentiles).
        Model versions, swap counters, degraded-mode state and the
        request-id sequence are preserved. Call only with an empty
        queue and no in-flight batch — in-flight requests submitted
        before a reset would count as served-but-never-submitted."""
        with self._lock:
            if self._queue or self._inflight:
                raise RuntimeError(
                    f"reset_stats with {len(self._queue)} requests "
                    f"queued and {self._inflight} in flight — drain "
                    "first")
            self.submitted = 0
            self.served = 0
            self.errors = 0
            self.shed = 0
            self.deadline_miss = 0
            self._degraded_pumps = 0
            self._busy = 0.0
            self._latencies = []
            self._by_bucket = {}

    def stats(self) -> ServeStats:
        """One consistent snapshot: every counter (and the queue/
        in-flight depths the derived ``dropped`` needs) is read under a
        single lock acquisition, so ``dropped`` can never transiently go
        negative under concurrent submitters or a racing
        :meth:`reset_stats` (it counts only what was popped for a
        dispatch and not yet answered — the ``inflight`` field)."""
        with self._lock:
            submitted, served = self.submitted, self.served
            pending, inflight = len(self._queue), self._inflight
            lat = list(self._latencies)
            busy = self._busy
            by_bucket = {
                k: {"count": v["count"], "rows": v["rows"],
                    "p50_ms": round(_percentile(v["latencies"], 50) * 1e3,
                                    4),
                    "p99_ms": round(_percentile(v["latencies"], 99) * 1e3,
                                    4),
                    "flows_per_sec": round(
                        v["rows"] / max(v["seconds"], 1e-9), 1)}
                for k, v in sorted(self._by_bucket.items())}
            return ServeStats(
                submitted=submitted, served=served,
                pending=pending, inflight=inflight,
                dropped=submitted - served - pending - inflight,
                shed=self.shed, deadline_miss=self.deadline_miss,
                errors=self.errors, degraded=self._degraded,
                degraded_pumps=self._degraded_pumps,
                queue_depth_ema=round(self._depth_ema, 4),
                swaps=self._swaps_seen,
                p50_ms=round(_percentile(lat, 50) * 1e3, 4),
                p99_ms=round(_percentile(lat, 99) * 1e3, 4),
                flows_per_sec=round(
                    (served - self.deadline_miss) / max(busy, 1e-9), 1),
                busy_seconds=round(busy, 4),
                by_bucket=by_bucket)

    @property
    def versions_served(self) -> List[int]:
        with self._lock:
            return sorted(self._versions_served)
