"""Batched streaming inference engine for the global anomaly detector.

The train side of the repo produces a global model; this is the *serve*
side: a request queue + micro-batching scoring loop that turns
individual flow-scoring requests into fixed-shape batched dispatches.

Design points (ISSUE 6 tentpole):

- **Power-of-two batch buckets.** A micro-batch of ``n`` requests is
  padded up to the next power of two (capped at ``max_batch``), so every
  shape the jitted scorer ever sees is one of ``log2(max_batch)+1``
  buckets — each compiles exactly once and then hits the jit cache.
  Padded tail rows are masked out of responses AND out of the drift
  monitor's statistics.
- **Fused drift monitoring.** When a :class:`~repro.serve.monitor.
  DriftMonitor` is attached, its pure-jnp EMA update runs INSIDE the
  scoring dispatch (one jit per bucket, zero extra dispatches); only the
  scalar statistic comes back to the host for the trigger policy.
- **Hot-swap at batch boundaries.** Every pump acquires
  ``(params, version)`` from the :class:`~repro.serve.swap.ModelSlot`
  ONCE — a batch never mixes models, a staged publish flips in O(1)
  between batches, and every response is stamped with the version that
  scored it. Nothing is ever dropped on a swap: requests queued across
  a publish are scored by whichever model is active when their batch
  runs.
- **Latency/throughput accounting.** Per-request enqueue->response
  latency feeds p50/p99 percentiles (overall and per bucket) and
  flows/sec; ``benchmarks/serve_bench.py`` commits these to
  ``BENCH_serve.json`` behind a CI regression gate.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import mlp_detector
from repro.serve.swap import ModelSlot


@dataclasses.dataclass(frozen=True)
class Response:
    """One scored request."""
    request_id: int
    probs: np.ndarray          # (num_classes,) class probabilities
    score: float               # anomaly score: 1 - P(class 0 / Normal)
    model_version: int         # ModelSlot version that scored it
    latency: float             # seconds, submit -> response


@dataclasses.dataclass(frozen=True)
class ServeStats:
    submitted: int
    served: int
    pending: int
    dropped: int               # zero by construction; reported to prove it
    errors: int
    swaps: int                 # model flips observed by the scoring loop
    p50_ms: float
    p99_ms: float
    flows_per_sec: float       # served rows / busy (scoring) seconds
    busy_seconds: float
    by_bucket: Dict[int, dict]  # bucket -> {count, p50_ms, p99_ms,
    #                                        flows_per_sec}


def _percentile(lat: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(lat), q)) if lat else 0.0


class ServeEngine:
    """Request-queue + micro-batching scoring loop.

    The engine is single-consumer (one thread calls :meth:`pump` /
    :meth:`drain`) but multi-producer: :meth:`submit` is thread-safe, as
    is a background thread publishing models into the slot. ``cfg`` is
    an mlp-family ``ArchConfig`` (the paper's detector); ``score_fn``
    overrides the default ``mlp_detector.predict`` scorer with any
    ``(params, x) -> (B, num_classes) probs`` callable.
    """

    def __init__(self, slot: ModelSlot, cfg, *, max_batch: int = 256,
                 monitor=None, score_fn: Optional[Callable] = None,
                 now: Callable[[], float] = time.perf_counter):
        if max_batch < 1 or (max_batch & (max_batch - 1)) != 0:
            raise ValueError(
                f"max_batch must be a power of two >= 1, got {max_batch} "
                "(batch buckets are powers of two so every shape hits a "
                "cached jit)")
        self.slot = slot
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.monitor = monitor
        self.now = now
        self._now0 = now()
        predict = score_fn or (lambda p, x: mlp_detector.predict(p, x, cfg))

        if monitor is None:
            def _scorer(params, x):
                probs = predict(params, x)
                return probs, 1.0 - probs[:, 0]
            self._scorer = jax.jit(_scorer)
        else:
            # the monitor's state AND reference are arguments (not trace
            # constants) so a post-swap rearm() is honored by buckets
            # that were already compiled
            def _scorer_mon(params, mstate, ref, x, mask):
                probs = predict(params, x)
                scores = 1.0 - probs[:, 0]
                mstate, stat = monitor.step(mstate, ref, x, scores,
                                            mask=mask)
                return probs, scores, mstate, stat
            self._scorer = jax.jit(_scorer_mon)

        self._lock = threading.Lock()
        self._queue: collections.deque = collections.deque()
        self._next_id = 0
        self._closed = False
        self.on_trigger: Optional[Callable[[], Any]] = None

        self.submitted = 0
        self.served = 0
        self.errors = 0
        self._busy = 0.0
        self._latencies: List[float] = []
        self._by_bucket: Dict[int, dict] = {}
        self._versions_served: set = set()
        self._swaps_seen = 0
        self._last_version: Optional[int] = None

    # ------------------------------------------------------------------
    # producers
    # ------------------------------------------------------------------
    def submit(self, x) -> int:
        """Enqueue one flow (``(num_features,)``) for scoring; returns
        its request id. Raises RuntimeError after :meth:`shutdown`."""
        x = np.asarray(x, np.float32)
        if x.shape != (self.cfg.num_features,):
            raise ValueError(
                f"expected one flow of shape ({self.cfg.num_features},), "
                f"got {x.shape}")
        with self._lock:
            if self._closed:
                raise RuntimeError(
                    "ServeEngine is shut down — no new requests accepted")
            rid = self._next_id
            self._next_id += 1
            self.submitted += 1
            self._queue.append((rid, x, self.now()))
        return rid

    def submit_many(self, X) -> List[int]:
        """Enqueue each row of ``(n, num_features)`` — one request per
        flow (micro-batching regroups them into buckets)."""
        return [self.submit(row) for row in np.asarray(X, np.float32)]

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    # ------------------------------------------------------------------
    # the scoring loop
    # ------------------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        """Smallest power-of-two bucket holding ``n`` requests (<= the
        ``max_batch`` cap, since pumps never take more than that)."""
        if not 1 <= n <= self.max_batch:
            raise ValueError(f"n={n} outside [1, {self.max_batch}]")
        return 1 << (n - 1).bit_length()

    def pump(self) -> List[Response]:
        """Score ONE micro-batch: flip in any staged model, take up to
        ``max_batch`` queued requests, pad to the power-of-two bucket,
        dispatch, stamp responses. Returns [] when the queue is empty."""
        with self._lock:
            take = min(len(self._queue), self.max_batch)
            reqs = [self._queue.popleft() for _ in range(take)]
        if not reqs:
            return []
        t0 = self.now()
        params, meta = self.slot.acquire()
        if self._last_version is not None \
                and meta.version != self._last_version:
            self._swaps_seen += 1
        self._last_version = meta.version
        n = len(reqs)
        bucket = self.bucket_for(n)
        xpad = np.zeros((bucket, self.cfg.num_features), np.float32)
        for i, (_rid, x, _t) in enumerate(reqs):
            xpad[i] = x
        fired = False
        try:
            if self.monitor is None:
                probs, scores = self._scorer(params, jnp.asarray(xpad))
            else:
                mask = np.zeros((bucket,), np.float32)
                mask[:n] = 1.0
                probs, scores, mstate, stat = self._scorer(
                    params, self.monitor.state, self.monitor.reference,
                    jnp.asarray(xpad), jnp.asarray(mask))
            probs = np.asarray(probs)        # device sync point
            scores = np.asarray(scores)
        except Exception:
            with self._lock:
                self.errors += n
            raise
        t_done = self.now()
        if self.monitor is not None:
            fired = self.monitor.observe(mstate, stat)

        out = []
        lats = []
        for i, (rid, _x, t_in) in enumerate(reqs):
            lat = t_done - t_in
            lats.append(lat)
            out.append(Response(request_id=rid, probs=probs[i],
                                score=float(scores[i]),
                                model_version=meta.version, latency=lat))
        dt = t_done - t0
        with self._lock:
            self.served += n
            self._busy += dt
            self._latencies.extend(lats)
            self._versions_served.add(meta.version)
            b = self._by_bucket.setdefault(
                bucket, {"count": 0, "rows": 0, "seconds": 0.0,
                         "latencies": []})
            b["count"] += 1
            b["rows"] += n
            b["seconds"] += dt
            b["latencies"].extend(lats)
        if fired and self.on_trigger is not None:
            self.on_trigger()
        return out

    def drain(self) -> List[Response]:
        """Pump until the queue is empty (requests submitted by other
        threads DURING the drain are served too)."""
        out: List[Response] = []
        while self.pending:
            out.extend(self.pump())
        return out

    def shutdown(self) -> ServeStats:
        """Drain every queued request, then refuse new submissions —
        the zero-dropped-requests guarantee is checkable afterwards as
        ``stats().served == stats().submitted``."""
        while True:
            with self._lock:
                if not self._queue:
                    self._closed = True
                    break
            self.pump()
        return self.stats()

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    def reset_stats(self) -> None:
        """Zero the latency/throughput accounting (e.g. after a warmup
        pass, so compile time stays out of steady-state percentiles).
        Model versions, swap counters and the request-id sequence are
        preserved. Call only with an empty queue — in-flight requests
        submitted before a reset would count as served-but-never-
        submitted."""
        with self._lock:
            if self._queue:
                raise RuntimeError(
                    f"reset_stats with {len(self._queue)} requests "
                    "queued — drain first")
            self.submitted = 0
            self.served = 0
            self.errors = 0
            self._busy = 0.0
            self._latencies = []
            self._by_bucket = {}

    def stats(self) -> ServeStats:
        with self._lock:
            lat = list(self._latencies)
            busy = self._busy
            by_bucket = {
                k: {"count": v["count"], "rows": v["rows"],
                    "p50_ms": round(_percentile(v["latencies"], 50) * 1e3,
                                    4),
                    "p99_ms": round(_percentile(v["latencies"], 99) * 1e3,
                                    4),
                    "flows_per_sec": round(
                        v["rows"] / max(v["seconds"], 1e-9), 1)}
                for k, v in sorted(self._by_bucket.items())}
            return ServeStats(
                submitted=self.submitted, served=self.served,
                pending=len(self._queue),
                dropped=self.submitted - self.served - len(self._queue)
                - self.errors,
                errors=self.errors, swaps=self._swaps_seen,
                p50_ms=round(_percentile(lat, 50) * 1e3, 4),
                p99_ms=round(_percentile(lat, 99) * 1e3, 4),
                flows_per_sec=round(self.served / max(busy, 1e-9), 1),
                busy_seconds=round(busy, 4),
                by_bucket=by_bucket)

    @property
    def versions_served(self) -> List[int]:
        with self._lock:
            return sorted(self._versions_served)
