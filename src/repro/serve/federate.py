"""Re-federation: the drift trigger drives a bounded-rounds
``ExperimentSession`` and hot-swaps the refreshed checkpoint back in.

This closes the train -> serve -> drift -> re-federate loop: the
:class:`~repro.serve.monitor.DriftMonitor` fires, the
:class:`Refederator` runs a fresh federation (optionally on a background
thread so the serving loop keeps scoring), checkpoints it (which writes
the validation sidecar), publishes the checkpoint into the
:class:`~repro.serve.swap.ModelSlot`, and re-arms the monitor with the
shifted serving distribution as the new reference. The serving engine
flips the refreshed model in at its next batch boundary — zero requests
dropped across the whole cycle.

Round accounting: each re-federation session counts its own rounds from
zero, so the publish passes ``round_base`` = the currently served
model's round counter — version round indices stay monotone across
re-federations and the swap layer's staleness gate keeps rejecting
genuinely old artifacts.

Failure is the normal regime (ISSUE 7): a re-federation attempt that
raises — session failure, checkpoint IO error, publish crash, any
``repro.faults`` injection — retries up to ``max_retries`` times with
exponential backoff and deterministic seeded jitter. A firing whose
retry budget is exhausted counts ONE consecutive failure; after
``breaker_threshold`` consecutive failed firings the circuit breaker
OPENS: triggers are swallowed (counted in ``skipped``) for
``breaker_cooldown`` firings, then the next trigger runs a single
HALF-OPEN probe (no retry budget) — success re-closes the breaker,
failure re-opens it. ``breaker_state`` / ``consecutive_failures`` /
``last_error`` expose the machine for ``serve/health.py``; a broken
federation pipeline therefore costs the serving loop nothing but stale
models, never a crash and never an unbounded retry storm.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.api.session import ExperimentSession
from repro.serve.swap import ModelSlot

BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half-open"


class Refederator:
    """Runs one bounded federation per trigger and publishes the result.

    Parameters
    ----------
    slot         : the ModelSlot the serving engine scores from
    spec_factory : ``trigger_index -> ExperimentSpec`` — each firing
                   builds the spec for that re-federation (typically
                   with a data factory reflecting the CURRENT traffic
                   distribution; its ``rounds`` field bounds the run)
    ckpt_dir     : where refreshed checkpoints (+ sidecars) land
    monitor      : re-armed (``adopt_current=True``) after a successful
                   publish, so the post-swap distribution becomes the
                   new drift reference; None skips re-arming
    background   : True runs each federation on a daemon thread (the
                   serving loop keeps pumping); False runs inline
    max_retries  : extra attempts per firing after the first fails
    backoff_base / backoff_factor / max_backoff
                 : exponential backoff (seconds) between attempts
    jitter       : fractional deterministic jitter on each backoff,
                   drawn from a generator seeded by ``(seed, firing)``
    breaker_threshold : consecutive failed firings that OPEN the breaker
    breaker_cooldown  : triggers swallowed while open before the
                        half-open probe (0 = probe on the very next)
    sleep        : injectable clock for tests (defaults to time.sleep)
    injector     : optional ``repro.faults.FaultInjector`` — sites
                   ``"refederate"`` (before the session runs) and
                   ``"publish"`` (before the checkpoint publishes)
    """

    def __init__(self, slot: ModelSlot,
                 spec_factory: Callable[[int], "object"], *,
                 ckpt_dir: str, monitor=None, background: bool = True,
                 on_complete: Optional[Callable] = None,
                 max_retries: int = 2, backoff_base: float = 0.25,
                 backoff_factor: float = 2.0, max_backoff: float = 30.0,
                 jitter: float = 0.1, breaker_threshold: int = 3,
                 breaker_cooldown: int = 1, seed: int = 0,
                 sleep: Callable[[float], None] = time.sleep,
                 injector=None):
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {breaker_threshold}")
        if breaker_cooldown < 0:
            raise ValueError(
                f"breaker_cooldown must be >= 0, got {breaker_cooldown}")
        self.slot = slot
        self.spec_factory = spec_factory
        self.ckpt_dir = ckpt_dir
        self.monitor = monitor
        self.background = background
        self.on_complete = on_complete
        self.max_retries = int(max_retries)
        self.backoff_base = float(backoff_base)
        self.backoff_factor = float(backoff_factor)
        self.max_backoff = float(max_backoff)
        self.jitter = float(jitter)
        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown = int(breaker_cooldown)
        self.seed = int(seed)
        self.sleep = sleep
        self.injector = injector
        self.completed = 0
        self.fired = 0
        self.retries = 0                  # lifetime retry attempts
        self.skipped = 0                  # triggers swallowed (open/busy)
        self.consecutive_failures = 0     # failed FIRINGS (post-retries)
        self.last_error: Optional[BaseException] = None
        self.last_checkpoint: Optional[str] = None
        self.last_outcome: Optional[str] = None   # "ok" | "failed" | None
        self._breaker = BREAKER_CLOSED
        self._cooldown_left = 0
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    @property
    def busy(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    @property
    def breaker_state(self) -> str:
        with self._lock:
            return self._breaker

    def fire(self) -> bool:
        """Kick off one re-federation (the engine's ``on_trigger``
        hook). Returns False — without starting anything — when a run
        is already in flight (overlapping triggers coalesce) or the
        circuit breaker swallows the trigger during its open cooldown.
        The first trigger past the cooldown runs as the HALF-OPEN
        probe: one attempt, no retries."""
        with self._lock:
            if self.busy:
                self.skipped += 1
                return False
            probe = False
            if self._breaker == BREAKER_OPEN:
                if self._cooldown_left > 0:
                    self._cooldown_left -= 1
                    self.skipped += 1
                    return False
                self._breaker = BREAKER_HALF_OPEN
                probe = True
            k = self.fired
            self.fired += 1
            if self.background:
                self._thread = threading.Thread(
                    target=self._run, args=(k, probe), daemon=True,
                    name=f"refederate-{k}")
                self._thread.start()
                return True
        self._run(k, probe)
        return True

    def join(self, timeout: Optional[float] = None) -> bool:
        """Wait for the in-flight background federation. Returns True
        when no run remains in flight. The thread reference is cleared
        ONLY when the join actually completed — after a timeout expiry
        the still-running daemon stays referenced and ``busy`` keeps
        reporting True (the ISSUE 7 satellite fix)."""
        t = self._thread
        if t is None:
            return True
        t.join(timeout)
        if t.is_alive():
            return False
        with self._lock:
            if self._thread is t:
                self._thread = None
        return True

    # ------------------------------------------------------------------
    def _backoff(self, attempt: int, rng) -> float:
        base = min(self.max_backoff,
                   self.backoff_base * self.backoff_factor ** attempt)
        return base * (1.0 + self.jitter * float(rng.random()))

    def _attempt(self, k: int) -> None:
        """One full re-federation attempt; any raise means failure."""
        if self.injector is not None:
            self.injector.check("refederate")
        spec = self.spec_factory(k)
        session = ExperimentSession.open(spec)
        session.run(spec.rounds)
        os.makedirs(self.ckpt_dir, exist_ok=True)
        path = os.path.join(self.ckpt_dir, f"refederated_{k:03d}.ckpt")
        session.checkpoint(path)
        self.last_checkpoint = path
        if self.injector is not None:
            self.injector.check("publish")
        # each session counts rounds from zero; base on the served
        # model's counter so version rounds stay monotone and the
        # staleness gate still rejects genuinely old artifacts
        self.slot.publish_checkpoint(
            path, spec=spec, round_base=self.slot.meta.round_idx)
        if self.monitor is not None:
            self.monitor.rearm(adopt_current=True)

    def _run(self, k: int, probe: bool = False) -> None:
        # a failed re-federation must not kill serving: every attempt's
        # exception is absorbed into retry/backoff, then into the
        # breaker — only `last_error` and health surface it
        rng = np.random.default_rng([self.seed, k])
        budget = 1 if probe else self.max_retries + 1
        for attempt in range(budget):
            try:
                self._attempt(k)
            except BaseException as e:
                self.last_error = e
                if attempt + 1 < budget:
                    with self._lock:
                        self.retries += 1
                    self.sleep(self._backoff(attempt, rng))
                    continue
                with self._lock:
                    self.last_outcome = "failed"
                    self.consecutive_failures += 1
                    if probe or (self.consecutive_failures
                                 >= self.breaker_threshold):
                        self._breaker = BREAKER_OPEN
                        self._cooldown_left = self.breaker_cooldown
                return
            with self._lock:
                self.completed += 1
                self.consecutive_failures = 0
                self.last_error = None
                self.last_outcome = "ok"
                self._breaker = BREAKER_CLOSED
            if self.on_complete is not None:
                self.on_complete(k, self.last_checkpoint)
            return
