"""Re-federation: the drift trigger drives a bounded-rounds
``ExperimentSession`` and hot-swaps the refreshed checkpoint back in.

This closes the train -> serve -> drift -> re-federate loop: the
:class:`~repro.serve.monitor.DriftMonitor` fires, the
:class:`Refederator` runs a fresh federation (optionally on a background
thread so the serving loop keeps scoring), checkpoints it (which writes
the validation sidecar), publishes the checkpoint into the
:class:`~repro.serve.swap.ModelSlot`, and re-arms the monitor with the
shifted serving distribution as the new reference. The serving engine
flips the refreshed model in at its next batch boundary — zero requests
dropped across the whole cycle.

Round accounting: each re-federation session counts its own rounds from
zero, so the publish passes ``round_base`` = the currently served
model's round counter — version round indices stay monotone across
re-federations and the swap layer's staleness gate keeps rejecting
genuinely old artifacts.
"""
from __future__ import annotations

import os
import threading
from typing import Callable, Optional

from repro.api.session import ExperimentSession
from repro.serve.swap import ModelSlot


class Refederator:
    """Runs one bounded federation per trigger and publishes the result.

    Parameters
    ----------
    slot         : the ModelSlot the serving engine scores from
    spec_factory : ``trigger_index -> ExperimentSpec`` — each firing
                   builds the spec for that re-federation (typically
                   with a data factory reflecting the CURRENT traffic
                   distribution; its ``rounds`` field bounds the run)
    ckpt_dir     : where refreshed checkpoints (+ sidecars) land
    monitor      : re-armed (``adopt_current=True``) after a successful
                   publish, so the post-swap distribution becomes the
                   new drift reference; None skips re-arming
    background   : True runs each federation on a daemon thread (the
                   serving loop keeps pumping); False runs inline
    """

    def __init__(self, slot: ModelSlot,
                 spec_factory: Callable[[int], "object"], *,
                 ckpt_dir: str, monitor=None, background: bool = True,
                 on_complete: Optional[Callable] = None):
        self.slot = slot
        self.spec_factory = spec_factory
        self.ckpt_dir = ckpt_dir
        self.monitor = monitor
        self.background = background
        self.on_complete = on_complete
        self.completed = 0
        self.fired = 0
        self.last_error: Optional[BaseException] = None
        self.last_checkpoint: Optional[str] = None
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    @property
    def busy(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def fire(self) -> bool:
        """Kick off one re-federation (the engine's ``on_trigger``
        hook). Returns False — without starting anything — when a run
        is already in flight: overlapping triggers coalesce."""
        with self._lock:
            if self.busy:
                return False
            k = self.fired
            self.fired += 1
            if self.background:
                self._thread = threading.Thread(
                    target=self._run, args=(k,), daemon=True,
                    name=f"refederate-{k}")
                self._thread.start()
                return True
        self._run(k)
        return True

    def join(self, timeout: Optional[float] = None) -> None:
        t = self._thread
        if t is not None:
            t.join(timeout)

    # ------------------------------------------------------------------
    def _run(self, k: int) -> None:
        try:
            spec = self.spec_factory(k)
            session = ExperimentSession.open(spec)
            session.run(spec.rounds)
            os.makedirs(self.ckpt_dir, exist_ok=True)
            path = os.path.join(self.ckpt_dir, f"refederated_{k:03d}.ckpt")
            session.checkpoint(path)
            self.last_checkpoint = path
            # each session counts rounds from zero; base on the served
            # model's counter so version rounds stay monotone and the
            # staleness gate still rejects genuinely old artifacts
            self.slot.publish_checkpoint(
                path, spec=spec, round_base=self.slot.meta.round_idx)
            if self.monitor is not None:
                self.monitor.rearm(adopt_current=True)
            self.completed += 1
            if self.on_complete is not None:
                self.on_complete(k, path)
        except BaseException as e:   # surfaced via last_error; a failed
            self.last_error = e      # re-federation must not kill serving
