"""repro.serve — continuous-federation serving for the global detector.

The serving layer that closes the paper's loop: train a global model
(``repro.api``), serve it as a batched streaming scorer
(:class:`ServeEngine`), watch live traffic for distribution shift
(:class:`DriftMonitor`, reusing ``core/scenario.py``'s drift machinery
as the detector), and when shift persists, re-federate and hot-swap the
refreshed checkpoint in without dropping a request (:class:`Refederator`
+ :class:`ModelSlot`). See README "Serving" and
``examples/continuous_federation.py`` for the full loop.
"""
from repro.serve.engine import (QueueFullError, Response, ServeEngine,
                                ServeStats)
from repro.serve.federate import (BREAKER_CLOSED, BREAKER_HALF_OPEN,
                                  BREAKER_OPEN, Refederator)
from repro.serve.health import HealthSnapshot, snapshot as health_snapshot
from repro.serve.monitor import DriftMonitor
from repro.serve.swap import (ModelSlot, ModelVersion, ServeModelError,
                              StaleCheckpointError)

__all__ = [
    "ServeEngine", "Response", "ServeStats", "QueueFullError",
    "ModelSlot", "ModelVersion", "ServeModelError", "StaleCheckpointError",
    "DriftMonitor", "Refederator",
    "BREAKER_CLOSED", "BREAKER_OPEN", "BREAKER_HALF_OPEN",
    "HealthSnapshot", "health_snapshot",
]
