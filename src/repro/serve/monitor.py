"""Online drift monitor: the scenario engine's drift machinery as a
DETECTOR over live serving traffic.

``core/scenario.py`` gained the shared statistics (``DriftStats`` /
``reference_snapshot`` / ``drift_stats_update`` / ``drift_statistic``);
this module wraps them in the serving-side policy: a streaming EMA of
per-feature moments and score-distribution moments is compared against a
training-time reference snapshot every micro-batch, and when the
normalized shift exceeds ``threshold`` for ``patience`` CONSECUTIVE
windows the monitor raises a re-federation trigger (``triggered``).

The update is pure jnp (:meth:`step`), so ``serve.engine`` fuses it into
the scoring dispatch — drift monitoring costs zero extra compiled
dispatches. Only the trigger logic (threshold + consecutive-window
counting) runs host-side, on the scalar statistic each batch already
returns.
"""
from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import scenario as scenario_mod
from repro.core.scenario import DriftStats


class DriftMonitor:
    """Streaming shift detector against a reference snapshot.

    Parameters
    ----------
    reference     : training-time :class:`DriftStats`
                    (``scenario.reference_snapshot``)
    threshold     : normalized-shift trigger level (1.0 ~= feature means
                    one reference std away on average; see
                    ``scenario.drift_statistic``)
    patience      : consecutive over-threshold windows required — a
                    single anomalous burst does not re-federate
    decay         : per-sample EMA decay of the streaming stats
    """

    def __init__(self, reference: DriftStats, *, threshold: float = 0.5,
                 patience: int = 3, decay: float = 0.98):
        if patience < 1:
            raise ValueError(f"patience must be >= 1, got {patience}")
        self.reference = reference
        self.threshold = float(threshold)
        self.patience = int(patience)
        self.decay = float(decay)
        self.state = scenario_mod.init_drift_stats(
            int(reference.feat_mean.shape[0]))
        self.history: List[float] = []       # one statistic per window
        self.triggered = False
        self.trigger_count = 0               # lifetime triggers raised
        self._over = 0                       # consecutive windows over

    # ------------------------------------------------------------------
    # fused path: pure jnp, called INSIDE the engine's jitted scorer
    # ------------------------------------------------------------------
    def step(self, state: DriftStats, reference: DriftStats, x, scores,
             mask=None):
        """(state, reference, x, scores, mask) -> (new_state, statistic).
        Pure jnp — jit/vmap safe. ``reference`` is an ARGUMENT, not a
        closed-over constant, so a post-swap :meth:`rearm` takes effect
        in already-compiled batch buckets (only ``decay`` is a trace
        constant; it never changes after construction)."""
        new = scenario_mod.drift_stats_update(state, x, scores, mask=mask,
                                              decay=self.decay)
        return new, scenario_mod.drift_statistic(new, reference)

    # ------------------------------------------------------------------
    # host path: trigger policy on the per-window scalar
    # ------------------------------------------------------------------
    def observe(self, state: DriftStats, statistic) -> bool:
        """Adopt the post-batch state + statistic (host side). Returns
        True the moment the trigger FIRES (edge, not level — it stays
        ``triggered`` until :meth:`rearm`, but observe only returns True
        once per arming so the federator fires once)."""
        self.state = state
        stat = float(statistic)
        self.history.append(stat)
        self._over = self._over + 1 if stat > self.threshold else 0
        if self._over >= self.patience and not self.triggered:
            self.triggered = True
            self.trigger_count += 1
            return True
        return False

    @property
    def statistic(self) -> float:
        return self.history[-1] if self.history else 0.0

    def rearm(self, reference: Optional[DriftStats] = None,
              adopt_current: bool = False) -> None:
        """Clear the trigger after a re-federation hot-swap.

        ``reference=...`` installs a fresh snapshot (e.g. recomputed on
        the re-trained model); ``adopt_current=True`` promotes the
        monitor's OWN streaming state to be the new reference — the
        shifted serving distribution the model was just re-trained on
        becomes the new normal. The streaming EMA restarts either way so
        post-swap windows are judged on their own."""
        if adopt_current:
            if reference is not None:
                raise ValueError("pass reference= or adopt_current=True, "
                                 "not both")
            if float(self.state.count) <= 0:
                raise ValueError("adopt_current=True needs at least one "
                                 "observed window")
            self.reference = self.state
        elif reference is not None:
            self.reference = reference
        self.state = scenario_mod.init_drift_stats(
            int(self.reference.feat_mean.shape[0]))
        self.triggered = False
        self._over = 0

    # ------------------------------------------------------------------
    @classmethod
    def from_sample(cls, x, scores, **kw) -> "DriftMonitor":
        """Monitor whose reference is the exact moments of ``(x,
        scores)`` — the usual construction right after training, with
        ``scores`` produced by the model about to be served."""
        return cls(scenario_mod.reference_snapshot(
            jnp.asarray(np.asarray(x)), jnp.asarray(np.asarray(scores))),
            **kw)
