"""Model hot-swap: double-buffered parameters with an atomic flip.

The serving engine must never score a half-written model and never drop
a request because a new global model arrived. A :class:`ModelSlot` holds
the ACTIVE parameters (what every in-flight batch scores against) and at
most one STAGED set published by a background re-federation; the engine
calls :meth:`acquire` at each micro-batch boundary, which atomically
flips staged -> active under a lock and returns a consistent
(params, version) pair. Requests queued across a publish are simply
scored by whichever model is active when their batch runs — none are
dropped, and every response is stamped with the model version that
scored it.

Checkpoint provenance: :meth:`publish_checkpoint` ingests an
``ExperimentSession.checkpoint()`` artifact, validating its JSON sidecar
(``api/session.py: sidecar_path``) BEFORE paying for the restore —
a checkpoint trained for a different model raises
:class:`ServeModelError` and one whose round counter has not advanced
past the active model raises :class:`StaleCheckpointError`, instead of
silently serving a wrong or outdated detector.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.api import session as session_mod
from repro.checkpoint.io import CheckpointCorruptError


class ServeModelError(ValueError):
    """A checkpoint that must not be served: wrong model architecture /
    fingerprint for this slot."""


class StaleCheckpointError(ValueError):
    """A checkpoint whose round counter has not advanced beyond the
    model already being served — publishing it would roll the detector
    back. Pass ``allow_stale=True`` to force (e.g. explicit rollback)."""


@dataclasses.dataclass(frozen=True)
class ModelVersion:
    """Provenance stamped on every response that a model scores."""
    version: int                  # monotone flip counter (0 = initial)
    round_idx: int                # federation rounds behind the params
    model: Optional[str] = None   # config name from the sidecar
    source: str = "init"          # "init" | "publish" | checkpoint path


class ModelSlot:
    """Double-buffered (active, staged) parameter holder.

    Thread-safe: ``publish*`` may be called from a background
    re-federation thread while the serving thread calls ``acquire``
    between batches. The flip is a pointer swap under a lock — O(1),
    no copies — so swap churn never stalls the scoring loop.
    """

    def __init__(self, params: Any, *, model: Optional[str] = None,
                 round_idx: int = 0):
        self._lock = threading.Lock()
        self._active = jax.tree.map(jnp.asarray, params)
        self._meta = ModelVersion(version=0, round_idx=int(round_idx),
                                  model=model, source="init")
        self._staged: Optional[tuple] = None
        self.swaps = 0                   # completed flips (not publishes)

    # ------------------------------------------------------------------
    @property
    def version(self) -> int:
        with self._lock:
            return self._meta.version

    @property
    def meta(self) -> ModelVersion:
        with self._lock:
            return self._meta

    @property
    def staged_version(self) -> Optional[int]:
        with self._lock:
            return self._staged[1].version if self._staged else None

    def acquire(self) -> tuple:
        """(params, ModelVersion) for the NEXT micro-batch, flipping in
        any staged model first. Called at batch boundaries only, so a
        batch never mixes two models."""
        with self._lock:
            if self._staged is not None:
                self._active, self._meta = self._staged
                self._staged = None
                self.swaps += 1
            return self._active, self._meta

    # ------------------------------------------------------------------
    def publish(self, params: Any, *, round_idx: Optional[int] = None,
                model: Optional[str] = None,
                source: str = "publish") -> ModelVersion:
        """Stage ``params`` for the next batch boundary. Device transfer
        happens OUTSIDE the lock; only the pointer swap is serialized.
        Re-publishing before a flip replaces the staged model (last
        writer wins — the flip always installs the newest publish)."""
        dev = jax.tree.map(jnp.asarray, params)
        with self._lock:
            meta = ModelVersion(
                version=max(self._meta.version,
                            self._staged[1].version if self._staged
                            else self._meta.version) + 1,
                round_idx=int(self._meta.round_idx
                              if round_idx is None else round_idx),
                model=model if model is not None else self._meta.model,
                source=source)
            self._staged = (dev, meta)
        return meta

    def publish_checkpoint(self, ckpt_path: str,
                           spec=None, *, expect_model: Optional[str] = None,
                           allow_stale: bool = False,
                           round_base: int = 0,
                           fallback: bool = False) -> ModelVersion:
        """Validate + load an ``ExperimentSession.checkpoint()`` artifact
        and stage its global parameters.

        Validation order matters: the sidecar is read FIRST (cheap JSON)
        so a mismatched or stale checkpoint is rejected before the full
        restore pays to rebuild the world. ``expect_model`` defaults to
        the slot's current model name (when it has one); ``spec`` is
        forwarded to :meth:`ExperimentSession.restore` for checkpoints
        whose spec held unpicklable callables (e.g. a drifted-data
        factory). ``round_base`` offsets the sidecar's round counter —
        re-federation sessions count rounds from zero, so the federator
        passes the served model's counter to keep versions monotone.

        ``fallback=True`` recovers from a corrupt or sidecar-less
        artifact by publishing the newest digest-verified ``*.ckpt`` in
        the same directory instead
        (``api/session.py: latest_good_checkpoint``) — the model/
        staleness gates still apply to whatever actually publishes."""
        try:
            return self._publish_checkpoint(
                ckpt_path, spec, expect_model=expect_model,
                allow_stale=allow_stale, round_base=round_base)
        except (CheckpointCorruptError, FileNotFoundError):
            if not fallback:
                raise
            good = session_mod.latest_good_checkpoint(
                os.path.dirname(ckpt_path), exclude=(ckpt_path,))
            if good is None:
                raise
            return self._publish_checkpoint(
                good, spec, expect_model=expect_model,
                allow_stale=allow_stale, round_base=round_base)

    def _publish_checkpoint(self, ckpt_path: str, spec=None, *,
                            expect_model: Optional[str] = None,
                            allow_stale: bool = False,
                            round_base: int = 0) -> ModelVersion:
        meta = session_mod.read_sidecar(ckpt_path)
        model = meta.get("model")
        expect = expect_model if expect_model is not None \
            else self.meta.model
        if expect is not None and model != expect:
            raise ServeModelError(
                f"checkpoint {ckpt_path!r} holds model {model!r} but this "
                f"slot serves {expect!r} — refusing to hot-swap a "
                "different architecture")
        rounds_done = int(round_base) + int(meta.get("rounds_done", 0))
        with self._lock:
            newest = self._meta.round_idx
            if self._staged is not None:
                newest = max(newest, self._staged[1].round_idx)
        if rounds_done <= newest and not allow_stale:
            raise StaleCheckpointError(
                f"checkpoint {ckpt_path!r} is at round {rounds_done}, not "
                f"ahead of the served model (round {newest}) — refusing "
                "to roll the detector back (allow_stale=True overrides)")
        session = session_mod.ExperimentSession.restore(ckpt_path, spec=spec)
        params = session.result().params
        return self.publish(params, round_idx=rounds_done, model=model,
                            source=ckpt_path)
