"""Reproduction of "Reducing Communication Overhead in Federated Learning
for Network Anomaly Detection with Adaptive Client Selection".

The supported entry point is the declarative experiment layer::

    import repro

    result = repro.run_experiment(repro.ExperimentSpec(
        strategy="ours", rounds=8,
        world=repro.WorldSpec(num_clients=10, dropout_p=0.1)))

Lower layers (``repro.core``, ``repro.kernels``, ``repro.launch``, ...)
remain importable for engine-level work.
"""
from repro.api import (ClientProfile, CommModel, DataSpec, ExperimentResult,
                       ExperimentSession, ExperimentSpec, RoundRecord,
                       STRATEGY_REGISTRY, ScheduleSpec, Strategy,
                       StrategyConfig, SweepResult, WorldSpec, get_strategy,
                       list_strategies, register_strategy, run_experiment,
                       run_sweep)

__all__ = [
    "ClientProfile", "CommModel", "DataSpec", "ExperimentResult",
    "ExperimentSession", "ExperimentSpec", "RoundRecord",
    "STRATEGY_REGISTRY", "ScheduleSpec", "Strategy", "StrategyConfig",
    "SweepResult", "WorldSpec", "get_strategy", "list_strategies",
    "register_strategy", "run_experiment", "run_sweep",
]
