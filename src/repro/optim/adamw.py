"""Optimizers (optax-like (init, update) pairs, implemented from scratch).

``adamw``     — fp32 moments + fp32 master weights (mixed precision: the
                param pytree may be bf16; master copies live in opt state).
``adafactor`` — factored second moments, no first moment, no master copy;
                used by the large archs (granite-34b, arctic-480b, rwkv6-7b)
                where Adam's fp32 state would not fit v5e HBM.
``sgd``       — momentum SGD (paper's local-training baseline).

update(grads, state, params) -> (new_params, new_state). All arithmetic in
fp32; returned params are cast back to the input param dtype.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: callable
    update: callable


def _cast_like(new, old):
    return jax.tree.map(lambda n, o: n.astype(o.dtype), new, old)


# --------------------------------------------------------------------------
# AdamW (with master weights)
# --------------------------------------------------------------------------

def adamw(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
          keep_master=True):
    def init(params):
        f32 = lambda p: jnp.zeros_like(p, jnp.float32)
        state = {
            "m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
            "count": jnp.zeros((), jnp.int32),
        }
        if keep_master:
            state["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        return state

    def update(grads, state, params, lr_now=None):
        step_lr = lr if lr_now is None else lr_now
        c = state["count"] + 1
        bc1 = 1.0 - b1 ** c.astype(jnp.float32)
        bc2 = 1.0 - b2 ** c.astype(jnp.float32)
        ref = state.get("master", params)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            pf = p.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = step_lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            pf = pf - step - step_lr * weight_decay * pf
            return m, v, pf

        out = jax.tree.map(upd, grads, state["m"], state["v"], ref)
        m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        pf = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        new_state = {"m": m, "v": v, "count": c}
        if keep_master:
            new_state["master"] = pf
        return _cast_like(pf, params), new_state

    return Optimizer(init, update)


# --------------------------------------------------------------------------
# Adafactor (factored second moment)
# --------------------------------------------------------------------------

def adafactor(lr=1e-3, decay=0.8, eps=1e-30, clip_threshold=1.0):
    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p.shape):
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"stats": jax.tree.map(per_leaf, params,
                                      is_leaf=lambda x: hasattr(x, "shape")),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr_now=None):
        step_lr = lr if lr_now is None else lr_now
        c = state["count"] + 1
        beta = 1.0 - c.astype(jnp.float32) ** (-decay)

        def upd(g, st, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p.shape):
                r = beta * st["r"] + (1 - beta) * g2.mean(-1)
                cc = beta * st["c"] + (1 - beta) * g2.mean(-2)
                denom = (r[..., None] * cc[..., None, :]
                         / jnp.maximum(r.mean(-1)[..., None, None], eps))
                u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_st = {"r": r, "c": cc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_st = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            pf = p.astype(jnp.float32) - step_lr * u
            return new_st, pf

        out = jax.tree.map(upd, grads, state["stats"], params,
                           is_leaf=lambda x: isinstance(x, dict) and
                           ("r" in x or "v" in x))
        stats = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        pf = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        return _cast_like(pf, params), {"stats": stats, "count": c}

    return Optimizer(init, update)


# --------------------------------------------------------------------------
# SGD (momentum)
# --------------------------------------------------------------------------

def sgd(lr=1e-2, momentum=0.9):
    def init(params):
        return {"mom": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                    params)}

    def update(grads, state, params, lr_now=None):
        step_lr = lr if lr_now is None else lr_now

        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return m, p.astype(jnp.float32) - step_lr * m

        out = jax.tree.map(upd, grads, state["mom"], params)
        mom = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        pf = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return _cast_like(pf, params), {"mom": mom}

    return Optimizer(init, update)


def for_config(cfg, lr=1e-3):
    if cfg.optimizer == "adafactor":
        return adafactor(lr)
    return adamw(lr, keep_master=(cfg.dtype != "float32"))
