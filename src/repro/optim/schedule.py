"""LR schedules (pure functions of step). Paper: "Adjust learning rate
with scheduler" (Algorithm 1 line 25)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr):
    return lambda step: jnp.float32(lr)


def cosine(lr, warmup_steps, total_steps, final_frac=0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) /
                     jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


def step_decay(lr, decay_every, gamma=0.5):
    def fn(step):
        k = jnp.asarray(step, jnp.float32) // decay_every
        return jnp.float32(lr) * gamma ** k
    return fn
