"""Dynamic loss scaler — JAX analogue of torch.cuda.amp.GradScaler (§IV-A).

The paper trains clients with autocast(float16) + GradScaler. On TPU we
default to bf16 (no scaler needed), but the scaler is implemented and
tested for fp16 parity: loss is multiplied by ``scale`` before grad;
gradients are unscaled; if any gradient is non-finite the update is
SKIPPED and the scale halves; after ``growth_interval`` consecutive good
steps the scale doubles. Pure pytree state — safe inside jit.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ScalerState(NamedTuple):
    scale: jnp.ndarray        # f32 scalar
    good_steps: jnp.ndarray   # i32 scalar


def init_scaler(init_scale: float = 2.0 ** 15) -> ScalerState:
    return ScalerState(jnp.float32(init_scale), jnp.int32(0))


def scale_loss(loss, state: ScalerState):
    return loss * state.scale


def unscale_grads(grads, state: ScalerState):
    return jax.tree.map(lambda g: g.astype(jnp.float32) / state.scale, grads)


def grads_finite(grads) -> jnp.ndarray:
    leaves = jax.tree.leaves(grads)
    ok = jnp.bool_(True)
    for leaf in leaves:
        ok &= jnp.all(jnp.isfinite(leaf))
    return ok


def next_state(state: ScalerState, finite: jnp.ndarray,
               growth_interval: int = 200, growth: float = 2.0,
               backoff: float = 0.5, max_scale: float = 2.0 ** 24) -> ScalerState:
    good = jnp.where(finite, state.good_steps + 1, 0)
    grow = good >= growth_interval
    scale = jnp.where(
        finite,
        jnp.where(grow, jnp.minimum(state.scale * growth, max_scale), state.scale),
        jnp.maximum(state.scale * backoff, 1.0))
    good = jnp.where(grow, 0, good)
    return ScalerState(scale, good)


def apply_or_skip(finite, new_params, params, new_opt, opt_state):
    """Keep old (params, opt_state) when grads were non-finite."""
    sel = lambda a, b: jax.tree.map(
        lambda x, y: jnp.where(finite, x, y), a, b)
    return sel(new_params, params), sel(new_opt, opt_state)
