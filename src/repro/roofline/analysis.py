"""Three-term roofline analysis from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
  memory term     = HLO_bytes / (chips × 819 GB/s HBM)
  collective term = collective_bytes / (chips × 50 GB/s ICI)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-module
totals across all devices on this backend); collective_bytes from the HLO
census. MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) — the ratio
MODEL_FLOPS/HLO_FLOPs flags remat/redundancy waste. The dominant term is
the bottleneck the §Perf loop iterates on.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    useful_ratio: float          # MODEL_FLOPS / HLO_FLOPs
    bytes_per_device: Optional[float] = None
    op_counts: Optional[dict] = None

    def as_row(self) -> str:
        return (f"{self.arch:22s} {self.shape:11s} {self.mesh:9s} "
                f"c={self.t_compute:.3e}s m={self.t_memory:.3e}s "
                f"x={self.t_collective:.3e}s -> {self.dominant:10s} "
                f"useful={self.useful_ratio:.2f}")


def model_flops(cfg, shape) -> float:
    """6·N·D with N = active params, D = processed tokens (or samples)."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        d_tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * d_tokens
    if shape.kind == "prefill":
        d_tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * d_tokens       # forward only
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze(arch: str, shape, mesh_name: str, chips: int,
            cost: dict, census: dict, cfg, memory_stats=None) -> Roofline:
    # Quantities come from the loop-aware HLO analyzer (hlo_census.analyze)
    # because XLA's cost_analysis counts while-loop bodies ONCE — a ~L×
    # undercount for scanned layers and ~seq× for SSM time-scans. Both the
    # analyzer and cost_analysis describe the PER-DEVICE partitioned
    # program (verified: a (1024,1024)² matmul over 16 devices reports
    # 2·1024³/16 flops), so roofline terms divide by a single chip's peak.
    flops_dev = float(census.get("flops", 0.0) or 0.0)
    bytes_dev = float(census.get("traffic_bytes", 0.0) or 0.0)
    # fall back to cost_analysis if the text analyzer found nothing
    if flops_dev == 0.0:
        flops_dev = float(cost.get("flops", 0.0) or 0.0)
    if bytes_dev == 0.0:
        bytes_dev = float(cost.get("bytes accessed", 0.0) or 0.0)
    coll_dev = float(census.get("collective_bytes", 0))
    t_c = flops_dev / PEAK_FLOPS_BF16
    t_m = bytes_dev / HBM_BW
    t_x = coll_dev / ICI_BW
    mf = model_flops(cfg, shape)
    dominant = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                   key=lambda kv: kv[1])[0]
    total_flops = flops_dev * chips
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=total_flops, hlo_bytes=bytes_dev * chips,
        collective_bytes=coll_dev * chips,
        model_flops=mf, t_compute=t_c, t_memory=t_m, t_collective=t_x,
        dominant=dominant,
        useful_ratio=(mf / total_flops) if total_flops else 0.0,
        bytes_per_device=memory_stats,
        op_counts=census.get("op_counts"))


def save_jsonl(path: str, rows):
    with open(path, "a") as f:
        for r in rows:
            f.write(json.dumps(dataclasses.asdict(r)) + "\n")


def load_jsonl(path: str):
    rows = []
    with open(path) as f:
        for line in f:
            if line.strip():
                rows.append(json.loads(line))
    return rows
