"""Loop-aware post-partitioning HLO analyzer.

XLA's ``compiled.cost_analysis()`` counts ``while``-loop bodies ONCE —
for scan-over-layers models that under-reports FLOPs by ~L× and for SSM
time-scans by ~seq_len×. This module re-derives per-device costs from
``compiled.as_text()`` (the optimized SPMD-partitioned module) with
proper trip-count multipliers (the MD-Roofline idea [Miao et al. 2022],
which the paper cites as related work §III):

  1. split the module into named computations;
  2. build the call graph (while body/condition, fusion ``calls=``,
     ``to_apply=`` regions) and propagate visit counts: a while body is
     visited trip-count times (trip parsed from the max integer constant
     in its condition computation);
  3. FLOPs: 2·|result|·K for every ``dot`` (K = product of lhs
     contracting dims), scaled by visits. Elementwise flops are ignored
     (negligible vs dots for these models);
  4. traffic: Σ materialized-instruction result bytes × visits × 2
     (write + re-read heuristic) + entry parameter bytes. Instructions
     inside fusion bodies are NOT materialized and are excluded;
  5. collective bytes: result bytes of all-reduce / all-gather /
     reduce-scatter / all-to-all / collective-permute × visits.

All quantities are PER DEVICE (the partitioned module is per-device).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\(")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CONST_INT = re.compile(r"\bconstant\((\d+)\)")
_CALLS = re.compile(r"(?:calls|to_apply|body|condition)=%([\w\.\-]+)")

_SKIP_TRAFFIC_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                     "bitcast", "after-all", "partition-id", "replica-id",
                     "iota"}


def _shape_elems_bytes(shape_str):
    """(elems, bytes) summed over all array components of the type."""
    elems = byts = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _dims_list(shape_str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def parse_computations(text: str):
    """-> (comps: {name: [instr dicts]}, entry_name)."""
    comps = {}
    entry = None
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hm = _COMP_HEADER.match(line.strip())
        if hm and ("=" not in line.split("(")[0]):
            cur = hm.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        im = _INSTR.match(line)
        if im:
            name, rtype, opcode = im.groups()
            rest = line[im.end():]
            comps[cur].append({
                "name": name, "type": rtype, "op": opcode,
                "line": line, "rest": rest,
            })
    return comps, entry


def _dot_flops(instr, symtab):
    # operands: first two %refs after the opening paren
    ops = _OPERAND.findall(instr["rest"].split("),")[0] + ")")
    lhs_shape = symtab.get(ops[0]) if ops else None
    res_elems, _ = _shape_elems_bytes(instr["type"])
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr["line"])
    k = 1
    if lhs_shape and m:
        dims = _dims_list(lhs_shape)
        for i in m.group(1).split(","):
            if i != "" and int(i) < len(dims):
                k *= dims[int(i)]
    return 2.0 * res_elems * k


def analyze(text: str) -> dict:
    comps, entry = parse_computations(text)

    # symbol tables (name -> type string) per computation
    symtabs = {c: {i["name"]: i["type"] for i in instrs}
               for c, instrs in comps.items()}

    # call graph with multipliers; identify fusion-body computations
    edges = defaultdict(list)          # parent -> [(child, mult)]
    fusion_bodies = set()
    trip_of_body = {}
    for cname, instrs in comps.items():
        for i in instrs:
            refs = dict()
            for m in re.finditer(r"(calls|to_apply|body|condition)=%([\w\.\-]+)",
                                 i["line"]):
                refs[m.group(1)] = m.group(2)
            if i["op"] == "while":
                body, cond = refs.get("body"), refs.get("condition")
                trip = 1
                if cond and cond in comps:
                    consts = [int(x) for ins in comps[cond]
                              for x in _CONST_INT.findall(ins["line"])]
                    # also scan full text lines of cond comp (constants may
                    # appear in fusion bodies called from cond)
                    for sub in _CALLS.findall(
                            "\n".join(x["line"] for x in comps[cond])):
                        if sub in comps:
                            consts += [int(x) for ins in comps[sub]
                                       for x in _CONST_INT.findall(ins["line"])]
                    if consts:
                        trip = max(consts)
                if body:
                    edges[cname].append((body, trip))
                    trip_of_body[body] = trip
                if cond:
                    edges[cname].append((cond, trip + 1))
            else:
                if "calls" in refs:
                    edges[cname].append((refs["calls"], 1))
                    fusion_bodies.add(refs["calls"])
                if "to_apply" in refs:
                    fusion_bodies.add(refs["to_apply"])

    # propagate visit counts from entry (DAG -> fixed point in few passes)
    visits = defaultdict(float)
    if entry:
        visits[entry] = 1.0
    for _ in range(64):
        changed = False
        nv = defaultdict(float)
        if entry:
            nv[entry] = 1.0
        for parent, chs in edges.items():
            for child, mult in chs:
                nv[child] += visits[parent] * mult
        for k in set(list(nv) + list(visits)):
            if abs(nv.get(k, 0) - visits.get(k, 0)) > 0.5 and k != entry:
                changed = True
        visits = nv
        if not changed:
            break

    flops = 0.0
    traffic = 0.0
    coll_bytes = defaultdict(float)
    op_counts = defaultdict(float)
    total_instr = 0
    for cname, instrs in comps.items():
        v = max(visits.get(cname, 0.0), 0.0)
        if v == 0:
            continue
        materialized = cname not in fusion_bodies
        st = symtabs[cname]
        for i in instrs:
            total_instr += 1
            op = i["op"]
            base = op
            for c in _COLLECTIVES:
                if op == c or op.startswith(c + "-"):
                    base = c
                    break
            op_counts[base] += v
            _, rbytes = _shape_elems_bytes(i["type"])
            if op == "dot":
                flops += v * _dot_flops(i, st)
            if base in _COLLECTIVES and not op.endswith("-done"):
                coll_bytes[base] += v * rbytes
            if materialized and op not in _SKIP_TRAFFIC_OPS:
                traffic += v * rbytes * 2.0     # write + re-read heuristic
            if materialized and op == "parameter" and cname == entry:
                traffic += rbytes
    return {
        "flops": flops,
        "traffic_bytes": traffic,
        "collective_bytes": float(sum(coll_bytes.values())),
        "per_op_bytes": {k: float(x) for k, x in coll_bytes.items()},
        "op_counts": {k: float(x) for k, x in op_counts.items()},
        "total_instructions": total_instr,
        "while_trips": dict(trip_of_body),
    }


# Back-compat alias used by early tests
def census(text: str) -> dict:
    return analyze(text)
