"""Hymba-style hybrid layer: parallel attention + Mamba (SSM) heads.

Each layer runs a GQA attention branch and a selective-SSM (Mamba) branch
on the SAME normed input; branch outputs are each normalized and averaged
(the Hymba fusion, arXiv:2411.13676), followed by a SwiGLU FFN. The SSM
branch gives the layer O(1) decode state, so hymba runs ``long_500k``
natively (attention heads use a sliding window on that shape).

Mamba branch (inner dim == d_model, state n = cfg.ssm_state):
    xz = x @ Win ; x1, z = split
    x1 = silu(causal_conv4(x1))
    dt = softplus(x1 @ Wdt1 @ Wdt2 + dt_bias)
    h_t = exp(dt_t * A) h_{t-1} + (dt_t * x1_t) B_t ;  y_t = h_t · C_t + D x1_t
    out = (y * silu(z)) @ Wout
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

_CONV_W = 4  # causal conv taps


def _dtr(cfg):
    return max(cfg.d_model // 16, 8)


def _mamba_params(cfg, key, dtype):
    d, n = cfg.d_model, cfg.ssm_state
    di, dtr = d, _dtr(cfg)
    ks = jax.random.split(key, 6)
    return {
        "Win": L.dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": L.dense_init(ks[1], (_CONV_W, di), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "Wdt1": L.dense_init(ks[2], (di, dtr), dtype),
        "Wdt2": L.dense_init(ks[3], (dtr, di), dtype),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus -> ~0.01
        "WB": L.dense_init(ks[4], (di, n), dtype),
        "WC": L.dense_init(ks[5], (di, n), dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "Wout": L.dense_init(jax.random.fold_in(key, 7), (di, d), dtype),
    }


def _layer_init(cfg, key, dtype):
    ks = jax.random.split(key, 6)
    return {
        "ln1": L.norm_params(cfg, ks[0], cfg.d_model, dtype),
        "attn": L.attn_params(cfg, ks[1], dtype),
        "mamba": _mamba_params(cfg, ks[2], dtype),
        "attn_out_norm": {"w": jnp.ones((cfg.d_model,), dtype)},
        "ssm_out_norm": {"w": jnp.ones((cfg.d_model,), dtype)},
        "ln2": L.norm_params(cfg, ks[3], cfg.d_model, dtype),
        "ffn": L.ffn_params(cfg, ks[4], dtype),
    }


def init_params(rng, cfg):
    dtype = cfg.compute_dtype
    k_emb, k_layers, k_head = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    return {
        "embed": L.embed_init(k_emb, (cfg.padded_vocab, cfg.d_model), dtype),
        "layers": jax.vmap(lambda k: _layer_init(cfg, k, dtype))(layer_keys),
        "final_norm": L.norm_params(cfg, k_head, cfg.d_model, dtype),
        "lm_head": L.dense_init(k_head, (cfg.d_model, cfg.padded_vocab), dtype),
    }


# --------------------------------------------------------------------------
# mamba branch
# --------------------------------------------------------------------------

def _causal_conv(mp, x1):
    """x1: (B,T,di) — 4-tap depthwise causal conv via shifts."""
    out = x1 * mp["conv_w"][-1]
    for tap in range(1, _CONV_W):
        shifted = jnp.pad(x1, ((0, 0), (tap, 0), (0, 0)))[:, :-tap]
        out = out + shifted * mp["conv_w"][-1 - tap]
    return out + mp["conv_b"]


def _ssm_scan(mp, x1, dt, Bm, Cm, h0, unroll: int = 16):
    """h0: (B,di,n) fp32. Returns (y (B,T,di), h_T).

    §Perf iteration A: ``unroll`` amortizes the HBM round-trip of the
    (B,di,n) state across unrolled steps (see rwkv6._wkv_scan)."""
    A = -jnp.exp(mp["A_log"])                             # (di,n)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp                         # (B,di),(B,di),(B,n),(B,n)
        dA = jnp.exp(dt_t[..., None] * A)                 # (B,di,n)
        h = dA * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.sum(h * C_t[:, None, :], axis=-1) + mp["D"] * x_t
        return h, y

    xs = (jnp.moveaxis(x1.astype(jnp.float32), 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    T = xs[0].shape[0]
    h_T, y = jax.lax.scan(step, h0, xs,
                          unroll=unroll if T % unroll == 0 else 1)
    return jnp.moveaxis(y, 0, 1), h_T


def _mamba_forward(mp, x, h0):
    """Returns (out, h_T, x1_raw_tail) — the tail is the PRE-conv x1 inputs
    (last CONV_W-1 steps) the decode path needs to resume the conv."""
    xz = x @ mp["Win"]
    x1_raw, z = jnp.split(xz, 2, axis=-1)
    x1 = jax.nn.silu(_causal_conv(mp, x1_raw))
    dt = jax.nn.softplus(
        ((x1 @ mp["Wdt1"]) @ mp["Wdt2"]).astype(jnp.float32) + mp["dt_bias"])
    Bm = (x1 @ mp["WB"]).astype(jnp.float32)
    Cm = (x1 @ mp["WC"]).astype(jnp.float32)
    y, h_T = _ssm_scan(mp, x1, dt, Bm, Cm, h0)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    tail = x1_raw[:, -(_CONV_W - 1):]
    return y @ mp["Wout"], h_T, tail


# --------------------------------------------------------------------------
# forward / loss / decode
# --------------------------------------------------------------------------

def forward(params, batch, cfg, *, return_cache: bool = False):
    x = params["embed"][batch["tokens"]]
    B, T, d = x.shape
    n = cfg.ssm_state
    h0 = jnp.zeros((B, d, n), jnp.float32)
    positions = jnp.arange(T)[None, :]

    def body(h, lp):
        z = L.apply_norm(cfg, h, lp["ln1"])
        a_out, (k, v) = L.full_attention(
            cfg, lp["attn"], z, positions=positions, causal=True,
            sliding_window=cfg.sliding_window)
        m_out, h_T, conv_tail = _mamba_forward(lp["mamba"], z, h0)
        fused = 0.5 * (L.rmsnorm(a_out, lp["attn_out_norm"]["w"])
                       + L.rmsnorm(m_out, lp["ssm_out_norm"]["w"]))
        h = h + fused
        z = L.apply_norm(cfg, h, lp["ln2"])
        h = h + L.ffn(cfg, lp["ffn"], z)
        ys = (k, v, h_T, conv_tail) if return_cache else None
        return h, ys

    if cfg.remat and not return_cache:
        body = jax.checkpoint(body, prevent_cse=False)
    x, caches = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = x @ params["lm_head"]
    cache = None
    if return_cache:
        # conv cache = last CONV_W-1 pre-conv x1 inputs per layer
        cache = {"k": caches[0], "v": caches[1], "h": caches[2],
                 "conv": caches[3], "step": jnp.asarray(T, jnp.int32)}
    return logits, cache, jnp.float32(0.0)


def loss_fn(params, batch, cfg):
    logits, _, _ = forward(params, batch, cfg)
    return L.softmax_xent(logits[:, :-1], batch["labels"][:, 1:])


def prefill(params, batch, cfg):
    logits, cache, _ = forward(params, batch, cfg, return_cache=True)
    return logits, cache


def init_cache(cfg, batch_size: int, seq_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    Sc = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    Lyr, d, n = cfg.num_layers, cfg.d_model, cfg.ssm_state
    kv = (Lyr, batch_size, Sc, cfg.num_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
        "h": jnp.zeros((Lyr, batch_size, d, n), jnp.float32),
        "conv": jnp.zeros((Lyr, batch_size, _CONV_W - 1, d), dtype),
        "step": jnp.asarray(0, jnp.int32),
    }


def _mamba_decode(mp, x, h, conv_tail):
    """x: (B,1,d); conv_tail: (B,CONV_W-1,di) previous x1-inputs."""
    xz = x @ mp["Win"]
    x1_new, z = jnp.split(xz, 2, axis=-1)                 # (B,1,di)
    window = jnp.concatenate([conv_tail, x1_new], axis=1)  # (B,CONV_W,di)
    c = jnp.einsum("btd,td->bd", window, mp["conv_w"]) + mp["conv_b"]
    x1 = jax.nn.silu(c)[:, None, :]                       # (B,1,di)
    dt = jax.nn.softplus(
        ((x1 @ mp["Wdt1"]) @ mp["Wdt2"]).astype(jnp.float32) + mp["dt_bias"])
    Bm = (x1 @ mp["WB"]).astype(jnp.float32)
    Cm = (x1 @ mp["WC"]).astype(jnp.float32)
    y, h_n = _ssm_scan(mp, x1, dt, Bm, Cm, h)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ mp["Wout"], h_n, window[:, 1:]


def decode_step(params, cache, batch, cfg):
    x = params["embed"][batch["tokens"]]
    step = cache["step"]

    def body(h, lp_state):
        lp, ck, cv, hs, conv = lp_state
        z = L.apply_norm(cfg, h, lp["ln1"])
        a_out, nk, nv = L.decode_attention(
            cfg, lp["attn"], z, ck, cv, step,
            sliding_window=cfg.sliding_window)
        m_out, h_n, conv_n = _mamba_decode(lp["mamba"], z, hs, conv)
        fused = 0.5 * (L.rmsnorm(a_out, lp["attn_out_norm"]["w"])
                       + L.rmsnorm(m_out, lp["ssm_out_norm"]["w"]))
        h = h + fused
        z = L.apply_norm(cfg, h, lp["ln2"])
        h = h + L.ffn(cfg, lp["ffn"], z)
        return h, (nk, nv, h_n, conv_n)

    x, (nk, nv, nh, nconv) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["h"], cache["conv"]))
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = x @ params["lm_head"]
    return logits, {"k": nk, "v": nv, "h": nh, "conv": nconv,
                    "step": step + 1}
