"""Decoder-only transformer (dense, MoE, VLM families).

Per-layer parameters are stacked on a leading layer axis and consumed via
``jax.lax.scan``; the layer body is optionally wrapped in ``jax.checkpoint``
(remat) for training. The same stack serves:

  dense — llama-style (granite-34b, qwen2, stablelm, phi3)
  moe   — FFN replaced by top-k mixture of experts (granite-moe, arctic)
  vlm   — InternVL2: stubbed patch embeddings are projected and prepended
          to the token embeddings (internvl2-2b)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_mod


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _layer_init(cfg, key, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.norm_params(cfg, ks[0], cfg.d_model, dtype),
        "attn": L.attn_params(cfg, ks[1], dtype),
        "ln2": L.norm_params(cfg, ks[2], cfg.d_model, dtype),
    }
    if cfg.num_experts:
        p["moe"] = moe_mod.moe_params(cfg, ks[3], dtype)
    else:
        p["ffn"] = L.ffn_params(cfg, ks[3], dtype)
    return p


def init_params(rng, cfg):
    dtype = cfg.compute_dtype
    k_emb, k_layers, k_head, k_proj = jax.random.split(rng, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    params = {
        "embed": L.embed_init(k_emb, (cfg.padded_vocab, cfg.d_model), dtype),
        "layers": jax.vmap(lambda k: _layer_init(cfg, k, dtype))(layer_keys),
        "final_norm": L.norm_params(cfg, k_head, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.padded_vocab), dtype)
    if cfg.family == "vlm":
        params["patch_proj"] = L.dense_init(k_proj, (cfg.d_model, cfg.d_model), dtype)
    return params


# --------------------------------------------------------------------------
# forward (training / prefill)
# --------------------------------------------------------------------------

def _embed_inputs(params, cfg, batch):
    x = params["embed"][batch["tokens"]]
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([patches, x], axis=1)
    return x


def forward(params, batch, cfg, *, return_cache: bool = False):
    """Returns (logits, cache_or_None, aux_loss)."""
    x = _embed_inputs(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]

    def body(carry, lp):
        h, aux = carry
        a_in = L.apply_norm(cfg, h, lp["ln1"])
        a_out, (k, v) = L.full_attention(
            cfg, lp["attn"], a_in, positions=positions, causal=True,
            sliding_window=cfg.sliding_window)
        h = h + a_out
        f_in = L.apply_norm(cfg, h, lp["ln2"])
        if cfg.num_experts:
            f_out, moe_aux = moe_mod.moe_ffn(cfg, lp["moe"], f_in)
            aux = aux + moe_aux
        else:
            f_out = L.ffn(cfg, lp["ffn"], f_in)
        h = h + f_out
        ys = (k, v) if return_cache else None
        return (h, aux), ys

    if cfg.remat and not return_cache:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
    x = L.apply_norm(cfg, x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    cache = None
    if return_cache:
        cache = {"k": caches[0], "v": caches[1],
                 "step": jnp.asarray(S, jnp.int32)}
    return logits, cache, aux


def loss_fn(params, batch, cfg):
    logits, _, aux = forward(params, batch, cfg)
    if cfg.family == "vlm":  # drop patch positions from the LM loss
        logits = logits[:, cfg.num_patches:]
    xent = L.softmax_xent(logits[:, :-1], batch["labels"][:, 1:])
    return xent + cfg.router_aux_weight * aux


def prefill(params, batch, cfg):
    logits, cache, _ = forward(params, batch, cfg, return_cache=True)
    return logits, cache


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, seq_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    Sc = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    shape = (cfg.num_layers, batch_size, Sc, cfg.num_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "step": jnp.asarray(0, jnp.int32)}


def decode_step(params, cache, batch, cfg):
    """batch: {"tokens": (B,1)}. Returns (logits (B,1,V), new_cache)."""
    x = params["embed"][batch["tokens"]]
    step = cache["step"]

    def body(h, lp_and_cache):
        lp, ck, cv = lp_and_cache
        a_in = L.apply_norm(cfg, h, lp["ln1"])
        a_out, nk, nv = L.decode_attention(
            cfg, lp["attn"], a_in, ck, cv, step,
            sliding_window=cfg.sliding_window)
        h = h + a_out
        f_in = L.apply_norm(cfg, h, lp["ln2"])
        if cfg.num_experts:
            f_out, _ = moe_mod.moe_ffn(cfg, lp["moe"], f_in)
        else:
            f_out = L.ffn(cfg, lp["ffn"], f_in)
        return h + f_out, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.apply_norm(cfg, x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, {"k": nk, "v": nv, "step": step + 1}
