"""Shared neural-net layer primitives (pure functions over pytrees).

Conventions:
  * params are dicts of jnp arrays; per-layer params are STACKED over a
    leading layer dim and consumed via ``jax.lax.scan``.
  * activations default to the config compute dtype (bf16); softmax and
    normalization statistics run in fp32.
  * attention supports GQA (grouped einsum — KV heads are never repeated
    into H full heads), causal masks, sliding windows, and single-token
    decode against a (cyclic) KV cache.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm(x, weight, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg, x, p, prefix=""):
    if cfg.norm == "layernorm":
        return layernorm(x, p[prefix + "w"], p[prefix + "b"])
    return rmsnorm(x, p[prefix + "w"])


def norm_params(cfg, key, d, dtype):
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {"w": jnp.ones((d,), dtype)}


# --------------------------------------------------------------------------
# rotary position embeddings (partial fraction supported)
# --------------------------------------------------------------------------

def rope_freqs(hd: int, fraction: float, theta: float):
    rot = int(hd * fraction)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, fraction: float, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv, rot = rope_freqs(hd, fraction, theta)
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # (...,S,1,rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., 0::2].astype(jnp.float32), xr[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1)


def sinusoidal_positions(seq: int, d: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe


def sinusoidal_position_at(pos, d: int):
    """Single-position sinusoidal embedding; pos may be a traced scalar."""
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = jnp.asarray(pos, jnp.float32) / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((d,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(ang))
    pe = pe.at[1::2].set(jnp.cos(ang))
    return pe


# --------------------------------------------------------------------------
# attention (GQA, grouped einsum; full-sequence and decode paths)
# --------------------------------------------------------------------------

def attn_params(cfg, key, dtype, d=None):
    d = d or cfg.d_model
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype),
        "wk": dense_init(ks[1], (d, K * hd), dtype),
        "wv": dense_init(ks[2], (d, K * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype, scale=1.0 / math.sqrt(2 * cfg.num_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    return p


def _project_qkv(cfg, p, x, xkv=None):
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    xkv = x if xkv is None else xkv
    q = x @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, xkv.shape[1], K, hd)
    v = v.reshape(B, xkv.shape[1], K, hd)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,Sq,H,hd) k: (B,Sk,K,hd) -> scores (B,K,G,Sq,Sk) fp32."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32))
    return s / math.sqrt(hd)


def _gqa_out(probs, v, dtype):
    """probs: (B,K,G,Sq,Sk) v: (B,Sk,K,hd) -> (B,Sq,H*hd)."""
    B, K, G, Sq, Sk = probs.shape
    hd = v.shape[-1]
    o = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return o.reshape(B, Sq, K * G * hd).astype(dtype)


# §Perf iteration B (EXPERIMENTS.md): blockwise attention in PURE XLA was
# tried as the S²-score fix and REFUTED — XLA spills the (m,l,acc) scan
# carries to HBM every KV block, so the memory term got WORSE (hymba
# train: 62.6s -> 182.9s). Flash attention only pays off with
# VMEM-resident accumulators -> the Pallas kernel in kernels/flash_attn.py
# (iteration C). blockwise_attention stays as the kernel's pure-jnp
# oracle and an opt-in (cfg.attention_impl="blockwise").
FLASH_BLOCK = 512


def full_attention(cfg, p, x, positions=None, causal=True, xkv=None,
                   sliding_window: Optional[int] = None, use_rope=True):
    """Full-sequence attention (training / prefill / encoder / cross)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, xkv)
    Sk = k.shape[1]
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if use_rope and xkv is None:
        q = apply_rope(q, positions, cfg.rope_fraction, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_fraction, cfg.rope_theta)
    if (getattr(cfg, "attention_impl", "full") == "blockwise"
            and S % FLASH_BLOCK == 0 and Sk % FLASH_BLOCK == 0):
        out = blockwise_attention(q, k, v, causal=(causal and xkv is None),
                                  sliding_window=sliding_window,
                                  out_dtype=x.dtype)
        return out @ p["wo"], (k, v)
    scores = _gqa_scores(q, k)                     # (B,K,G,S,Sk)
    if causal and xkv is None:
        i = jnp.arange(S)[:, None]
        j = jnp.arange(Sk)[None, :]
        mask = j <= i
        if sliding_window is not None:
            mask &= (i - j) < sliding_window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v, x.dtype)
    return out @ p["wo"], (k, v)


def blockwise_attention(q, k, v, *, causal: bool, sliding_window=None,
                        out_dtype, block: int = FLASH_BLOCK):
    """Flash-style online-softmax attention in pure JAX.

    Never materializes more than one (B,K,G,block,block) score tile at a
    time; running (max, sum, acc) statistics carry across KV blocks via
    ``lax.scan``. Memory per step: O(block²) vs O(S²). Causality is
    enforced per tile; fully-masked tiles still compute (branch-free SPMD)
    but their contribution multiplies to zero.
    """
    import math as _math
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    nq, nk = S // block, k.shape[1] // block
    scale = 1.0 / _math.sqrt(hd)
    qf = q.reshape(B, nq, block, K, G, hd).astype(jnp.float32)
    kf = k.reshape(B, nk, block, K, hd).astype(jnp.float32)
    vf = v.reshape(B, nk, block, K, hd).astype(jnp.float32)

    q_idx = jnp.arange(block)
    k_idx = jnp.arange(block)

    def q_block(qi, qb):
        # qb: (B, block, K, G, hd)
        def kv_step(carry, kv):
            m, l, acc = carry
            kj, kb, vb = kv                     # kb/vb: (B, block, K, hd)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb) * scale
            if causal or sliding_window is not None:
                qi_abs = qi * block + q_idx[:, None]
                kj_abs = kj * block + k_idx[None, :]
                mask = jnp.ones((block, block), bool)
                if causal:
                    mask &= kj_abs <= qi_abs
                if sliding_window is not None:
                    mask &= (qi_abs - kj_abs) < sliding_window
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] \
                + jnp.einsum("bkgqs,bskd->bkgqd", p, vb)
            return (m_new, l, acc), None

        m0 = jnp.full((B, K, G, block), -1e30, jnp.float32)
        l0 = jnp.zeros((B, K, G, block), jnp.float32)
        a0 = jnp.zeros((B, K, G, block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)     # (B,K,G,block,hd)
        return jnp.moveaxis(out, 3, 1).reshape(B, block, K * G * hd)

    outs = jax.lax.map(lambda args: q_block(*args),
                       (jnp.arange(nq), jnp.moveaxis(qf, 1, 0)))
    # outs: (nq, B, block, H*hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H * hd).astype(out_dtype)


def decode_attention(cfg, p, x, cache_k, cache_v, step, *,
                     sliding_window: Optional[int] = None, cross=False,
                     use_rope: bool = True):
    """One-token decode. x: (B,1,d). cache_[kv]: (B,Scache,K,hd).

    For sliding-window archs the cache is cyclic with Scache == window and
    the new KV is written at ``step % window``. Returns (out, new_k, new_v).
    """
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(cfg, p, x)
    Sc = cache_k.shape[1]
    if cross:
        # cross attention: cache holds pre-projected encoder KV, no update
        k, v = cache_k, cache_v
        scores = _gqa_scores(q, k)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, v, x.dtype)
        return out @ p["wo"], cache_k, cache_v
    if use_rope:
        pos = jnp.full((B, 1), step)
        q = apply_rope(q, pos, cfg.rope_fraction, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_fraction, cfg.rope_theta)
    slot = step % Sc if sliding_window is not None else step
    k = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype),
                                     (0, slot, 0, 0))
    scores = _gqa_scores(q, k)                     # (B,K,G,1,Sc)
    s_idx = jnp.arange(Sc)
    if sliding_window is not None:
        # slot s holds absolute position step - ((step - s) mod Sc)
        slot_pos = step - jnp.mod(step - s_idx, Sc)
        valid = (slot_pos >= 0) & (slot_pos <= step)
    else:
        valid = s_idx <= step
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v, x.dtype)
    return out @ p["wo"], k, v


# --------------------------------------------------------------------------
# feed-forward
# --------------------------------------------------------------------------

def ffn_params(cfg, key, dtype, d=None, ff=None):
    d = d or cfg.d_model
    ff = ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_act == "swiglu":
        return {
            "wg": dense_init(ks[0], (d, ff), dtype),
            "wu": dense_init(ks[1], (d, ff), dtype),
            "wd": dense_init(ks[2], (ff, d), dtype, scale=1.0 / math.sqrt(2 * cfg.num_layers)),
        }
    return {
        "w1": dense_init(ks[0], (d, ff), dtype),
        "b1": jnp.zeros((ff,), dtype),
        "w2": dense_init(ks[1], (ff, d), dtype, scale=1.0 / math.sqrt(2 * cfg.num_layers)),
        "b2": jnp.zeros((d,), dtype),
    }


def ffn(cfg, p, x):
    if cfg.mlp_act == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    return (jax.nn.gelu(x @ p["w1"] + p["b1"])) @ p["w2"] + p["b2"]


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None):
    """logits (..., V) fp-any; labels int (...,). Mean over valid tokens.

    GSPMD-friendly on a vocab-sharded V: the gold logit is extracted via a
    fused one-hot CONTRACTION (each vocab shard contributes its slice +
    tiny (B,S) psum), NOT take_along_axis — a gather over a sharded dim
    makes GSPMD all-gather the full fp32 logits (§Perf iteration D took a
    3x regression from exactly that before this rewrite)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    V = lf.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(jnp.where(idx == labels[..., None], lf, 0.0), axis=-1)
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
