"""RWKV6 "Finch" — attention-free RNN with data-dependent decay.

Faithful-to-structure implementation of the RWKV6 block [arXiv:2404.05892]:
  * time-mix with ddlerp (data-dependent token-shift interpolation via a
    low-rank adapter over 5 targets w/k/v/r/g),
  * data-dependent per-channel decay  w_t = exp(-exp(w0 + lora(x_w))),
  * multi-head WKV linear-attention recurrence with bonus ``u``:
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
        o_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
  * channel-mix with squared-ReLU.

Training runs the recurrence as a ``lax.scan`` over time inside a
``lax.scan`` over layers; decode carries (S, token-shift, channel-shift)
state — O(1) per token, which is why rwkv6 runs the ``long_500k`` shape
natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def _heads(cfg):
    return cfg.d_model // cfg.rwkv_head_dim


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _layer_init(cfg, key, dtype):
    d, ff, lora = cfg.d_model, cfg.d_ff, cfg.rwkv_lora_dim
    H, hd = _heads(cfg), cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    tmix = {
        "mu_base": jnp.full((d,), 0.5, dtype),
        "mus": jnp.full((5, d), 0.5, dtype),
        "W1": L.dense_init(ks[0], (d, 5 * lora), dtype),
        "W2": L.dense_init(ks[1], (5, lora, d), dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),     # slow decay at init
        "dw1": L.dense_init(ks[2], (d, 2 * lora), dtype),
        "dw2": L.dense_init(ks[3], (2 * lora, d), dtype),
        "u": jnp.zeros((H, hd), jnp.float32),
        "Wr": L.dense_init(ks[4], (d, d), dtype),
        "Wk": L.dense_init(ks[5], (d, d), dtype),
        "Wv": L.dense_init(ks[6], (d, d), dtype),
        "Wg": L.dense_init(ks[7], (d, d), dtype),
        "Wo": L.dense_init(ks[8], (d, d), dtype),
        "gn_w": jnp.ones((d,), dtype),
        "gn_b": jnp.zeros((d,), dtype),
    }
    cmix = {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "Wk": L.dense_init(ks[9], (d, ff), dtype),
        "Wv": L.dense_init(ks[10], (ff, d), dtype),
        "Wr": L.dense_init(ks[11], (d, d), dtype),
    }
    return {
        "ln1": {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
        "ln2": {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
        "tmix": tmix,
        "cmix": cmix,
    }


def init_params(rng, cfg):
    dtype = cfg.compute_dtype
    d = cfg.d_model
    k_emb, k_layers, k_head = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    return {
        "embed": L.embed_init(k_emb, (cfg.padded_vocab, d), dtype),
        "ln0": {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
        "layers": jax.vmap(lambda k: _layer_init(cfg, k, dtype))(layer_keys),
        "final_norm": {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)},
        "lm_head": L.dense_init(k_head, (d, cfg.padded_vocab), dtype),
    }


# --------------------------------------------------------------------------
# block pieces
# --------------------------------------------------------------------------

def _ddlerp(tp, x, xx):
    """Data-dependent lerp -> (x_w, x_k, x_v, x_r, x_g), each (B,S,d)."""
    delta = xx - x
    base = x + delta * tp["mu_base"]
    lo = jnp.tanh(base @ tp["W1"])                      # (B,S,5*lora)
    B, S, _ = lo.shape
    lo = lo.reshape(B, S, 5, -1)
    off = jnp.einsum("bstl,tld->bstd", lo, tp["W2"])    # (B,S,5,d)
    mix = tp["mus"][None, None] + off
    outs = x[:, :, None, :] + delta[:, :, None, :] * mix
    return tuple(outs[:, :, i, :] for i in range(5))


def _decay(tp, x_w):
    """Data-dependent decay w_t in (0,1), fp32, shape of x_w."""
    ddd = jnp.tanh(x_w @ tp["dw1"]) @ tp["dw2"]
    return jnp.exp(-jnp.exp(tp["w0"] + ddd.astype(jnp.float32)))


def _wkv_scan(r, k, v, w, u, S0, unroll: int = 16):
    """r,k,v,w: (B,T,H,hd); u: (H,hd); S0: (B,H,hd,hd) fp32 -> (o, S_T).

    §Perf iteration A (EXPERIMENTS.md): ``unroll`` fuses consecutive steps
    into one loop body so the (B,H,hd,hd) state is materialized to HBM
    once per ``unroll`` steps instead of every step — the sequential-scan
    HBM-traffic term drops ~unroll×."""
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                                   # (B,H,hd)
        a = k_t[..., :, None] * v_t[..., None, :]                  # (B,H,hd,hd)
        o = jnp.sum((S + u[None, :, :, None] * a) * r_t[..., :, None], axis=-2)
        S = w_t[..., :, None] * S + a
        return S, o
    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    T = xs[0].shape[0]
    S_T, o = jax.lax.scan(step, S0, xs,
                          unroll=unroll if T % unroll == 0 else 1)
    return jnp.moveaxis(o, 0, 1), S_T                              # (B,T,H,hd)


def _group_norm(x, w, b, H, eps=1e-5):
    """Per-head layernorm over hd. x: (..., d) viewed as (..., H, hd)."""
    shp = x.shape
    xf = x.astype(jnp.float32).reshape(shp[:-1] + (H, shp[-1] // H))
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf.reshape(shp) * w + b).astype(x.dtype)


def _time_mix(cfg, tp, x, xx, S0):
    """x: (B,T,d); xx: token-shifted x; S0: (B,H,hd,hd)."""
    B, T, d = x.shape
    H, hd = _heads(cfg), cfg.rwkv_head_dim
    x_w, x_k, x_v, x_r, x_g = _ddlerp(tp, x, xx)
    r = (x_r @ tp["Wr"]).reshape(B, T, H, hd)
    k = (x_k @ tp["Wk"]).reshape(B, T, H, hd)
    v = (x_v @ tp["Wv"]).reshape(B, T, H, hd)
    g = jax.nn.silu(x_g @ tp["Wg"])
    w = _decay(tp, x_w).reshape(B, T, H, hd)
    o, S_T = _wkv_scan(r, k, v, w, tp["u"], S0)
    o = o.reshape(B, T, d).astype(x.dtype)
    o = _group_norm(o, tp["gn_w"], tp["gn_b"], H)
    return (o * g) @ tp["Wo"], S_T


def _channel_mix(tp, x, xx):
    x_k = x + (xx - x) * tp["mu_k"]
    x_r = x + (xx - x) * tp["mu_r"]
    k = jnp.square(jax.nn.relu(x_k @ tp["Wk"]))
    return jax.nn.sigmoid(x_r @ tp["Wr"]) * (k @ tp["Wv"])


def _shift(x):
    """Token shift: previous token, zeros at t=0. x: (B,T,d)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


# --------------------------------------------------------------------------
# forward / loss / decode
# --------------------------------------------------------------------------

def forward(params, batch, cfg, *, return_cache: bool = False):
    x = params["embed"][batch["tokens"]]
    x = L.layernorm(x, params["ln0"]["w"], params["ln0"]["b"])
    B, T, d = x.shape
    H, hd = _heads(cfg), cfg.rwkv_head_dim
    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def body(h, lp):
        z1 = L.layernorm(h, lp["ln1"]["w"], lp["ln1"]["b"])
        t_out, S_T = _time_mix(cfg, lp["tmix"], z1, _shift(z1), S0)
        h = h + t_out
        z2 = L.layernorm(h, lp["ln2"]["w"], lp["ln2"]["b"])
        h = h + _channel_mix(lp["cmix"], z2, _shift(z2))
        # decode resumes from the LAST TOKEN's normed inputs per sub-block
        ys = (S_T, z1[:, -1], z2[:, -1]) if return_cache else None
        return h, ys

    if cfg.remat and not return_cache:
        body = jax.checkpoint(body, prevent_cse=False)
    x, caches = jax.lax.scan(body, x, params["layers"])
    x = L.layernorm(x, params["final_norm"]["w"], params["final_norm"]["b"])
    logits = x @ params["lm_head"]
    cache = None
    if return_cache:
        cache = {"S": caches[0], "tshift": caches[1], "cshift": caches[2],
                 "step": jnp.asarray(T, jnp.int32)}
    return logits, cache, jnp.float32(0.0)


def loss_fn(params, batch, cfg):
    logits, _, _ = forward(params, batch, cfg)
    return L.softmax_xent(logits[:, :-1], batch["labels"][:, 1:])


def prefill(params, batch, cfg):
    logits, cache, _ = forward(params, batch, cfg, return_cache=True)
    return logits, cache


def init_cache(cfg, batch_size: int, seq_len: int, dtype=None):
    H, hd, d, Lyr = _heads(cfg), cfg.rwkv_head_dim, cfg.d_model, cfg.num_layers
    return {
        "S": jnp.zeros((Lyr, batch_size, H, hd, hd), jnp.float32),
        "tshift": jnp.zeros((Lyr, batch_size, d), cfg.compute_dtype),
        "cshift": jnp.zeros((Lyr, batch_size, d), cfg.compute_dtype),
        "step": jnp.asarray(0, jnp.int32),
    }


def decode_step(params, cache, batch, cfg):
    x = params["embed"][batch["tokens"]]                 # (B,1,d)
    x = L.layernorm(x, params["ln0"]["w"], params["ln0"]["b"])

    def body(h, lp_state):
        lp, S, tsh, csh = lp_state
        z = L.layernorm(h, lp["ln1"]["w"], lp["ln1"]["b"])
        xx = tsh[:, None, :].astype(z.dtype)             # previous token
        t_out, S_n = _time_mix(cfg, lp["tmix"], z, xx, S)
        new_tsh = z[:, 0]
        h = h + t_out
        z = L.layernorm(h, lp["ln2"]["w"], lp["ln2"]["b"])
        h = h + _channel_mix(lp["cmix"], z, csh[:, None, :].astype(z.dtype))
        return h, (S_n, new_tsh, z[:, 0])

    x, (S_n, tsh_n, csh_n) = jax.lax.scan(
        body, x, (params["layers"], cache["S"], cache["tshift"], cache["cshift"]))
    x = L.layernorm(x, params["final_norm"]["w"], params["final_norm"]["b"])
    logits = x @ params["lm_head"]
    return logits, {"S": S_n, "tshift": tsh_n, "cshift": csh_n,
                    "step": cache["step"] + 1}
