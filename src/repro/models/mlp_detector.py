"""The paper's own anomaly-detection model: 3-layer MLP (256, 128, 64).

ReLU activations, dropout p=0.3 (Algorithm 1 line 20), softmax
classification over attack classes (UNSW-NB15: 10 classes; ROAD binary).
This is the model used by every faithful-reproduction experiment
(Tables I–VII). Kept deliberately identical in spirit to the paper's
PyTorch module; dropout is applied only when an rng key is provided.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_params(rng, cfg):
    dims = (cfg.num_features,) + tuple(cfg.mlp_hidden) + (cfg.num_classes,)
    keys = jax.random.split(rng, len(dims) - 1)
    params = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = L.dense_init(keys[i], (a, b), jnp.float32)
        params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
    return params


def forward(params, x, cfg, rng=None):
    n = len(cfg.mlp_hidden) + 1
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
            if rng is not None and cfg.dropout > 0:
                rng, sub = jax.random.split(rng)
                keep = jax.random.bernoulli(sub, 1.0 - cfg.dropout, x.shape)
                x = jnp.where(keep, x / (1.0 - cfg.dropout), 0.0)
    return x


def loss_fn(params, batch, cfg, rng=None):
    logits = forward(params, batch["x"], cfg, rng)
    return L.softmax_xent(logits, batch["y"])


def predict(params, x, cfg):
    return jax.nn.softmax(forward(params, x, cfg), axis=-1)


def accuracy(params, batch, cfg):
    logits = forward(params, batch["x"], cfg)
    return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))


def auc_roc(scores, labels):
    """Binary AUC via the Mann-Whitney identity (rank statistic).

    scores: (N,) anomaly score; labels: (N,) in {0,1}. Pure-jnp so it can
    run inside jit; ties get average rank.
    """
    order = jnp.argsort(scores)
    ranks = jnp.empty_like(scores).at[order].set(
        jnp.arange(1, scores.shape[0] + 1, dtype=scores.dtype))
    pos = labels.astype(scores.dtype)
    n_pos = pos.sum()
    n_neg = pos.shape[0] - n_pos
    u = ranks @ pos - n_pos * (n_pos + 1) / 2.0
    return u / jnp.maximum(n_pos * n_neg, 1.0)
