"""Whisper-tiny encoder-decoder BACKBONE (audio family).

Per the assignment carve-out, the mel-spectrogram + conv feature extractor
is a STUB: ``input_specs()`` supplies precomputed frame embeddings of shape
(B, encoder_seq, d_model). This module implements the transformer backbone
that consumes them: a bidirectional encoder (sinusoidal positions, GELU
MLP, LayerNorm) and a causal decoder with cross-attention (tied embeddings,
as in Whisper [arXiv:2212.04356]).

Decode carries a self-attention KV cache plus the PRE-PROJECTED encoder
cross-attention KV (computed once at prefill, reused every step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def _enc_layer_init(cfg, key, dtype):
    ks = jax.random.split(key, 4)
    return {
        "ln1": L.norm_params(cfg, ks[0], cfg.d_model, dtype),
        "attn": L.attn_params(cfg, ks[1], dtype),
        "ln2": L.norm_params(cfg, ks[2], cfg.d_model, dtype),
        "ffn": L.ffn_params(cfg, ks[3], dtype),
    }


def _dec_layer_init(cfg, key, dtype):
    ks = jax.random.split(key, 6)
    return {
        "ln1": L.norm_params(cfg, ks[0], cfg.d_model, dtype),
        "self_attn": L.attn_params(cfg, ks[1], dtype),
        "lnx": L.norm_params(cfg, ks[2], cfg.d_model, dtype),
        "cross_attn": L.attn_params(cfg, ks[3], dtype),
        "ln2": L.norm_params(cfg, ks[4], cfg.d_model, dtype),
        "ffn": L.ffn_params(cfg, ks[5], dtype),
    }


def init_params(rng, cfg):
    dtype = cfg.compute_dtype
    k_emb, k_enc, k_dec, k_n = jax.random.split(rng, 4)
    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    return {
        "embed": L.embed_init(k_emb, (cfg.padded_vocab, cfg.d_model), dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(cfg, k, dtype))(enc_keys),
        "enc_norm": L.norm_params(cfg, k_n, cfg.d_model, dtype),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(cfg, k, dtype))(dec_keys),
        "final_norm": L.norm_params(cfg, k_n, cfg.d_model, dtype),
    }


def encode(params, enc_embeds, cfg):
    """enc_embeds: (B, Se, d) stubbed conv-frontend output."""
    Se = enc_embeds.shape[1]
    x = enc_embeds.astype(cfg.compute_dtype) \
        + L.sinusoidal_positions(Se, cfg.d_model).astype(cfg.compute_dtype)

    def body(h, lp):
        z = L.apply_norm(cfg, h, lp["ln1"])
        a, _ = L.full_attention(cfg, lp["attn"], z, causal=False, use_rope=False)
        h = h + a
        z = L.apply_norm(cfg, h, lp["ln2"])
        return h + L.ffn(cfg, lp["ffn"], z), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.apply_norm(cfg, x, params["enc_norm"])


def _cross_kv(lp, enc_out, cfg):
    """Pre-project encoder output to cross-attention K/V: (B,Se,K,hd)."""
    B, Se, _ = enc_out.shape
    K, hd = cfg.num_kv_heads, cfg.hd
    k = enc_out @ lp["cross_attn"]["wk"]
    v = enc_out @ lp["cross_attn"]["wv"]
    if "bk" in lp["cross_attn"]:
        k, v = k + lp["cross_attn"]["bk"], v + lp["cross_attn"]["bv"]
    return k.reshape(B, Se, K, hd), v.reshape(B, Se, K, hd)


def forward(params, batch, cfg, *, return_cache: bool = False):
    enc_out = encode(params, batch["enc_embeds"], cfg)
    x = params["embed"][batch["tokens"]]
    T = x.shape[1]
    x = x + L.sinusoidal_positions(T, cfg.d_model).astype(x.dtype)

    def body(h, lp):
        z = L.apply_norm(cfg, h, lp["ln1"])
        a, (k, v) = L.full_attention(cfg, lp["self_attn"], z,
                                     causal=True, use_rope=False)
        h = h + a
        z = L.apply_norm(cfg, h, lp["lnx"])
        c, _ = L.full_attention(cfg, lp["cross_attn"], z, xkv=enc_out,
                                causal=False, use_rope=False)
        h = h + c
        z = L.apply_norm(cfg, h, lp["ln2"])
        h = h + L.ffn(cfg, lp["ffn"], z)
        ys = None
        if return_cache:
            xk, xv = _cross_kv(lp, enc_out, cfg)
            ys = (k, v, xk, xv)
        return h, ys

    if cfg.remat and not return_cache:
        body = jax.checkpoint(body, prevent_cse=False)
    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = x @ params["embed"].T
    cache = None
    if return_cache:
        cache = {"k": caches[0], "v": caches[1], "xk": caches[2],
                 "xv": caches[3], "step": jnp.asarray(T, jnp.int32)}
    return logits, cache, jnp.float32(0.0)


def loss_fn(params, batch, cfg):
    logits, _, _ = forward(params, batch, cfg)
    return L.softmax_xent(logits[:, :-1], batch["labels"][:, 1:])


def prefill(params, batch, cfg):
    logits, cache, _ = forward(params, batch, cfg, return_cache=True)
    return logits, cache


def init_cache(cfg, batch_size: int, seq_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    Lyr, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((Lyr, batch_size, seq_len, K, hd), dtype),
        "v": jnp.zeros((Lyr, batch_size, seq_len, K, hd), dtype),
        "xk": jnp.zeros((Lyr, batch_size, cfg.encoder_seq, K, hd), dtype),
        "xv": jnp.zeros((Lyr, batch_size, cfg.encoder_seq, K, hd), dtype),
        "step": jnp.asarray(0, jnp.int32),
    }


def decode_step(params, cache, batch, cfg):
    x = params["embed"][batch["tokens"]]
    step = cache["step"]
    x = x + L.sinusoidal_position_at(step, cfg.d_model).astype(x.dtype)

    def body(h, lp_state):
        lp, ck, cv, xk, xv = lp_state
        z = L.apply_norm(cfg, h, lp["ln1"])
        a, nk, nv = L.decode_attention(cfg, lp["self_attn"], z, ck, cv, step,
                                       use_rope=False)
        h = h + a
        z = L.apply_norm(cfg, h, lp["lnx"])
        c, _, _ = L.decode_attention(cfg, lp["cross_attn"], z, xk, xv, step,
                                     cross=True)
        h = h + c
        z = L.apply_norm(cfg, h, lp["ln2"])
        return h + L.ffn(cfg, lp["ffn"], z), (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = x @ params["embed"].T
    return logits, {"k": nk, "v": nv, "xk": cache["xk"], "xv": cache["xv"],
                    "step": step + 1}
