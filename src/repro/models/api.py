"""Model API: family dispatch + input specs.

Every family exposes:
  init_params(rng, cfg)                       -> param pytree
  loss_fn(params, batch, cfg)                 -> scalar loss
  prefill(params, batch, cfg)                 -> (logits, cache)
  decode_step(params, cache, batch, cfg)      -> (logits, cache)
  init_cache(cfg, batch, seq)                 -> cache pytree

``input_specs`` builds `jax.ShapeDtypeStruct` stand-ins for every model
input of a given (config × shape × step-kind) — weak-type-correct,
shardable, no device allocation — used by the multi-pod dry-run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape
from repro.models import hybrid, mlp_detector, rwkv6, transformer, whisper

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": rwkv6,
    "hybrid": hybrid,
    "audio": whisper,
    "mlp": mlp_detector,
}


def module_for(cfg: ArchConfig):
    return _FAMILY[cfg.family]


def init_params(rng, cfg: ArchConfig):
    return module_for(cfg).init_params(rng, cfg)


def loss_fn(params, batch, cfg: ArchConfig):
    return module_for(cfg).loss_fn(params, batch, cfg)


def prefill(params, batch, cfg: ArchConfig):
    return module_for(cfg).prefill(params, batch, cfg)


def decode_step(params, cache, batch, cfg: ArchConfig):
    return module_for(cfg).decode_step(params, cache, batch, cfg)


def init_cache(cfg: ArchConfig, batch_size: int, seq_len: int):
    return module_for(cfg).init_cache(cfg, batch_size, seq_len)


def build_default_eval(cfg: ArchConfig):
    """Jitted default quality metric ev(params, batch) -> scalar, shared
    by both FL engines so their accuracy fields stay comparable:
    classification accuracy for the mlp detector family, a -loss quality
    proxy for everything else (LMs etc.)."""

    @jax.jit
    def ev(params, batch):
        if cfg.family == "mlp":
            from repro.models import mlp_detector
            return mlp_detector.accuracy(params, batch, cfg)
        return -loss_fn(params, batch, cfg)

    return ev


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _token_batch(cfg, lead, seq):
    """Token/label specs with modality extras. lead: leading dims tuple."""
    toks = seq
    batch = {}
    if cfg.family == "vlm":
        toks = max(seq - cfg.num_patches, 1)
        batch["patch_embeds"] = _sds(lead + (cfg.num_patches, cfg.d_model),
                                     jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["enc_embeds"] = _sds(lead + (cfg.encoder_seq, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
    batch["tokens"] = _sds(lead + (toks,), jnp.int32)
    batch["labels"] = _sds(lead + (toks,), jnp.int32)
    return batch


def train_input_specs(cfg: ArchConfig, shape: InputShape, num_clients: int):
    """Per-client-batched training inputs: leading dim = num_clients."""
    per_client = max(shape.global_batch // num_clients, 1)
    if cfg.family == "mlp":
        return {"x": _sds((num_clients, per_client, cfg.num_features), jnp.float32),
                "y": _sds((num_clients, per_client), jnp.int32)}
    return _token_batch(cfg, (num_clients, per_client), shape.seq_len)


def prefill_input_specs(cfg: ArchConfig, shape: InputShape):
    if cfg.family == "mlp":
        return {"x": _sds((shape.global_batch, cfg.num_features), jnp.float32)}
    batch = _token_batch(cfg, (shape.global_batch,), shape.seq_len)
    batch.pop("labels")
    return batch


def decode_input_specs(cfg: ArchConfig, shape: InputShape):
    """(batch, cache) specs for a single-token serve_step."""
    batch = {"tokens": _sds((shape.global_batch, 1), jnp.int32)}
    cache = jax.eval_shape(
        functools.partial(init_cache, cfg, shape.global_batch, shape.seq_len))
    return batch, cache


def input_specs(cfg: ArchConfig, shape: InputShape, num_clients: int = 1):
    """Dispatch on shape.kind. Returns the kwargs pytree for the step fn."""
    if shape.kind == "train":
        return {"batch": train_input_specs(cfg, shape, num_clients)}
    if shape.kind == "prefill":
        return {"batch": prefill_input_specs(cfg, shape)}
    batch, cache = decode_input_specs(cfg, shape)
    return {"batch": batch, "cache": cache}
