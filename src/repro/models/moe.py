"""Top-k mixture-of-experts FFN with capacity-based dispatch.

Dispatch uses scatter/gather into a fixed (E, C, d) buffer (Switch/Mixtral
style) so compiled FLOPs are proportional to *active* experts — the einsum
one-hot dispatch tensor (T, E, C) is never materialized. Expert tensors are
laid out (E, d, ff) so the expert dim can be sharded for expert parallelism
(arctic-480b: E over the "data" axis, ff over "model").

Aux loss is the standard Switch load-balance term
``E * sum_e f_e * p_e`` (f_e = fraction of tokens routed to e, p_e = mean
router prob of e).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L


def moe_params(cfg, key, dtype):
    E, d, ff = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d, E), jnp.float32),
        "wg": L.dense_init(ks[1], (E, d, ff), dtype),
        "wu": L.dense_init(ks[2], (E, d, ff), dtype),
        "wd": L.dense_init(ks[3], (E, ff, d), dtype),
    }
    if cfg.moe_dense_residual:
        p["dense"] = L.ffn_params(cfg, ks[4], dtype)
    return p


def capacity(cfg, tokens: int) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.top_k / cfg.num_experts)
    return max(4, min(c, tokens))


# --------------------------------------------------------------------------
# token<->slot permutations with custom VJPs: the BACKWARD of each gather
# is ALSO a gather through the inverse permutation. Plain AD of a gather
# emits a scatter into an unsharded zeros buffer, which GSPMD replicates
# and all-reduces (43 GB per layer on granite-moe before this).
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dispatch(k, xt, tok_for_slot, valid, slot_c, keep):
    """xt: (T, d) -> slot-major (E*C, d); bwd gathers via slot_c."""
    return xt[tok_for_slot] * valid[:, None].astype(xt.dtype)


def _dispatch_fwd(k, xt, tok_for_slot, valid, slot_c, keep):
    out = _dispatch(k, xt, tok_for_slot, valid, slot_c, keep)
    return out, (slot_c, keep)


def _dispatch_bwd(k, res, dxe):
    slot_c, keep = res
    d = dxe.shape[-1]
    dxt = dxe[slot_c] * keep[:, None].astype(dxe.dtype)        # (Tk, d)
    return (dxt.reshape(-1, k, d).sum(axis=1), None, None, None, None)


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _combine(k, ye, w, slot_c, choice_for_slot, valid):
    """ye: (E*C, d), w: (Tk,) -> (T, d); bwd gathers via choice_for_slot."""
    yt = ye[slot_c] * w[:, None].astype(ye.dtype)
    return yt.reshape(-1, k, ye.shape[-1]).sum(axis=1)


def _combine_fwd(k, ye, w, slot_c, choice_for_slot, valid):
    return _combine(k, ye, w, slot_c, choice_for_slot, valid), \
        (ye, w, slot_c, choice_for_slot, valid)


def _combine_bwd(k, res, dout):
    ye, w, slot_c, choice_for_slot, valid = res
    dyt = jnp.repeat(dout, k, axis=0)                           # (Tk, d)
    vmask = valid[:, None].astype(dyt.dtype)
    dye = (dyt[choice_for_slot] * vmask
           * w[choice_for_slot][:, None].astype(dyt.dtype))
    dw = jnp.sum(dyt.astype(jnp.float32)
                 * ye[slot_c].astype(jnp.float32), axis=-1)
    return dye.astype(ye.dtype), dw.astype(w.dtype), None, None, None


_combine.defvjp(_combine_fwd, _combine_bwd)


def moe_ffn(cfg, p, x):
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar fp32).

    §Perf iteration D (EXPERIMENTS.md): dispatch is GATHER-based. The
    original scatter of (E·C, d) token buffers had no sharding provenance
    (jnp.zeros) so GSPMD replicated it and ALL-REDUCED 43 GB per layer.
    Here only an int32/bool inverse-permutation of size E·C+1 is ever
    scattered; token payloads move through gathers (sharding follows the
    source), and the combine is a reshape-sum (tok_idx = repeat(arange)),
    no scatter at all.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    T = B * S
    C = capacity(cfg, T)
    xt = x.reshape(T, d)

    gates = jax.nn.softmax(xt.astype(jnp.float32) @ p["router"], axis=-1)  # (T,E)
    topv, topi = jax.lax.top_k(gates, k)                                   # (T,k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) inside its expert's capacity buffer
    flat_e = topi.reshape(T * k)                                # (Tk,)
    mask = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # (Tk, E)
    pos = jnp.cumsum(mask, axis=0) - mask                       # (Tk, E)
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < C
    # overflow routes to a dump slot (index E*C) so it never collides
    slot = jnp.where(keep, flat_e * C + flat_pos, E * C)        # (Tk,)

    # inverse permutation: which token (choice) fills each capacity slot
    tok_idx = jnp.repeat(jnp.arange(T), k)
    tok_for_slot = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(
        tok_idx, mode="drop")
    choice_for_slot = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(
        jnp.arange(T * k), mode="drop")
    valid = jnp.zeros((E * C + 1,), jnp.bool_).at[slot].set(
        keep, mode="drop")
    slot_c = jnp.minimum(slot, E * C - 1)

    w = (topv.reshape(T * k) * keep).astype(x.dtype)            # (Tk,)
    if cfg.moe_dispatch == "gather":
        xe = _dispatch(k, xt, tok_for_slot[:E * C], valid[:E * C],
                       slot_c, keep).reshape(E, C, d)
    else:  # scatter path (measured alternative; see EXPERIMENTS §Perf D)
        tok_all = jnp.repeat(jnp.arange(T), k)
        xd = xt[tok_all] * keep[:, None].astype(x.dtype)
        xe = jnp.zeros((E * C, d), x.dtype).at[
            jnp.minimum(slot, E * C - 1)].add(
            xd * keep[:, None].astype(x.dtype)).reshape(E, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["wu"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wd"]).reshape(E * C, d)

    if cfg.moe_dispatch == "gather":
        out = _combine(k, ye, w, slot_c, choice_for_slot[:E * C],
                       valid[:E * C])
    else:
        yt = ye[jnp.minimum(slot, E * C - 1)] * w[:, None]
        out = yt.reshape(T, k, d).sum(axis=1)

    if cfg.moe_dense_residual:
        out = out + L.ffn(cfg, p["dense"], xt)

    # load-balance aux
    f_e = jnp.mean(jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(1), axis=0)  # (E,)
    p_e = jnp.mean(gates, axis=0)
    aux = E * jnp.sum(f_e / k * p_e)
    return out.reshape(B, S, d), aux
