"""jit'd public wrappers over the Pallas kernels: pytree <-> (R, LANE)
layout management, padding, and ratio/aggregation conveniences.

Backend routing goes through ``kernels.backend.resolve()`` (overridable
via ``REPRO_KERNEL_BACKEND``): compiled Mosaic-Pallas on TPU, compiled
Triton-Pallas (``kernels/gpu.py``) on GPU, interpret-mode kernel bodies
elsewhere — and the resolved choice is logged once, never silent. An
explicit ``interpret=`` argument bypasses the selector (used by the
oracle bit-match tests to pin a specific lowering).

Padding uses value 0 for updates and a -2 sentinel for reference signs so
padded positions can never count as aligned (sign() ∈ {-1,0,1}).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import backend as _backend
from repro.kernels import gpu as _gpu
from repro.kernels import masked_agg as _agg
from repro.kernels import quantize as _q
from repro.kernels import sign_align as _sa

LANE = _sa.LANE

# op name -> (TPU/interpret module fn, GPU Triton-Pallas fn)
_KERNELS = {
    "sign_align_counts": (_sa.sign_align_counts, _gpu.sign_align_counts),
    "per_client_sign_align": (_sa.per_client_sign_align,
                              _gpu.per_client_sign_align),
    "masked_agg": (_agg.masked_agg, _gpu.masked_agg),
    "fused_update": (_agg.fused_update, _gpu.fused_update),
    "quantize_q8": (_q.quantize_q8, _gpu.quantize_q8),
    "dequantize_q8": (_q.dequantize_q8, _gpu.dequantize_q8),
}


def default_interpret() -> bool:
    """True when the resolved backend runs kernel bodies in interpret
    mode (i.e. no compiled Pallas lowering is active)."""
    return _backend.resolve() not in ("tpu-pallas", "gpu-pallas")


def _kernel(name, *args, interpret=None):
    """Dispatch one kernel call through the backend selector.

    ``interpret`` non-None pins the legacy Mosaic-kernel path with that
    lowering mode; ``None`` routes by ``backend.resolve()``.
    """
    tpu_fn, gpu_fn = _KERNELS[name]
    if interpret is not None:
        return tpu_fn(*args, interpret=interpret)
    b = _backend.resolve()
    if b == "gpu-pallas":
        return gpu_fn(*args)
    return tpu_fn(*args, interpret=(b != "tpu-pallas"))


def flatten_to_lanes(tree, lane: int = LANE):
    """Concatenate a pytree into a (R, lane) f32 matrix (zero-padded).
    Returns (mat, total_size) — total_size = true element count."""
    leaves = [l.reshape(-1).astype(jnp.float32) for l in jax.tree.leaves(tree)]
    flat = jnp.concatenate(leaves) if leaves else jnp.zeros((0,), jnp.float32)
    n = flat.size
    rows = max((n + lane - 1) // lane, 1)
    pad = rows * lane - n
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, lane), n


def unflatten_from_lanes(mat, like):
    """Inverse of flatten_to_lanes into the structure/dtypes of ``like``."""
    flat = mat.reshape(-1)
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for ref in leaves:
        out.append(flat[off:off + ref.size].reshape(ref.shape).astype(ref.dtype))
        off += ref.size
    return jax.tree.unflatten(treedef, out)


def ref_sign_lanes(ref_sign_tree, lane: int = LANE):
    """Flatten an int8 sign pytree to (R, lane) with -2 padding sentinel."""
    leaves = [l.reshape(-1) for l in jax.tree.leaves(ref_sign_tree)]
    flat = jnp.concatenate(leaves).astype(jnp.int8)
    n = flat.size
    rows = max((n + lane - 1) // lane, 1)
    flat = jnp.pad(flat, (0, rows * lane - n), constant_values=-2)
    return flat.reshape(rows, lane)


# ---------------------------------------------------------------------------
# public ops
# ---------------------------------------------------------------------------

def sign_align_ratio(update_tree, ref_sign_tree, interpret=None) -> jnp.ndarray:
    """Kernel-backed Algorithm-1 relevance for one client's update."""
    g, n = flatten_to_lanes(update_tree)
    r = ref_sign_lanes(ref_sign_tree)
    count = _kernel("sign_align_counts", g, r, interpret=interpret)
    return count / jnp.maximum(jnp.float32(n), 1.0)


def per_client_sign_align_ratio(stacked_updates, ref_sign_tree,
                                interpret=None) -> jnp.ndarray:
    """stacked_updates: pytree with leading client dim C -> (C,) ratios."""
    C = jax.tree.leaves(stacked_updates)[0].shape[0]
    per_client = [jax.tree.map(lambda x, i=i: x[i], stacked_updates)
                  for i in range(C)]
    mats = [flatten_to_lanes(t)[0] for t in per_client]
    n = flatten_to_lanes(per_client[0])[1]
    u = jnp.stack(mats)                                  # (C, R, LANE)
    r = ref_sign_lanes(ref_sign_tree)
    counts = _kernel("per_client_sign_align", u, r, interpret=interpret)
    return counts / jnp.maximum(jnp.float32(n), 1.0)


def masked_aggregate(stacked_updates, mask, weights=None, interpret=None):
    """Kernel-backed masked mean over the client axis. Returns a pytree
    shaped like one client's update (f32 leaves cast back to input dtype)."""
    C = jax.tree.leaves(stacked_updates)[0].shape[0]
    w = mask if weights is None else mask * weights
    w = w / jnp.maximum(w.sum(), 1e-9)
    per_client = [jax.tree.map(lambda x, i=i: x[i], stacked_updates)
                  for i in range(C)]
    u = jnp.stack([flatten_to_lanes(t)[0] for t in per_client])
    out = _kernel("masked_agg", u, w, interpret=interpret)
    like = per_client[0]
    return unflatten_from_lanes(out, like)


def fused_selective_update(params, stacked_updates, mask, lr,
                           weights=None, interpret=None):
    """Beyond-paper fused kernel: params − lr · masked_mean(updates)."""
    C = jax.tree.leaves(stacked_updates)[0].shape[0]
    w = mask if weights is None else mask * weights
    w_lr = lr * w / jnp.maximum(w.sum(), 1e-9)
    p_mat, _ = flatten_to_lanes(params)
    per_client = [jax.tree.map(lambda x, i=i: x[i], stacked_updates)
                  for i in range(C)]
    u = jnp.stack([flatten_to_lanes(t)[0] for t in per_client])
    out = _kernel("fused_update", p_mat, u, w_lr, interpret=interpret)
    return unflatten_from_lanes(out, params)


def quantize_tree(tree, interpret=None):
    """Compress a pytree update to (int8 mat, scales, n). ~4x fewer bytes."""
    mat, n = flatten_to_lanes(tree)
    q, s = _kernel("quantize_q8", mat, interpret=interpret)
    return q, s, n


def dequantize_tree(q, s, like, interpret=None):
    mat = _kernel("dequantize_q8", q, s, interpret=interpret)
    return unflatten_from_lanes(mat, like)
