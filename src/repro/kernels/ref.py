"""Pure-jnp oracles for every Pallas kernel (the correctness reference).

Shapes follow the kernels' canonical layout: flat parameter vectors are
reshaped to (R, LANE) with LANE=1024 (8×128 VREG-aligned); client-stacked
updates are (C, R, LANE).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sign_align_counts(g, r):
    """g: (R, LANE) float; r: (R, LANE) int8 reference signs.
    Returns scalar count of positions where sign(g) == r."""
    s = jnp.sign(g.astype(jnp.float32)).astype(jnp.int8)
    return jnp.sum((s == r).astype(jnp.float32))


def per_client_sign_align(u, r):
    """u: (C, R, LANE); r: (R, LANE) int8 -> (C,) aligned counts."""
    s = jnp.sign(u.astype(jnp.float32)).astype(jnp.int8)
    eq = (s == r[None]).astype(jnp.float32)
    return eq.reshape(u.shape[0], -1).sum(axis=1)


def masked_agg(u, w):
    """u: (C, R, LANE); w: (C,) pre-normalized weights -> (R, LANE) f32."""
    return jnp.einsum("crl,c->rl", u.astype(jnp.float32), w.astype(jnp.float32))


def fused_update(p, u, w_lr):
    """Fused selective-aggregate + SGD apply (beyond-paper, DESIGN.md §7).
    p: (R, LANE) params; u: (C, R, LANE) updates; w_lr: (C,) = lr·mask·w.
    Returns p - Σ_c w_lr[c]·u[c]."""
    agg = jnp.einsum("crl,c->rl", u.astype(jnp.float32), w_lr.astype(jnp.float32))
    return (p.astype(jnp.float32) - agg).astype(p.dtype)


def cohort_gather(src, idx):
    """src: (N, R, LANE); idx: (K,) i32 -> (K, R, LANE) gathered rows —
    oracle of the one-hot matmul gather (exact: one nonzero per row)."""
    return jnp.take(src, idx, axis=0)


def quantize_q8(x):
    """Per-row symmetric int8 quantization. x: (R, LANE) float.
    Returns (q int8 (R, LANE), scale f32 (R, 1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_q8(q, scale):
    return q.astype(jnp.float32) * scale
