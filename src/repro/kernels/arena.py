"""Flat parameter arena: the canonical (R, LANE) device layout shared by
the cohort megastep and the Pallas aggregation kernels.

The paper's profiled anti-pattern (Tables V-VI) is thousands of tiny
per-tensor kernels; the fix is to pack the whole parameter pytree ONCE
into a lane-aligned f32 matrix and run every hot-path reduction on that
single buffer:

  * per-client sign-alignment counts    (kernels/sign_align.py)
  * masked/weighted cohort aggregation  (kernels/masked_agg.py)
  * int8 wire quantization              (kernels/quantize.py)

``ParamArena`` owns the static layout metadata (treedef, shapes, dtypes,
offsets, row count) so ``pack``/``unpack`` are pure jnp reshapes that
trace away inside a jitted step — no per-leaf dispatches at run time.

Backend dispatch (one selector for every op, ``kernels.backend``): on
TPU the Mosaic-Pallas kernels run compiled (``interpret=False``), on GPU
the Triton-Pallas kernels from ``kernels/gpu.py`` run compiled, and
everywhere else the pure-jnp oracles from ``kernels/ref.py`` are used —
XLA-compiled, bit-matching the kernel semantics, and fast on CPU where
interpret-mode Pallas would be a correctness-only crawl. The resolved
backend is logged once per process and can be forced with
``REPRO_KERNEL_BACKEND={pallas,oracle,auto}`` (unknown values and
pallas-on-unsupported-platform raise — no silent fallback). Padding uses
value 0 for updates and a -2 sentinel for reference signs so padded
positions can never count as aligned (sign() ∈ {-1, 0, 1}).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import backend as _backend
from repro.kernels import gather as _gather
from repro.kernels import gpu as _gpu
from repro.kernels import masked_agg as _agg
from repro.kernels import quantize as _qz
from repro.kernels import ref as _ref
from repro.kernels import sign_align as _sa

LANE = _sa.LANE


def use_pallas() -> bool:
    """True when a compiled Pallas lowering (TPU Mosaic or GPU Triton)
    is the active kernel backend; False on the jnp-oracle path."""
    return _backend.resolve() != "oracle"


class ParamArena:
    """Static layout of one parameter pytree in the (rows, LANE) arena.

    Construct once from a template pytree (real arrays or
    ``jax.ShapeDtypeStruct``s — only shapes/dtypes are read); ``pack`` /
    ``unpack`` are then cheap pure functions usable inside jit.
    """

    def __init__(self, template, lane: int = LANE):
        leaves, treedef = jax.tree.flatten(template)
        self.treedef = treedef
        self.shapes = tuple(tuple(l.shape) for l in leaves)
        self.dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
        self.sizes = tuple(int(np.prod(s)) if s else 1 for s in self.shapes)
        self.n = int(sum(self.sizes))
        self.lane = int(lane)
        self.rows = max(-(-self.n // self.lane), 1)
        self.pad = self.rows * self.lane - self.n

    # ------------------------------------------------------------------
    # pack / unpack (pure jnp — trace away inside jit)
    # ------------------------------------------------------------------
    def pack(self, tree) -> jnp.ndarray:
        """pytree -> (rows, lane) f32, zero-padded."""
        leaves = [l.reshape(-1).astype(jnp.float32)
                  for l in jax.tree.leaves(tree)]
        flat = (jnp.concatenate(leaves) if leaves
                else jnp.zeros((0,), jnp.float32))
        flat = jnp.pad(flat, (0, self.rows * self.lane - self.n))
        return flat.reshape(self.rows, self.lane)

    def pack_cohort(self, tree) -> jnp.ndarray:
        """pytree with leading client dim C -> (C, rows, lane) f32."""
        leaves = jax.tree.leaves(tree)
        C = leaves[0].shape[0]
        flat = jnp.concatenate(
            [l.reshape(C, -1).astype(jnp.float32) for l in leaves], axis=1)
        flat = jnp.pad(flat, ((0, 0), (0, self.rows * self.lane - self.n)))
        return flat.reshape(C, self.rows, self.lane)

    def unpack(self, mat, dtype=None):
        """(rows, lane) -> pytree; leaves cast to the template dtypes
        (or a single override ``dtype``, e.g. f32 for gradient math)."""
        flat = mat.reshape(-1)
        out, off = [], 0
        for shape, dt, size in zip(self.shapes, self.dtypes, self.sizes):
            leaf = flat[off:off + size].reshape(shape)
            out.append(leaf.astype(dtype or dt))
            off += size
        return jax.tree.unflatten(self.treedef, out)

    def unpack_cohort(self, mat, dtype=None):
        """(C, rows, lane) -> pytree with leading client dim C."""
        C = mat.shape[0]
        flat = mat.reshape(C, -1)
        out, off = [], 0
        for shape, dt, size in zip(self.shapes, self.dtypes, self.sizes):
            leaf = flat[:, off:off + size].reshape((C,) + shape)
            out.append(leaf.astype(dtype or dt))
            off += size
        return jax.tree.unflatten(self.treedef, out)

    # ------------------------------------------------------------------
    # reference-sign helpers
    # ------------------------------------------------------------------
    def valid_mask(self) -> np.ndarray:
        """(rows, lane) bool host constant; True on real (unpadded) slots."""
        idx = np.arange(self.rows * self.lane)
        return (idx < self.n).reshape(self.rows, self.lane)

    def sign_ref(self, new_mat, old_mat) -> jnp.ndarray:
        """int8 sign of the global movement, -2 sentinel on padding."""
        sign = jnp.sign(new_mat - old_mat).astype(jnp.int8)
        return jnp.where(jnp.asarray(self.valid_mask()), sign,
                         jnp.int8(-2))

    def pack_signs(self, sign_tree) -> jnp.ndarray:
        """int8 sign pytree -> (rows, lane) with -2 padding sentinel."""
        leaves = [l.reshape(-1) for l in jax.tree.leaves(sign_tree)]
        flat = jnp.concatenate(leaves).astype(jnp.int8)
        return jnp.pad(flat, (0, self.rows * self.lane - self.n),
                       constant_values=-2).reshape(self.rows, self.lane)


# ---------------------------------------------------------------------------
# backend-dispatched cohort ops (TPU Mosaic / GPU Triton / jnp oracle)
# ---------------------------------------------------------------------------

def cohort_sign_align(u, r) -> jnp.ndarray:
    """u: (C, rows, lane) f32 updates; r: (rows, lane) int8 reference.
    Returns (C,) aligned counts (divide by the arena's true n for ratios)."""
    b = _backend.resolve()
    if b == "tpu-pallas":
        return _sa.per_client_sign_align(u, r, interpret=False)
    if b == "gpu-pallas":
        return _gpu.per_client_sign_align(u, r)
    return _ref.per_client_sign_align(u, r)


def weighted_sum(u, w, compute_dtype=jnp.float32) -> jnp.ndarray:
    """Σ_c w[c]·u[c] over the client axis -> (rows, lane) f32.

    ``compute_dtype`` selects the cross-client reduction precision for
    the jnp oracle (bf16 halves all-reduce bytes on the production mesh);
    the Pallas kernels always reduce in f32.
    """
    b = _backend.resolve()
    if b == "tpu-pallas":
        return _agg.masked_agg(u, w, interpret=False)
    if b == "gpu-pallas":
        return _gpu.masked_agg(u, w)
    out = jnp.einsum("crl,c->rl", u.astype(compute_dtype),
                     w.astype(compute_dtype))
    return out.astype(jnp.float32)


def fused_apply(p, u, w_lr) -> jnp.ndarray:
    """p − Σ_c w_lr[c]·u[c] (aggregate+apply fused, p.dtype preserved)."""
    b = _backend.resolve()
    if b == "tpu-pallas":
        return _agg.fused_update(p, u, w_lr, interpret=False)
    if b == "gpu-pallas":
        return _gpu.fused_update(p, u, w_lr)
    return _ref.fused_update(p, u, w_lr)


def cohort_gather(src, idx) -> jnp.ndarray:
    """Gather per-client arena slabs by cohort index: src (N, rows, lane)
    f32, idx (K,) i32 -> (K, rows, lane). The device control plane's
    top-k selection feeds this (EF buffers, per-client state slabs); on
    TPU/GPU it runs as a one-hot matmul sweep (matrix-unit friendly, no
    serial DMA per row), on CPU as the bit-identical ``jnp.take``
    oracle."""
    b = _backend.resolve()
    if b == "oracle":
        return _ref.cohort_gather(src, idx)
    onehot = (idx[:, None] == jnp.arange(src.shape[0])[None, :]
              ).astype(jnp.float32)
    if b == "gpu-pallas":
        return _gpu.onehot_gather(src, onehot)
    return _gather.onehot_gather(src, onehot, interpret=False)


def quantize_rows(x):
    """x: (R, lane) f32 -> (q int8 (R, lane), scales f32 (R, 1))."""
    b = _backend.resolve()
    if b == "tpu-pallas":
        return _qz.quantize_q8(x, interpret=False)
    if b == "gpu-pallas":
        return _gpu.quantize_q8(x)
    return _ref.quantize_q8(x)


def dequantize_rows(q, s) -> jnp.ndarray:
    b = _backend.resolve()
    if b == "tpu-pallas":
        return _qz.dequantize_q8(q, s, interpret=False)
    if b == "gpu-pallas":
        return _gpu.dequantize_q8(q, s)
    return _ref.dequantize_q8(q, s)
