"""Explicit kernel-backend selection for the (R, LANE) arena ops.

Three backends:

  * ``tpu-pallas``  — the Mosaic-lowered Pallas kernels, compiled
    (``interpret=False``) on TPU.
  * ``gpu-pallas``  — the Triton-lowered Pallas kernels in
    ``kernels/gpu.py``, compiled on GPU.
  * ``oracle``      — the pure-jnp reference implementations in
    ``kernels/ref.py`` (XLA-compiled, bit-matching the kernel
    semantics; the CPU default).

Resolution order: the ``REPRO_KERNEL_BACKEND`` environment variable
(``pallas`` | ``oracle`` | ``auto``) wins; ``auto`` (and the unset
default) picks by ``jax.default_backend()``. Forcing ``pallas`` on a
platform with no Pallas lowering is an error, not a degrade, and an
unknown forced value raises immediately — the silent-fallback failure
mode this module exists to remove. The resolved backend is logged once
per process so every run names the kernels it actually executed.
"""
from __future__ import annotations

import logging
import os

import jax

ENV_VAR = "REPRO_KERNEL_BACKEND"
FORCED_VALUES = ("pallas", "oracle", "auto")
BACKENDS = ("tpu-pallas", "gpu-pallas", "oracle")

_PLATFORM_PALLAS = {"tpu": "tpu-pallas", "gpu": "gpu-pallas"}

_log = logging.getLogger("repro.kernels")
_announced: set = set()


def resolve() -> str:
    """Return the active kernel backend, one of ``BACKENDS``.

    Re-reads the environment on every call (cheap: two dict lookups)
    so tests can flip ``REPRO_KERNEL_BACKEND`` mid-process; the
    announcement log still fires only once per distinct resolution.
    """
    forced = os.environ.get(ENV_VAR, "auto").strip().lower() or "auto"
    if forced not in FORCED_VALUES:
        raise ValueError(
            f"{ENV_VAR}={forced!r} is not a valid kernel backend override; "
            f"expected one of {FORCED_VALUES}")
    platform = jax.default_backend()
    if forced == "oracle":
        backend = "oracle"
    elif forced == "pallas":
        backend = _PLATFORM_PALLAS.get(platform)
        if backend is None:
            raise RuntimeError(
                f"{ENV_VAR}=pallas was forced but platform {platform!r} has "
                "no Pallas lowering (TPU -> Mosaic, GPU -> Triton); refusing "
                "to degrade to the jnp oracles silently. Unset the override "
                f"or use {ENV_VAR}=oracle explicitly.")
    else:  # auto
        backend = _PLATFORM_PALLAS.get(platform, "oracle")
    _announce(backend, platform, forced)
    return backend


def _announce(backend: str, platform: str, forced: str) -> None:
    key = (backend, platform, forced)
    if key in _announced:
        return
    _announced.add(key)
    _log.info("active kernel backend: %s (platform=%s, %s=%s)",
              backend, platform, ENV_VAR, forced)
