"""Triton-lowered Pallas kernels for GPU — the same (R, LANE) arena ops
as the TPU modules, rewritten inside Triton's constraints:

  * every block dimension must be a power of two (``tl.arange``
    requirement), so the public wrappers pad the client/population axes
    (C, K, N) to the next power of two and slice the padding back off —
    zero-padded rows contribute exactly 0 to every reduction, and sign
    references pad with the -2 sentinel so padded slots can never count
    as aligned;
  * the grid is a parallel launch with no cross-program accumulation,
    so reductions stay inside one program (partials summed by the
    jit'd wrapper, as on TPU);
  * no 3-D einsum — the client-axis reductions are broadcast-multiply
    followed by ``jnp.sum(axis=0)``, which Triton lowers as a register
    reduction.

Block shapes keep the full LANE (1024, a power of two) but sweep one
arena row per program for the client-resident kernels so the resident
tile stays C·4 KiB — inside shared memory for any realistic cohort.

All kernels bit-match the jnp oracles in ``kernels/ref.py``; the oracle
tests in ``tests/test_kernels.py`` run them in interpret mode on any
backend and compiled when ``jax.default_backend() == "gpu"``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024
BLOCK_R = 8          # rows per program for the 2-D (row-tiled) kernels


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _pad_axis(x, axis: int, target: int, value=0):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# sign alignment
# ---------------------------------------------------------------------------

def _count_kernel(g_ref, r_ref, out_ref):
    s = jnp.sign(g_ref[...].astype(jnp.float32)).astype(jnp.int8)
    eq = (s == r_ref[...]).astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(eq)


@functools.partial(jax.jit, static_argnames=("interpret", "block_r"))
def sign_align_counts(g, r, *, interpret: bool = False,
                      block_r: int = BLOCK_R):
    """g: (R, LANE) float; r: (R, LANE) int8. Returns scalar f32 count.

    R is padded to a block multiple: g with zeros (sign 0), r with the
    -2 sentinel — padded positions never compare equal.
    """
    R = g.shape[0]
    Rp = pl.cdiv(R, block_r) * block_r
    g = _pad_axis(g, 0, Rp)
    r = _pad_axis(r, 0, Rp, value=-2)
    grid = (Rp // block_r,)
    partial = pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_r, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 1), jnp.float32),
        interpret=interpret,
    )(g, r)
    return partial.sum()


def _per_client_kernel(u_ref, r_ref, out_ref):
    s = jnp.sign(u_ref[...].astype(jnp.float32)).astype(jnp.int8)
    eq = (s == r_ref[...][None]).astype(jnp.float32)       # (C, 1, LANE)
    out_ref[:, 0] = jnp.sum(eq, axis=(1, 2))


@functools.partial(jax.jit, static_argnames=("interpret",))
def per_client_sign_align(u, r, *, interpret: bool = False):
    """u: (C, R, LANE); r: (R, LANE) int8 -> (C,) aligned counts (f32).

    One arena row per program; the client axis (padded to a power of
    two with zero rows — sign 0, counted never) stays resident.
    """
    C, R, _ = u.shape
    Cp = _pow2(C)
    u = _pad_axis(u, 0, Cp)
    grid = (R,)
    partial = pl.pallas_call(
        _per_client_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Cp, 1, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((1, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((Cp, 1), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((Cp, R), jnp.float32),
        interpret=interpret,
    )(u, r)
    return partial.sum(axis=1)[:C]


# ---------------------------------------------------------------------------
# masked aggregation / fused apply
# ---------------------------------------------------------------------------

def _agg_kernel(u_ref, w_ref, out_ref):
    u = u_ref[...].astype(jnp.float32)                 # (C, 1, LANE)
    w = w_ref[...].astype(jnp.float32)                 # (C, 1)
    out_ref[...] = jnp.sum(u[:, 0, :] * w, axis=0)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_agg(u, w, *, interpret: bool = False):
    """u: (C, R, LANE); w: (C,) normalized weights -> (R, LANE) f32."""
    C, R, _ = u.shape
    Cp = _pow2(C)
    u = _pad_axis(u, 0, Cp)
    w = _pad_axis(w.reshape(-1, 1), 0, Cp)
    grid = (R,)
    return pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Cp, 1, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((Cp, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, LANE), jnp.float32),
        interpret=interpret,
    )(u, w)


def _fused_kernel(p_ref, u_ref, w_ref, out_ref):
    u = u_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    agg = jnp.sum(u[:, 0, :] * w, axis=0)[None]
    out_ref[...] = (p_ref[...].astype(jnp.float32) - agg).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_update(p, u, w_lr, *, interpret: bool = False):
    """p: (R, LANE); u: (C, R, LANE); w_lr: (C,) = lr·mask·weight.
    Returns p − Σ_c w_lr[c]·u[c] in p.dtype (aggregate+apply fused)."""
    C, R, _ = u.shape
    Cp = _pow2(C)
    u = _pad_axis(u, 0, Cp)
    w_lr = _pad_axis(w_lr.reshape(-1, 1), 0, Cp)
    grid = (R,)
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, LANE), lambda i: (i, 0)),
            pl.BlockSpec((Cp, 1, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((Cp, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, LANE), p.dtype),
        interpret=interpret,
    )(p, u, w_lr)


# ---------------------------------------------------------------------------
# one-hot cohort gather
# ---------------------------------------------------------------------------

def _gather_kernel(oh_ref, src_ref, out_ref):
    oh = oh_ref[...].astype(jnp.float32)               # (1, N)
    src = src_ref[...].astype(jnp.float32)             # (N, 1, LANE)
    out_ref[...] = jnp.sum(src[:, 0, :] * oh[0, :, None], axis=0)[None, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def onehot_gather(src, onehot, *, interpret: bool = False):
    """src: (N, R, LANE) f32; onehot: (K, N) f32 -> (K, R, LANE) f32.

    Grid over (K, R); N padded to a power of two with zero slabs
    (coefficient 0 — exact). Exactness holds because each one-hot row
    has a single 1.0 coefficient, matching the ``jnp.take`` oracle.
    """
    N, R, _ = src.shape
    K = onehot.shape[0]
    Np = _pow2(N)
    src = _pad_axis(src, 0, Np)
    onehot = _pad_axis(onehot, 1, Np)
    grid = (K, R)
    return pl.pallas_call(
        _gather_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Np), lambda k, i: (k, 0)),
            pl.BlockSpec((Np, 1, LANE), lambda k, i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, LANE), lambda k, i: (k, i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, R, LANE), jnp.float32),
        interpret=interpret,
    )(onehot, src)


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------

def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_q8(x, *, interpret: bool = False):
    """x: (R, LANE) float -> (q int8 (R, LANE), scale f32 (R, 1)).

    One row per program — the per-row amax reduction never crosses a
    program boundary, so no grid accumulation is needed.
    """
    R = x.shape[0]
    grid = (R,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, LANE), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, LANE), jnp.int8),
            jax.ShapeDtypeStruct((R, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def _dequant_kernel(q_ref, s_ref, out_ref):
    out_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_q8(q, scale, *, interpret: bool = False):
    R = q.shape[0]
    grid = (R,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, LANE), jnp.float32),
        interpret=interpret,
    )(q, scale)
