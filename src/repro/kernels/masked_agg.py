"""Pallas TPU kernel: masked weighted aggregation over the client axis
(paper §IV-C: w_g = 1/|S| Σ_{i∈S} w_i — the server-side hot spot).

Layout: updates u (C, R, LANE); weights w (C,) already mask·weight
normalized by the jit'd wrapper (zero-safe). Grid sweeps R in (BR, LANE)
tiles; the full client dim is VMEM-resident per tile (C·BR·LANE·4 B =
16 clients → 512 KiB at BR=8 — comfortably inside the ~16 MiB v5e VMEM).
The reduction over C runs on the VPU as a dot over the leading axis.

``fused_update`` additionally subtracts the aggregate from the parameter
tile in the same pass (aggregate+apply fusion — removes one full HBM
round-trip of the aggregated update; beyond-paper §Perf optimization).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024
BLOCK_R = 8


def _agg_kernel(u_ref, w_ref, out_ref):
    u = u_ref[...].astype(jnp.float32)                 # (C, BR, LANE)
    w = w_ref[...].astype(jnp.float32)                 # (C, 1)
    out_ref[...] = jnp.einsum("crl,co->rl", u, w)


@functools.partial(jax.jit, static_argnames=("interpret", "block_r"))
def masked_agg(u, w, *, interpret: bool = True, block_r: int = BLOCK_R):
    """u: (C, R, LANE); w: (C,) normalized weights -> (R, LANE) f32."""
    C, R, _ = u.shape
    grid = (pl.cdiv(R, block_r),)
    return pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, block_r, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((C, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, LANE), jnp.float32),
        interpret=interpret,
    )(u, w.reshape(-1, 1))


def _fused_kernel(p_ref, u_ref, w_ref, out_ref):
    u = u_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    agg = jnp.einsum("crl,co->rl", u, w)
    out_ref[...] = (p_ref[...].astype(jnp.float32) - agg).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_r"))
def fused_update(p, u, w_lr, *, interpret: bool = True,
                 block_r: int = BLOCK_R):
    """p: (R, LANE); u: (C, R, LANE); w_lr: (C,) = lr·mask·weight.
    Returns p − Σ_c w_lr[c]·u[c] in p.dtype (aggregate+apply fused)."""
    C, R, _ = u.shape
    grid = (pl.cdiv(R, block_r),)
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, LANE), lambda i: (i, 0)),
            pl.BlockSpec((C, block_r, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((C, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, LANE), p.dtype),
        interpret=interpret,
    )(p, u, w_lr.reshape(-1, 1))
