"""Pallas TPU kernel: per-row symmetric int8 quantization of model updates
(beyond-paper: §VI names gradient compression as the complementary lever;
this gives an additional 4× on transmitted bytes on top of the θ filter).

Layout: x (R, LANE). Each grid step quantizes a (BR, LANE) tile: row scale
= max|x|/127 (fp32), q = clip(round(x/scale)). Dequant is the inverse
kernel. Both are single-pass VPU work with VMEM-resident tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024
BLOCK_R = 8


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("interpret", "block_r"))
def quantize_q8(x, *, interpret: bool = True, block_r: int = BLOCK_R):
    """x: (R, LANE) float -> (q int8 (R, LANE), scale f32 (R, 1))."""
    R = x.shape[0]
    grid = (pl.cdiv(R, block_r),)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, LANE), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_r, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, LANE), jnp.int8),
            jax.ShapeDtypeStruct((R, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def _dequant_kernel(q_ref, s_ref, out_ref):
    out_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret", "block_r"))
def dequantize_q8(q, scale, *, interpret: bool = True, block_r: int = BLOCK_R):
    R = q.shape[0]
    grid = (pl.cdiv(R, block_r),)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, LANE), jnp.float32),
        interpret=interpret,
    )(q, scale)
