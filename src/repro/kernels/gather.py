"""Pallas TPU kernel: one-hot cohort gather on the parameter arena.

The device-resident control plane selects a fixed-width cohort of K
clients per scanned round; per-client arena buffers (the batched
error-feedback state, per-client delta slabs) must then be gathered by
the selected indices WITHOUT leaving the device. On TPU a dynamic
``jnp.take`` over the leading axis lowers to a serial DMA per row; the
MXU-friendly formulation is a one-hot matmul over the client axis:

    out[c] = Σ_n onehot[c, n] · src[n]          onehot: (K, N) f32

which is exact (each row has a single 1.0 coefficient) and reuses the
same (BR, LANE)-tiled sweep as ``masked_agg``. The grid sweeps the row
dimension; the full client axis is VMEM-resident per tile (N·BR·LANE·4 B
= 32 clients → 1 MiB at BR=8, comfortably inside ~16 MiB v5e VMEM).

CPU path: the pure-jnp oracle in ``kernels/ref.py`` (``jnp.take``) —
bit-matching because the one-hot sum has exactly one nonzero term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024
BLOCK_R = 8


def _gather_kernel(oh_ref, src_ref, out_ref):
    oh = oh_ref[...].astype(jnp.float32)               # (K, N)
    src = src_ref[...].astype(jnp.float32)             # (N, BR, LANE)
    out_ref[...] = jnp.einsum("kn,nrl->krl", oh, src)


@functools.partial(jax.jit, static_argnames=("interpret", "block_r"))
def onehot_gather(src, onehot, *, interpret: bool = True,
                  block_r: int = BLOCK_R):
    """src: (N, R, LANE) f32; onehot: (K, N) f32 -> (K, R, LANE) f32."""
    N, R, _ = src.shape
    K = onehot.shape[0]
    grid = (pl.cdiv(R, block_r),)
    return pl.pallas_call(
        _gather_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, N), lambda i: (0, 0)),
            pl.BlockSpec((N, block_r, LANE), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((K, block_r, LANE), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, R, LANE), jnp.float32),
        interpret=interpret,
    )(onehot, src)
