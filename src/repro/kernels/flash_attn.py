"""Pallas TPU kernel: flash attention with VMEM-resident accumulators
(§Perf iteration C — the fix XLA-level blockwise attention cannot give,
see models/layers.py iteration-B note).

Grid: (B·K·G, nq). Each instance owns one (BQ, hd) query tile and loops
the KV blocks with ``jax.lax.fori_loop``; the online-softmax statistics
(m, l) and the (BQ, hd) output accumulator live in VMEM for the whole
loop — HBM traffic is exactly q+k+v reads + o writes, O(S·hd) instead of
O(S²). Causal masking per tile; MXU-aligned tiles (BQ=BK=128, hd≥64).

HBM-traffic model for the roofline (per device, per layer, fwd):
    bytes = (q + k + v + o) = 4·B·S·H·hd·itemsize       [vs  B·H·S²·4  naive]
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128
BK = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, scale: float,
                  nk: int, block_q: int, block_k: int):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale            # (BQ, hd)
    hd = q.shape[-1]

    def body(kj, carry):
        m, l, acc = carry
        k = k_ref[kj].astype(jnp.float32)                  # (BK, hd)
        v = v_ref[kj].astype(jnp.float32)
        s = q @ k.T                                        # (BQ, BK)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = kj * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(k_pos <= q_pos, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), -1e30, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, hd), jnp.float32)
    upper = (qi + 1) * block_q // block_k if causal else nk
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "interpret",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, interpret: bool = True,
                    block_q: int = BQ, block_k: int = BK):
    """q: (BH, S, hd); k/v: (BH, Sk, hd) — heads pre-flattened (GQA groups
    expanded by the ops.py wrapper). Returns (BH, S, hd) in q.dtype."""
    BH, S, hd = q.shape
    Sk = k.shape[1]
    nq, nk = S // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_flash_kernel, causal=causal, scale=scale,
                               nk=nk, block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, Sk // block_k, block_k, hd),
                         lambda b, i: (b, 0, 0, 0)),
            pl.BlockSpec((None, Sk // block_k, block_k, hd),
                         lambda b, i: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        interpret=interpret,
    )(q, k.reshape(BH, nk, block_k, hd), v.reshape(BH, nk, block_k, hd))


def flash_bytes(batch: int, seq: int, heads: int, hd: int,
                itemsize: int = 2) -> int:
    """Kernel HBM-traffic model: q+k+v reads + o write."""
    return 4 * batch * seq * heads * hd * itemsize
