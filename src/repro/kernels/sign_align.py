"""Pallas TPU kernel: gradient sign-alignment counting (paper Alg. 1,
CALCULATE-RELEVANCE — the O(C·M) hot spot of the technique).

TPU adaptation (DESIGN.md §7): where the paper's PyTorch loop issues one
tiny CUDA kernel per tensor per client (2.13M launches in its Table VI),
we flatten the parameter pytree ONCE into a (R, 1024) layout and sweep it
with a 1-D grid of VMEM-resident (BR, 1024) tiles; each grid step
accumulates its partial count into a per-tile output that is summed by the
jit'd wrapper. Elementwise compare + reduce → VPU-bound, fully vectorized.

Also provides the per-client variant: u (C, R, LANE) against a shared
reference sign tile — one pass produces all C counts (grid over R only;
the client dim stays resident in VMEM, C ≤ 64 for any realistic mesh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024          # 8 sublanes × 128 lanes
BLOCK_R = 8          # rows per tile -> (8, 1024) f32 = 32 KiB VMEM per ref


def _count_kernel(g_ref, r_ref, out_ref):
    s = jnp.sign(g_ref[...].astype(jnp.float32)).astype(jnp.int8)
    eq = (s == r_ref[...]).astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(eq)


@functools.partial(jax.jit, static_argnames=("interpret", "block_r"))
def sign_align_counts(g, r, *, interpret: bool = True, block_r: int = BLOCK_R):
    """g: (R, LANE) float; r: (R, LANE) int8. Returns scalar f32 count."""
    R = g.shape[0]
    grid = (pl.cdiv(R, block_r),)
    partial = pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_r, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 1), jnp.float32),
        interpret=interpret,
    )(g, r)
    return partial.sum()


def _per_client_kernel(u_ref, r_ref, out_ref):
    s = jnp.sign(u_ref[...].astype(jnp.float32)).astype(jnp.int8)
    eq = (s == r_ref[...][None]).astype(jnp.float32)       # (C, BR, LANE)
    out_ref[:, 0] = jnp.sum(eq, axis=(1, 2))


@functools.partial(jax.jit, static_argnames=("interpret", "block_r"))
def per_client_sign_align(u, r, *, interpret: bool = True,
                          block_r: int = BLOCK_R):
    """u: (C, R, LANE); r: (R, LANE) int8 -> (C,) aligned counts (f32)."""
    C, R, _ = u.shape
    grid = (pl.cdiv(R, block_r),)
    partial = pl.pallas_call(
        _per_client_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, block_r, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((block_r, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((C, 1), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((C, grid[0]), jnp.float32),
        interpret=interpret,
    )(u, r)
    return partial.sum(axis=1)
