"""Declarative tier trees: `TierSpec` / `TopologySpec`.

Follows the `core/scenario.py` spec idiom: frozen dataclasses whose
`issues(prefix)` return (field, value, hint) triples that
`ExperimentSpec.validate()` folds into one `SpecError`, plus a
`resolve_topology` normalizer that maps presets by name and collapses
inactive (single-tier) topologies to None so a flat topology is the
no-topology path by construction.

Tier semantics: `tiers[0]` is the leaf tier whose pods hold
`tiers[0].fanout` clients each; `tiers[t].fanout` (t > 0, non-root) is
the number of tier-(t-1) pods per tier-t pod; the root tier absorbs
every pod below it regardless of fanout.  `tiers[t].sync_every` is the
round cadence at which tier-(t-1) accumulators sync up into tier t
(the leaf tier accumulates every round, so its cadence must be 1), and
`theta` is the per-tier sign-alignment veto threshold (None = accept
every child on each sync).
"""
import dataclasses
from typing import Optional, Tuple, Union

__all__ = ["TOPOLOGY_PRESETS", "TierSpec", "TopologySpec",
           "resolve_topology"]


@dataclasses.dataclass(frozen=True)
class TierSpec:
    name: str
    fanout: Optional[int] = None
    sync_every: int = 1
    theta: Optional[float] = None
    lat_scale: float = 1.0
    bw_scale: float = 1.0

    def issues(self, prefix=""):
        out = []
        if not self.name:
            out.append((prefix + "name", self.name, "tier needs a name"))
        if self.fanout is not None and self.fanout < 1:
            out.append((prefix + "fanout", self.fanout, "must be >= 1"))
        if self.sync_every < 1:
            out.append((prefix + "sync_every", self.sync_every,
                        "must be >= 1"))
        if self.theta is not None and not 0.0 <= self.theta <= 1.0:
            out.append((prefix + "theta", self.theta,
                        "must be in [0, 1] or None"))
        if self.lat_scale <= 0.0:
            out.append((prefix + "lat_scale", self.lat_scale,
                        "must be > 0"))
        if self.bw_scale <= 0.0:
            out.append((prefix + "bw_scale", self.bw_scale, "must be > 0"))
        return out


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    tiers: Tuple[TierSpec, ...] = ()
    assignment_seed: int = 0

    def __post_init__(self):
        if isinstance(self.tiers, list):
            object.__setattr__(self, "tiers", tuple(self.tiers))

    def active(self):
        """A topology with fewer than two tiers has no boundary to sync
        across: it is the flat star and resolves to None."""
        return len(self.tiers) >= 2

    def issues(self, prefix="topology."):
        out = []
        names = [t.name for t in self.tiers]
        if len(set(names)) != len(names):
            out.append((prefix + "tiers", tuple(names),
                        "tier names must be unique"))
        for i, tier in enumerate(self.tiers):
            out.extend(tier.issues(f"{prefix}tiers[{i}]."))
        if self.active():
            if self.tiers[0].sync_every != 1:
                out.append((prefix + "tiers[0].sync_every",
                            self.tiers[0].sync_every,
                            "leaf tier accumulates every round"))
            for i, tier in enumerate(self.tiers[:-1]):
                if tier.fanout is None:
                    out.append((f"{prefix}tiers[{i}].fanout", None,
                                "non-root tiers need a fanout"))
            for i in range(1, len(self.tiers)):
                lo = self.tiers[i - 1].sync_every
                hi = self.tiers[i].sync_every
                if lo and hi % lo != 0:
                    out.append((f"{prefix}tiers[{i}].sync_every", hi,
                                f"must be a multiple of tier {i - 1}'s "
                                f"sync_every={lo} (nested cadence)"))
        return out


TOPOLOGY_PRESETS = {
    # the ISSUE / paper Fig. 2 shape: frequent edge-pod accumulation,
    # selective regional syncs, rare global syncs
    "edge-region-global": TopologySpec(tiers=(
        TierSpec("edge", fanout=8, sync_every=1),
        TierSpec("region", fanout=4, sync_every=4, theta=0.65),
        TierSpec("global", sync_every=16),
    )),
    # the core/hierarchy.py 2-tier special case as a preset
    "two-tier-pods": TopologySpec(tiers=(
        TierSpec("pod", fanout=8, sync_every=1),
        TierSpec("global", sync_every=4, theta=0.65),
    )),
}


def resolve_topology(value: Union[None, str, TopologySpec]):
    """Normalize a topology knob to an *active* TopologySpec or None.

    Accepts None, a preset name, or a TopologySpec; single-tier (or
    empty) topologies normalize to None so that a flat topology is
    bit-exact with today's path because it IS today's path.
    """
    if value is None:
        return None
    if isinstance(value, str):
        if value not in TOPOLOGY_PRESETS:
            raise ValueError(
                f"unknown topology preset {value!r}; "
                f"known: {sorted(TOPOLOGY_PRESETS)}")
        value = TOPOLOGY_PRESETS[value]
    if not isinstance(value, TopologySpec):
        raise TypeError(f"topology must be None, a preset name or a "
                        f"TopologySpec, got {type(value).__name__}")
    return value if value.active() else None
