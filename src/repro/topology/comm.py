"""Per-tier link pricing for inter-tier syncs.

Edge uplinks (client -> leaf pod) are already priced by the flat
engine's `CommModel` / `LinkSpec` walks as `bytes_sent`; this module
prices only the NEW traffic hierarchy introduces — pod payloads crossing
tier boundaries when a sync fires.  Accepted (sign-alignment-passing)
children ship a full payload; vetoed children ship only a beacon, the
same beacon-byte convention the flat selective-update path uses.  The
flat-star equivalent (every client's payload crossing the WAN to one
server every round) is the baseline hierarchy is measured against.
"""
import dataclasses
from typing import Tuple

from repro.topology.spec import TopologySpec

__all__ = ["PARAM_BYTES", "TierLink", "boundary_links", "flat_star_bytes"]

# wire width of one aggregated parameter on an inter-tier link (f32)
PARAM_BYTES = 4.0


@dataclasses.dataclass(frozen=True)
class TierLink:
    """Resolved link pricing for one boundary (tier b -> tier b+1)."""
    payload_bytes: float
    beacon_bytes: float
    latency: float
    bandwidth: float

    def sync_bytes(self, accepted, vetoed):
        return accepted * self.payload_bytes + vetoed * self.beacon_bytes

    def sync_time(self):
        """One sync wave: per-tier links are homogeneous and transfer in
        parallel, so the wave costs one latency + one payload transfer."""
        return self.latency + self.payload_bytes / self.bandwidth


def boundary_links(spec: TopologySpec, comm, n_params: int
                   ) -> Tuple[TierLink, ...]:
    """One `TierLink` per boundary, scaled off the experiment's
    `CommModel` (duck-typed: latency / bandwidth / beacon_bytes) by the
    parent tier's lat_scale / bw_scale."""
    latency = getattr(comm, "latency", 0.05) if comm is not None else 0.05
    bandwidth = (getattr(comm, "bandwidth", 1e9)
                 if comm is not None else 1e9)
    beacon = (getattr(comm, "beacon_bytes", 0.125)
              if comm is not None else 0.125)
    payload = float(n_params) * PARAM_BYTES
    return tuple(
        TierLink(payload_bytes=payload, beacon_bytes=float(beacon),
                 latency=float(latency) * tier.lat_scale,
                 bandwidth=float(bandwidth) * tier.bw_scale)
        for tier in spec.tiers[1:])


def flat_star_bytes(num_clients: int, n_params: int, rounds: int) -> float:
    """Inter-tier bytes of the flat-star equivalent: every client's
    payload crosses the single WAN aggregation point every round."""
    return float(num_clients) * float(n_params) * PARAM_BYTES * float(rounds)
