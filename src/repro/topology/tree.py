"""Static tier tree + seeded client->leaf-pod assignment.

The assignment is a seeded affine bijection on client ids,
``perm(c) = (c * mult + offset) % N`` with ``gcd(mult, N) == 1`` checked
on the host at build time, so it is pointwise-computable: a non-resident
million-client world gets pod structure without materializing an (N,)
array — `leaf_pods` works on scalars, numpy arrays and jnp arrays alike
(host math is done in int64 to dodge int32 overflow at N ~ 1e6).
"""
import dataclasses
import math
from typing import Tuple

import numpy as np

from repro.topology.spec import TopologySpec

__all__ = ["TopologyTree", "build_tree", "child_valid", "leaf_pods"]


@dataclasses.dataclass(frozen=True)
class TopologyTree:
    """Resolved node counts + assignment constants for one topology.

    pods[t] is the node count of tier t (pods[-1] == 1, the root);
    groups[b] is the child-slot count per parent at boundary b (tier b
    children -> tier b+1 parents), i.e. the reshape factor for syncs.
    """
    num_clients: int
    leaf_fanout: int
    pods: Tuple[int, ...]
    groups: Tuple[int, ...]
    mult: int
    offset: int

    @property
    def num_boundaries(self):
        return len(self.pods) - 1


def build_tree(spec: TopologySpec, num_clients: int) -> TopologyTree:
    if not spec.active():
        raise ValueError("build_tree needs an active (>= 2 tier) topology")
    if num_clients < 1:
        raise ValueError(f"num_clients must be >= 1, got {num_clients}")
    tiers = spec.tiers
    pods = [max(1, -(-num_clients // tiers[0].fanout))]
    for t in range(1, len(tiers) - 1):
        pods.append(max(1, -(-pods[-1] // tiers[t].fanout)))
    pods.append(1)
    groups = []
    for b in range(len(pods) - 1):
        if b + 1 < len(pods) - 1:
            groups.append(tiers[b + 1].fanout)
        else:
            groups.append(pods[b])          # the root absorbs everything
    rng = np.random.default_rng(spec.assignment_seed)
    offset = int(rng.integers(0, num_clients))
    mult = 1
    if num_clients > 1:
        for _ in range(256):
            cand = int(rng.integers(1, num_clients))
            if math.gcd(cand, num_clients) == 1:
                mult = cand
                break
    return TopologyTree(num_clients=num_clients,
                        leaf_fanout=tiers[0].fanout,
                        pods=tuple(pods), groups=tuple(groups),
                        mult=mult, offset=offset)


def leaf_pods(tree: TopologyTree, ids):
    """Leaf pod id for each client id; pointwise, no (N,) table."""
    ids = np.asarray(ids, dtype=np.int64)
    perm = (ids * tree.mult + tree.offset) % tree.num_clients
    return (perm // tree.leaf_fanout).astype(np.int32)


def child_valid(tree: TopologyTree, b: int) -> np.ndarray:
    """Static (parents, group) bool mask: which child slots at boundary
    b are real tier-b pods (the tail slots of the last parent are arena
    padding introduced by the ceil-division fanout)."""
    parents, group = tree.pods[b + 1], tree.groups[b]
    idx = np.arange(parents * group).reshape(parents, group)
    return idx < tree.pods[b]
