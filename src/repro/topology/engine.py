"""Pure-jnp multi-tier sync engine: `TopologyState` + `topology_step`.

`TopologyState` is the topology twin of `ControlState`: per-tier arrays
with a leading pod dim, a valid `lax.scan` carry, so the step runs
eagerly on the loop/megastep paths, inside `build_scanned_rounds`'
scan carry, and through `fl_step`.

Design: topology rides ON TOP of the flat round as an
accumulate-and-sync layer — the flat training trajectory is unchanged
(single-tier ≡ no topology bit-exactly, accuracy identical by
construction).  Each round every leaf pod accumulates its clients'
weighted delta contributions (the scatter-add decomposes the global
update: the pod accumulators sum to `weighted_sum(deltas, w)`).  A
boundary b (tier b children -> tier b+1 parents) syncs when
``(r + 1) % tiers[b+1].sync_every == 0`` — a closed form on the
ABSOLUTE round index, not a carried counter, so ``rounds_per_dispatch=R``
stays bit-identical to ``R=1``.  On sync each parent judges its
children's accumulators against its reference signs
(`cohort_alignment`), vetoes misaligned pods (theta), with the
bootstrap `has_ref` semantics and all-vetoed fallback inherited from
`core/hierarchy.maybe_pod_sync`; accepted children are masked-mean
aggregated up, all child accumulators reset (broadcast-down), and the
link pricing charges payloads for accepted pods and beacons for vetoed
ones.
"""
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alignment
from repro.topology import comm as comm_mod
from repro.topology import tree as tree_mod
from repro.topology.spec import TopologySpec

__all__ = ["TopologyRuntime", "TopologyState", "empty_topology",
           "init_topology"]


class TopologyState(NamedTuple):
    """Per-tier sync state; every leaf is a jnp array (scan-carry safe).

    accum[b]:   (pods[b], rows, lane) f32 — child-side accumulators at
                boundary b (accum[0] is the leaf-pod plane).
    ref[b]:     (pods[b+1], rows, lane) int8 — parent reference signs
                (-2 on arena padding so padding never matches).
    has_ref[b]: (pods[b+1],) bool — parents that have synced at least
                once (the PR 8 bootstrap fix: an explicit bool, not a
                counter == 0 test).
    tier_bytes / tier_time / syncs / accepts / vetoes: (B,) cumulative
                per-boundary accounting.
    """
    accum: Tuple
    ref: Tuple
    has_ref: Tuple
    tier_bytes: jnp.ndarray
    tier_time: jnp.ndarray
    syncs: jnp.ndarray
    accepts: jnp.ndarray
    vetoes: jnp.ndarray


def empty_topology() -> TopologyState:
    """Zero-width placeholder carry for topology-less runs (mirrors
    `scenario.empty_world`)."""
    zf = jnp.zeros((0,), jnp.float32)
    return TopologyState(accum=(), ref=(), has_ref=(),
                         tier_bytes=zf, tier_time=zf,
                         syncs=jnp.zeros((0,), jnp.int32),
                         accepts=zf, vetoes=zf)


def init_topology(tree: tree_mod.TopologyTree, arena) -> TopologyState:
    rows, lane = arena.rows, arena.lane
    base_ref = np.where(arena.valid_mask(), np.int8(0), np.int8(-2))
    accum, ref, has_ref = [], [], []
    for b in range(tree.num_boundaries):
        parents = tree.pods[b + 1]
        accum.append(jnp.zeros((tree.pods[b], rows, lane), jnp.float32))
        ref.append(jnp.asarray(np.tile(base_ref[None], (parents, 1, 1))))
        has_ref.append(jnp.zeros((parents,), bool))
    nb = tree.num_boundaries
    return TopologyState(accum=tuple(accum), ref=tuple(ref),
                         has_ref=tuple(has_ref),
                         tier_bytes=jnp.zeros((nb,), jnp.float32),
                         tier_time=jnp.zeros((nb,), jnp.float32),
                         syncs=jnp.zeros((nb,), jnp.int32),
                         accepts=jnp.zeros((nb,), jnp.float32),
                         vetoes=jnp.zeros((nb,), jnp.float32))


class TopologyRuntime:
    """Prepared engine for a fixed (spec, num_clients, arena, comm).

    `step(state, r, deltas, w, pods)` is pure jnp: deltas (C, rows,
    lane) and weights (C,) are the SAME cohort-packed deltas/weights the
    flat aggregation consumed that round (w == 0 for non-participants),
    `pods` the leaf pod of each cohort row (defaults to the full
    0..N-1 assignment `self.pod_of`), and r the absolute round index.
    Call it every round on every path — cadence must advance even on
    empty rounds.
    """

    def __init__(self, spec: TopologySpec, num_clients: int, arena,
                 comm=None):
        self.spec = spec
        self.arena = arena
        self.tree = tree_mod.build_tree(spec, num_clients)
        self.links = comm_mod.boundary_links(spec, comm, arena.n)
        self.pod_of = jnp.asarray(tree_mod.leaf_pods(
            self.tree, np.arange(num_clients, dtype=np.int64)))
        self._valid = tuple(
            jnp.asarray(tree_mod.child_valid(self.tree, b))
            for b in range(self.tree.num_boundaries))
        self._vmask = jnp.asarray(arena.valid_mask())
        self._syncs = tuple(self._make_sync(b)
                            for b in range(self.tree.num_boundaries))

    def init(self) -> TopologyState:
        return init_topology(self.tree, self.arena)

    def step(self, state: TopologyState, r, deltas, w,
             pods=None) -> TopologyState:
        if pods is None:
            pods = self.pod_of
        contrib = w[:, None, None].astype(jnp.float32) \
            * deltas.astype(jnp.float32)
        acc0 = state.accum[0].at[pods].add(contrib)
        state = state._replace(accum=(acc0,) + state.accum[1:])
        r = jnp.asarray(r, jnp.int32)
        for b in range(self.tree.num_boundaries):
            cadence = self.spec.tiers[b + 1].sync_every
            due = ((r + 1) % cadence) == 0
            state = jax.lax.cond(due, self._syncs[b], lambda s: s, state)
        return state

    def _make_sync(self, b):
        tree, spec = self.tree, self.spec
        children, parents = tree.pods[b], tree.pods[b + 1]
        group = tree.groups[b]
        theta = spec.tiers[b + 1].theta
        valid = self._valid[b]                       # (parents, group)
        vmask = self._vmask                          # (rows, lane)
        link = self.links[b]
        n = self.arena.n
        last = b == tree.num_boundaries - 1

        def sync(state):
            kids = state.accum[b]                    # (children, r, l)
            pad = parents * group - children
            if pad:
                kids_p = jnp.concatenate(
                    [kids, jnp.zeros((pad,) + kids.shape[1:], kids.dtype)])
            else:
                kids_p = kids
            grouped = kids_p.reshape(parents, group, *kids.shape[1:])
            ratios = jax.vmap(
                lambda u, ref: alignment.cohort_alignment(u, ref, n)
            )(grouped, state.ref[b])                 # (parents, group)
            if theta is None:
                passed = valid
            else:
                passed = (ratios >= theta) & valid
            # bootstrap: a parent with no reference yet accepts every
            # real child; then the all-vetoed fallback keeps liveness
            passed = jnp.where(~state.has_ref[b][:, None], valid, passed)
            none_passed = passed.sum(axis=1) == 0
            passed = jnp.where(none_passed[:, None], valid, passed)
            wf = passed.astype(jnp.float32)
            denom = jnp.maximum(wf.sum(axis=1), 1e-9)
            agg = jnp.einsum("pg,pgrl->prl", wf, grouped) \
                / denom[:, None, None]
            new_ref = jnp.where(vmask[None],
                                jnp.sign(agg).astype(jnp.int8),
                                jnp.int8(-2))
            accepted = wf.sum()
            vetoed = jnp.float32(children) - accepted
            accum = list(state.accum)
            accum[b] = jnp.zeros_like(kids)
            if not last:
                accum[b + 1] = state.accum[b + 1] + agg
            refs = list(state.ref)
            refs[b] = new_ref
            hrs = list(state.has_ref)
            hrs[b] = jnp.ones_like(state.has_ref[b])
            return state._replace(
                accum=tuple(accum), ref=tuple(refs), has_ref=tuple(hrs),
                tier_bytes=state.tier_bytes.at[b].add(
                    link.sync_bytes(accepted, vetoed)),
                tier_time=state.tier_time.at[b].add(link.sync_time()),
                syncs=state.syncs.at[b].add(1),
                accepts=state.accepts.at[b].add(accepted),
                vetoes=state.vetoes.at[b].add(vetoed))

        return sync

    def summary(self, state: TopologyState, rounds=None) -> dict:
        """Host-side per-tier accounting + flat-star comparison."""
        host = jax.device_get(state)
        out = {
            "tiers": [t.name for t in self.spec.tiers],
            "pods": list(self.tree.pods),
            "boundaries": [f"{self.spec.tiers[b].name}->"
                           f"{self.spec.tiers[b + 1].name}"
                           for b in range(self.tree.num_boundaries)],
            "tier_bytes": [float(x) for x in host.tier_bytes],
            "tier_time": [float(x) for x in host.tier_time],
            "syncs": [int(x) for x in host.syncs],
            "accepts": [float(x) for x in host.accepts],
            "vetoes": [float(x) for x in host.vetoes],
            "total_bytes": float(np.sum(host.tier_bytes)),
            "payload_bytes": self.links[0].payload_bytes,
        }
        if rounds:
            flat = comm_mod.flat_star_bytes(self.tree.num_clients,
                                            self.arena.n, rounds)
            out["rounds"] = int(rounds)
            out["bytes_per_round"] = out["total_bytes"] / rounds
            out["flat_star_bytes"] = flat
            out["flat_star_bytes_per_round"] = flat / rounds
            out["reduction"] = 1.0 - out["total_bytes"] / max(flat, 1e-9)
        return out
