"""Hierarchical federation as a first-class declarative axis.

A `TopologySpec` declares a tier tree (edge-pod -> regional -> global);
clients map to leaf pods via a seeded static assignment, and the engine
runs a pure-jnp `topology_step` on every execution path (loop, megastep,
scanned carry, spmd fl_step).  The flat training trajectory is untouched:
topology is an accumulate-and-sync measurement/distribution layer whose
per-tier sync cadence, sign-alignment vetoes and link pricing quantify
what hierarchy saves over a flat star.

    from repro.api import ExperimentSpec, TierSpec, TopologySpec

    spec = ExperimentSpec(topology=TopologySpec(tiers=(
        TierSpec("edge", fanout=8, sync_every=1),
        TierSpec("region", fanout=4, sync_every=4, theta=0.65),
        TierSpec("global", sync_every=16),
    )), rounds=32)
"""
from repro.topology.comm import (PARAM_BYTES, TierLink, boundary_links,
                                 flat_star_bytes)
from repro.topology.engine import (TopologyRuntime, TopologyState,
                                   empty_topology, init_topology)
from repro.topology.spec import (TOPOLOGY_PRESETS, TierSpec, TopologySpec,
                                 resolve_topology)
from repro.topology.tree import (TopologyTree, build_tree, child_valid,
                                 leaf_pods)

__all__ = [
    "PARAM_BYTES", "TOPOLOGY_PRESETS", "TierLink", "TierSpec",
    "TopologyRuntime", "TopologySpec", "TopologyState", "TopologyTree",
    "boundary_links", "build_tree", "child_valid", "empty_topology",
    "flat_star_bytes", "init_topology", "leaf_pods", "resolve_topology",
]
