"""repro.faults — seeded, deterministic fault injection (ISSUE 7).

The paper's operating regime *is* failure: stragglers, dropouts and
flaky links on the training side (§V "varying client conditions"), and
on the serving side the north star's "heavy traffic from millions of
users" guarantees bursts, dispatch errors and corrupted artifacts.
Companion work (arXiv:2501.15038 adaptive selection, arXiv:2411.01490
anomalous-client detection) treats client/server failure as normal;
this module makes every degradation path *provable* in CI by making the
faults themselves deterministic.

A :class:`FaultSpec` names the fault classes and their schedules; a
:class:`FaultInjector` is the runtime: each *site* (a short string
naming an operation — ``"scorer"``, ``"ckpt_write"``, ...) keeps its own
call counter and its own seeded generator, so whether call #k at a site
fires is a pure function of ``(spec.seed, site, k)`` — independent of
thread interleaving, wall time, or what any other site drew. Two runs
with the same spec inject byte-identical fault sequences, which is what
lets ``tests/test_faults.py`` assert exact shed counts, breaker
transitions and recovery paths instead of flaky probabilistic ones.

Standard sites (consumers may invent more — any string works):

  ``ckpt_write``   checkpoint serialization/IO errors on save
  ``ckpt_read``    checkpoint IO errors on restore
  ``scorer``       serving-engine scoring-dispatch exceptions
  ``publish``      model-slot publish crashes
  ``refederate``   re-federation session failures

Wiring is explicit where possible (``ServeEngine(injector=...)``,
``Refederator(injector=...)``) and ambient for the low-level checkpoint
IO, which has no construction site of its own: ``with injector.scoped():
...`` installs the injector process-wide so ``checkpoint/io.py`` hooks
see it — a plain module global (NOT a context-var) so background
re-federation threads inherit it.

Synthetic request bursts (:class:`BurstSpec`) are the sixth fault
class: not an exception but an arrival-pattern generator —
``spec.burst.sizes(windows, base)`` yields a deterministic per-window
request count where every ``period``-th window is ``mult`` times the
base load, the overload shape ``benchmarks/serve_bench.py`` measures
shed rate and p99-under-burst against.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import zlib
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

STANDARD_SITES = ("ckpt_write", "ckpt_read", "scorer", "publish",
                  "refederate")


class InjectedFault(RuntimeError):
    """The deterministic failure a :class:`FaultInjector` raises.

    Carries the site and the (0-based) call index that fired so
    degradation paths can log/assert exactly which injection they
    absorbed."""

    def __init__(self, site: str, index: int):
        self.site = site
        self.index = index
        super().__init__(f"injected fault at site {site!r} (call #{index})")


@dataclasses.dataclass(frozen=True)
class BurstSpec:
    """Deterministic synthetic traffic bursts: every ``period``-th
    window offers ``mult``x the base request count (``phase`` shifts
    which window bursts first)."""
    period: int = 4
    mult: int = 8
    phase: int = 0

    def is_burst(self, window: int) -> bool:
        return self.period > 0 and (window % self.period) == (
            self.phase % self.period)

    def size(self, window: int, base: int) -> int:
        return base * self.mult if self.is_burst(window) else base

    def sizes(self, windows: int, base: int) -> List[int]:
        return [self.size(w, base) for w in range(windows)]


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Which fault classes fire, and how often.

    ``*_p`` fields are per-call probabilities drawn from a per-site
    seeded generator (1.0 = every call, the persistent-failure regime
    that must open circuit breakers). ``at`` maps a site to EXACT call
    indices that fire regardless of probability — the surgical schedule
    tests use ("fail attempt 0, succeed attempt 1"). ``burst`` is the
    synthetic arrival-pattern fault class for the serving queue.
    """
    seed: int = 0
    ckpt_write_p: float = 0.0
    ckpt_read_p: float = 0.0
    scorer_p: float = 0.0
    publish_p: float = 0.0
    refederate_p: float = 0.0
    at: Mapping[str, Tuple[int, ...]] = dataclasses.field(
        default_factory=dict)
    burst: Optional[BurstSpec] = None

    _P_FIELDS = {"ckpt_write": "ckpt_write_p", "ckpt_read": "ckpt_read_p",
                 "scorer": "scorer_p", "publish": "publish_p",
                 "refederate": "refederate_p"}

    def probability(self, site: str) -> float:
        return float(getattr(self, self._P_FIELDS.get(site, ""), 0.0)
                     if site in self._P_FIELDS else 0.0)

    def validate(self) -> "FaultSpec":
        for site, f in self._P_FIELDS.items():
            p = getattr(self, f)
            if not (0.0 <= p <= 1.0):
                raise ValueError(f"FaultSpec.{f}={p} outside [0, 1]")
        for site, idxs in self.at.items():
            if any(int(i) < 0 for i in idxs):
                raise ValueError(
                    f"FaultSpec.at[{site!r}]={idxs}: indices must be >= 0")
        if self.burst is not None and (self.burst.period < 1
                                       or self.burst.mult < 1):
            raise ValueError(
                f"BurstSpec(period={self.burst.period}, "
                f"mult={self.burst.mult}): both must be >= 1")
        return self


class FaultInjector:
    """Runtime for a :class:`FaultSpec`: per-site call counters + seeded
    draws, thread-safe (sites may be polled from the serving thread and
    a background re-federation thread concurrently)."""

    def __init__(self, spec: Optional[FaultSpec] = None):
        self.spec = (spec or FaultSpec()).validate()
        self._lock = threading.Lock()
        self._rng: Dict[str, np.random.Generator] = {}
        self.calls: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}

    def _site_rng(self, site: str) -> np.random.Generator:
        if site not in self._rng:
            # (seed, crc32(site)) keys the stream: deterministic per
            # site, independent across sites, stable across processes
            self._rng[site] = np.random.default_rng(
                [self.spec.seed, zlib.crc32(site.encode())])
        return self._rng[site]

    # ------------------------------------------------------------------
    def _advance(self, site: str) -> Tuple[bool, int]:
        with self._lock:
            k = self.calls.get(site, 0)
            self.calls[site] = k + 1
            fire = k in set(int(i) for i in self.spec.at.get(site, ()))
            p = self.spec.probability(site)
            if p > 0.0:
                # the draw advances even when at= already decided, so
                # the stream position stays a function of k alone
                fire = bool(self._site_rng(site).random() < p) or fire
            if fire:
                self.fired[site] = self.fired.get(site, 0) + 1
            return fire, k

    def poll(self, site: str) -> bool:
        """Advance ``site``'s counter; True when this call is scheduled
        to fail. A pure function of (seed, site, call index)."""
        return self._advance(site)[0]

    def check(self, site: str) -> None:
        """Raise :class:`InjectedFault` when this call is scheduled to
        fail — the one-liner degradation paths wrap in try/except."""
        fire, k = self._advance(site)
        if fire:
            raise InjectedFault(site, k)

    def counts(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {s: {"calls": self.calls.get(s, 0),
                        "fired": self.fired.get(s, 0)}
                    for s in sorted(set(self.calls) | set(self.fired))}

    # ------------------------------------------------------------------
    # ambient installation for the low-level checkpoint IO hooks
    # ------------------------------------------------------------------
    @contextlib.contextmanager
    def scoped(self):
        """Install this injector as the process-wide ambient injector
        consulted by ``repro.checkpoint.io`` (a module global, visible
        to background threads; restores the previous one on exit)."""
        global _ACTIVE
        prev = _ACTIVE
        _ACTIVE = self
        try:
            yield self
        finally:
            _ACTIVE = prev


_ACTIVE: Optional[FaultInjector] = None


def active() -> Optional[FaultInjector]:
    """The ambient injector installed by ``FaultInjector.scoped()`` (or
    None outside any chaos scope)."""
    return _ACTIVE


def check_active(site: str) -> None:
    """Hook for modules without an injection constructor argument
    (checkpoint IO): fault-check ``site`` against the ambient injector;
    a no-op when no chaos scope is active."""
    inj = _ACTIVE
    if inj is not None:
        inj.check(site)


__all__ = [
    "BurstSpec", "FaultInjector", "FaultSpec", "InjectedFault",
    "STANDARD_SITES", "active", "check_active",
]
