"""Pytree checkpoint I/O (msgpack + raw numpy buffers, no deps beyond
msgpack). Used by the Weibull-driven CheckpointManager and the trainers."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, tree) -> None:
    leaves, treedef = _flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [
            {"dtype": str(np.asarray(l).dtype),
             "shape": list(np.asarray(l).shape),
             "data": np.asarray(l).tobytes()}
            for l in leaves
        ],
    }
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)  # atomic — a crash never corrupts the checkpoint


def restore(path: str, like):
    """Restore into the structure of ``like`` (shapes must match)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves_like, treedef = jax.tree.flatten(like)
    blobs = payload["leaves"]
    if len(blobs) != len(leaves_like):
        raise ValueError(f"checkpoint has {len(blobs)} leaves, "
                         f"expected {len(leaves_like)}")
    leaves = []
    for blob, ref in zip(blobs, leaves_like):
        arr = np.frombuffer(blob["data"], dtype=np.dtype(blob["dtype"]))
        arr = arr.reshape(blob["shape"])
        if tuple(arr.shape) != tuple(np.asarray(ref).shape):
            raise ValueError(f"shape mismatch {arr.shape} vs "
                             f"{np.asarray(ref).shape}")
        leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, leaves)
