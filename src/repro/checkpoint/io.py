"""Pytree checkpoint I/O (msgpack + raw numpy buffers, no deps beyond
msgpack). Used by the Weibull-driven CheckpointManager and the trainers.

Integrity (ISSUE 7): every checkpoint written since format v2 embeds a
SHA-256 digest of its packed body; :func:`restore` verifies it before
deserializing, so a truncated file, a bit-flipped payload or msgpack
garbage raises :class:`CheckpointCorruptError` naming the offending
path instead of surfacing as an unpickling/shape error deep in the
restore. :func:`verify` is the cheap non-raising probe behind
``CheckpointManager.latest_good()``. Legacy (pre-digest) checkpoints
still restore — they parse as the old bare payload dict — but
``verify`` reports them as good only if they parse cleanly.

Fault injection: :func:`save`/:func:`restore` consult the ambient
``repro.faults`` injector (sites ``ckpt_write``/``ckpt_read``) so the
chaos suite can prove the degradation paths. An injected write fault
fires BEFORE the atomic rename — the previous checkpoint at ``path``
is never damaged by a failed save.
"""
from __future__ import annotations

import hashlib
import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro import faults

IO_FORMAT = 2


class CheckpointCorruptError(OSError):
    """A checkpoint that cannot be trusted: truncated, bit-flipped,
    unparseable, or failing its content digest. ``.path`` names the
    offending artifact."""

    def __init__(self, path: str, reason: str):
        self.path = path
        self.reason = reason
        super().__init__(f"corrupt checkpoint {path!r}: {reason}")


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _pack_body(tree) -> bytes:
    leaves, treedef = _flatten(tree)
    return msgpack.packb({
        "treedef": str(treedef),
        "leaves": [
            {"dtype": str(np.asarray(l).dtype),
             "shape": list(np.asarray(l).shape),
             "data": np.asarray(l).tobytes()}
            for l in leaves
        ],
    }, use_bin_type=True)


def save(path: str, tree) -> None:
    body = _pack_body(tree)
    envelope = msgpack.packb({
        "format": IO_FORMAT,
        "sha256": hashlib.sha256(body).hexdigest(),
        "body": body,
    }, use_bin_type=True)
    faults.check_active("ckpt_write")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(envelope)
    os.replace(tmp, path)  # atomic — a crash never corrupts the checkpoint


def _read_payload(path: str) -> dict:
    """Read + digest-verify ``path`` down to the body payload dict."""
    faults.check_active("ckpt_read")
    with open(path, "rb") as f:
        raw = f.read()
    try:
        outer = msgpack.unpackb(raw, raw=False)
    except Exception as e:
        raise CheckpointCorruptError(
            path, f"unparseable msgpack ({type(e).__name__}: {e})") from e
    if isinstance(outer, dict) and "body" in outer:        # format v2
        body = outer["body"]
        want = outer.get("sha256")
        got = hashlib.sha256(body).hexdigest()
        if want != got:
            raise CheckpointCorruptError(
                path, f"content digest mismatch (sidecar sha256 {want!r} "
                      f"!= computed {got!r})")
        try:
            payload = msgpack.unpackb(body, raw=False)
        except Exception as e:
            raise CheckpointCorruptError(
                path, f"digest ok but body unparseable "
                      f"({type(e).__name__}: {e})") from e
    elif isinstance(outer, dict) and "leaves" in outer:    # legacy v1
        payload = outer
    else:
        raise CheckpointCorruptError(
            path, "not a checkpoint envelope (no body/leaves)")
    return payload


def verify(path: str) -> bool:
    """True iff ``path`` exists and its content digest checks out (or,
    for a legacy pre-digest checkpoint, parses cleanly). Never raises —
    the probe ``latest_good()`` scans candidates with."""
    if not os.path.exists(path):
        return False
    try:
        _read_payload(path)
        return True
    except (CheckpointCorruptError, OSError, faults.InjectedFault):
        return False


def restore(path: str, like):
    """Restore into the structure of ``like`` (shapes must match).
    Raises :class:`CheckpointCorruptError` on any untrusted artifact."""
    payload = _read_payload(path)
    leaves_like, treedef = jax.tree.flatten(like)
    blobs = payload["leaves"]
    if len(blobs) != len(leaves_like):
        raise ValueError(f"checkpoint has {len(blobs)} leaves, "
                         f"expected {len(leaves_like)}")
    leaves = []
    for blob, ref in zip(blobs, leaves_like):
        arr = np.frombuffer(blob["data"], dtype=np.dtype(blob["dtype"]))
        arr = arr.reshape(blob["shape"])
        if tuple(arr.shape) != tuple(np.asarray(ref).shape):
            raise ValueError(f"shape mismatch {arr.shape} vs "
                             f"{np.asarray(ref).shape}")
        leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, leaves)
