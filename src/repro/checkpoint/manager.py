"""Weibull-interval-driven checkpoint manager (paper §IV-C).

Wraps checkpoint/io.py with the adaptive policy: the manager is told the
current (simulated or real) time and failure history; it re-fits (λ, k)
and writes a checkpoint whenever the optimal interval has elapsed.

Rolling retention + verified recovery (ISSUE 7): every save also lands
in a sequence-numbered history file (``ckpt_<tag>_00007.msgpack``),
pruned to the newest ``keep`` entries, and :meth:`latest_good` walks
that history newest-first returning the first artifact whose content
digest verifies — so a corrupted (or injected-fault) latest checkpoint
degrades to the previous good one instead of killing recovery.
:meth:`restore` takes ``fallback=True`` to do exactly that
automatically.
"""
from __future__ import annotations

import os
import re
import shutil
import time
from typing import List, Optional

import numpy as np

from repro.checkpoint import io
from repro.checkpoint.io import CheckpointCorruptError
from repro.core.checkpoint_policy import fit_weibull, optimal_interval


class CheckpointManager:
    def __init__(self, directory: str, total_time: float = 3600.0,
                 recovery_time: float = 5.0, min_interval: float = 1.0,
                 keep: int = 3):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.total_time = total_time
        self.recovery_time = recovery_time
        self.min_interval = min_interval
        self.keep = keep
        self.failures: List[float] = []
        self.last_save: Optional[float] = None
        self.interval = total_time / 20.0   # prior before any failures
        self.saves = 0

    def record_failure(self, t: float):
        self.failures.append(t)
        if len(self.failures) >= 2:
            lam, k = fit_weibull(np.diff(sorted(self.failures)))
            self.interval = max(
                self.min_interval,
                optimal_interval(self.total_time, self.recovery_time, lam, k))

    def should_save(self, now: float) -> bool:
        if self.last_save is None:
            return True
        return (now - self.last_save) >= self.interval

    def path(self, tag: str = "latest") -> str:
        return os.path.join(self.dir, f"ckpt_{tag}.msgpack")

    def _history_path(self, tag: str, seq: int) -> str:
        return os.path.join(self.dir, f"ckpt_{tag}_{seq:05d}.msgpack")

    def history(self, tag: str = "latest") -> List[str]:
        """Retained history paths for ``tag``, newest first."""
        pat = re.compile(rf"^ckpt_{re.escape(tag)}_(\d{{5}})\.msgpack$")
        entries = []
        for name in os.listdir(self.dir):
            m = pat.match(name)
            if m:
                entries.append((int(m.group(1)),
                                os.path.join(self.dir, name)))
        return [p for _seq, p in sorted(entries, reverse=True)]

    def save(self, tree, now: float = None, tag: str = "latest"):
        now = time.time() if now is None else now
        canonical = self.path(tag)
        io.save(canonical, tree)
        # the history copy shares the just-verified bytes (the digest
        # rides inside the file), so a later bit-flip of either copy is
        # detected independently
        hist = self._history_path(tag, self.saves)
        shutil.copyfile(canonical, hist)
        self.last_save = now
        self.saves += 1
        for stale in self.history(tag)[self.keep:]:
            os.remove(stale)

    def maybe_save(self, tree, now: float, tag: str = "latest") -> bool:
        if self.should_save(now):
            self.save(tree, now, tag)
            return True
        return False

    def latest_good(self, tag: str = "latest") -> Optional[str]:
        """Newest retained checkpoint whose content digest verifies —
        the canonical path first, then the rolling history newest-first.
        None when no trustworthy artifact survives."""
        for cand in [self.path(tag)] + self.history(tag):
            if io.verify(cand):
                return cand
        return None

    def restore(self, like, tag: str = "latest", fallback: bool = False):
        """Restore ``tag``'s canonical checkpoint. With
        ``fallback=True`` a corrupt (or missing) canonical artifact
        degrades to :meth:`latest_good` instead of raising; only when
        NO retained artifact verifies does the original error surface.
        """
        from repro.faults import InjectedFault
        try:
            return io.restore(self.path(tag), like)
        except (CheckpointCorruptError, OSError, InjectedFault):
            if not fallback:
                raise
            good = self.latest_good(tag)
            if good is None:
                raise
            return io.restore(good, like)
