"""Weibull-interval-driven checkpoint manager (paper §IV-C).

Wraps checkpoint/io.py with the adaptive policy: the manager is told the
current (simulated or real) time and failure history; it re-fits (λ, k)
and writes a checkpoint whenever the optimal interval has elapsed.
"""
from __future__ import annotations

import os
import time
from typing import List, Optional

import numpy as np

from repro.checkpoint import io
from repro.core.checkpoint_policy import fit_weibull, optimal_interval


class CheckpointManager:
    def __init__(self, directory: str, total_time: float = 3600.0,
                 recovery_time: float = 5.0, min_interval: float = 1.0):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.total_time = total_time
        self.recovery_time = recovery_time
        self.min_interval = min_interval
        self.failures: List[float] = []
        self.last_save: Optional[float] = None
        self.interval = total_time / 20.0   # prior before any failures
        self.saves = 0

    def record_failure(self, t: float):
        self.failures.append(t)
        if len(self.failures) >= 2:
            lam, k = fit_weibull(np.diff(sorted(self.failures)))
            self.interval = max(
                self.min_interval,
                optimal_interval(self.total_time, self.recovery_time, lam, k))

    def should_save(self, now: float) -> bool:
        if self.last_save is None:
            return True
        return (now - self.last_save) >= self.interval

    def path(self, tag: str = "latest") -> str:
        return os.path.join(self.dir, f"ckpt_{tag}.msgpack")

    def save(self, tree, now: float = None, tag: str = "latest"):
        now = time.time() if now is None else now
        io.save(self.path(tag), tree)
        self.last_save = now
        self.saves += 1

    def maybe_save(self, tree, now: float, tag: str = "latest") -> bool:
        if self.should_save(now):
            self.save(tree, now, tag)
            return True
        return False

    def restore(self, like, tag: str = "latest"):
        return io.restore(self.path(tag), like)
