"""End-to-end driver: federated training of a ~100M-parameter LM with the
paper's selective-update aggregation via the compiled SPMD engine.

The model is a 6-layer, d_model=768 qwen2-style decoder (~109M params
with embeddings) trained on a synthetic token stream, 4 FL clients, using
the SAME production fl_train_step that the multi-pod dry-run lowers —
just on the CPU device, driven through one ``ExperimentSpec`` with
``engine="spmd"``. Logs loss / accept-rate / bytes saved by the θ-filter.

  PYTHONPATH=src python examples/federated_lm.py --steps 300
(defaults to a CI-friendly 30; --steps 300 is the full run;
``REPRO_SMOKE=1`` shrinks to a 2-round, 2-layer miniature)
"""
import argparse
import os
import time

from repro.api import (DataSpec, ExperimentSpec, WorldSpec, run_experiment)
from repro.configs import registry
from repro.optim import schedule

SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30 if not SMOKE else 2)
    ap.add_argument("--clients", type=int, default=4 if not SMOKE else 2)
    ap.add_argument("--seq", type=int, default=256 if not SMOKE else 32)
    ap.add_argument("--per-client-batch", type=int, default=4 if not SMOKE
                    else 2)
    ap.add_argument("--theta", type=float, default=0.55)
    args = ap.parse_args()

    if SMOKE:
        cfg = registry.get_config("qwen2-1.5b").replace(
            num_layers=2, d_model=64, num_heads=2, num_kv_heads=1,
            head_dim=32, d_ff=128, vocab_size=512, remat=False)
    else:
        cfg = registry.get_config("qwen2-1.5b").replace(
            num_layers=6, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=50304, remat=False)
    print(f"model: {cfg.num_layers}L d{cfg.d_model} qwen2-style, "
          f"{cfg.param_count()/1e6:.1f}M params"
          + ("" if SMOKE else " (~100M target)"))

    bs = args.per_client_batch
    spec = ExperimentSpec(
        model=cfg,
        data=DataSpec(dataset="lm", partition="iid", seq_len=args.seq,
                      n_samples=args.clients * bs * 64, eval_samples=16),
        world=WorldSpec(num_clients=args.clients, profile="uniform"),
        strategy="cmfl",                    # sync + θ-filter (the spmd path)
        strategy_kwargs=dict(batch_size=bs, lr=3e-4, theta=args.theta,
                             local_epochs=1,
                             # one (C, B, seq) cohort batch per round
                             max_samples_per_round=bs),
        engine="spmd", rounds=args.steps, seed=0,
        optimizer="adamw",
        lr_schedule=schedule.cosine(3e-4, warmup_steps=20,
                                    total_steps=args.steps))

    t0 = time.time()
    res = run_experiment(spec)
    shown = res.records[:: max(1, args.steps // 10)]
    if shown[-1] is not res.final:
        shown.append(res.final)
    for r in shown:
        print(f"round {r.round:4d} loss={r.loss:.4f} "
              f"accept={r.accept_rate:.2f} "
              f"sent={r.bytes_sent/1e9:.2f}GB")
    saved = res.bytes_baseline - res.final.bytes_sent
    print(f"\n{args.steps} federated rounds in {time.time()-t0:.0f}s; "
          f"upload bytes saved by θ-filter: {saved/1e9:.2f} GB "
          f"(quality proxy -loss: {res.final.accuracy:.3f})")


if __name__ == "__main__":
    main()
