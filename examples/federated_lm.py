"""End-to-end driver: federated training of a ~100M-parameter LM with the
paper's selective-update aggregation, for a few hundred rounds.

The model is a 6-layer, d_model=768 qwen2-style decoder (~109M params
with embeddings) trained on a synthetic token stream, 4 FL clients, using
the SAME production fl_train_step that the multi-pod dry-run lowers —
just on the CPU device. Logs loss / accept-rate / bytes saved; writes
Weibull-managed checkpoints.

  PYTHONPATH=src python examples/federated_lm.py --steps 300
(defaults to a CI-friendly 30; --steps 300 is the full run)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.core import fl_step
from repro.data import synthetic
from repro.optim import adamw as optim_mod
from repro.optim import schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--per-client-batch", type=int, default=4)
    ap.add_argument("--theta", type=float, default=0.55)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_fedlm_ckpt")
    args = ap.parse_args()

    cfg = registry.get_config("qwen2-1.5b").replace(
        num_layers=6, d_model=768, num_heads=12, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=50304, remat=False)
    n_params = cfg.param_count()
    print(f"model: 6L d768 qwen2-style, {n_params/1e6:.1f}M params "
          f"(~100M target)")

    opt = optim_mod.adamw(3e-4)
    sched = schedule.cosine(3e-4, warmup_steps=20, total_steps=args.steps)
    state = fl_step.init_state(jax.random.PRNGKey(0), cfg, opt)
    step = fl_step.build_fl_train_step(cfg, opt, theta=args.theta,
                                       lr_schedule=sched)
    ckpt = CheckpointManager(args.ckpt_dir, total_time=3600.0)

    rng = np.random.default_rng(0)
    C, B, S = args.clients, args.per_client_batch, args.seq

    def next_batch():
        t, l = synthetic.make_lm_tokens(int(rng.integers(1 << 30)),
                                        C * B, S, cfg.vocab_size)
        return {"tokens": jnp.asarray(t.reshape(C, B, S)),
                "labels": jnp.asarray(l.reshape(C, B, S))}

    t0 = time.time()
    saved_bytes = 0.0
    for i in range(args.steps):
        state, m = step(state, next_batch())
        saved_bytes += float(m["bytes_baseline"] - m["bytes_sent"])
        if i % 10 == 0 or i == args.steps - 1:
            print(f"round {i:4d} loss={float(m['loss']):.4f} "
                  f"accept={float(m['accept_rate']):.2f} "
                  f"align={float(m['alignment_mean']):.3f} "
                  f"saved={saved_bytes/1e9:.2f}GB "
                  f"[{time.time()-t0:.0f}s]")
        ckpt.maybe_save(state.params, now=time.time() - t0)
    print(f"\n{args.steps} federated rounds in {time.time()-t0:.0f}s; "
          f"upload bytes saved by θ-filter: {saved_bytes/1e9:.2f} GB; "
          f"checkpoints written: {ckpt.saves}")


if __name__ == "__main__":
    main()
