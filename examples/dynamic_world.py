"""Dynamic worlds: the adaptive control plane vs a world that moves.

The paper's claim is robustness "across varying client conditions"
(§V) — this example actually varies them. One declarative knob turns a
frozen world into a living one::

    ExperimentSpec(scenario="dynamic", ...)        # preset, or
    ExperimentSpec(scenario=ScenarioSpec(drift=DriftSpec(rate=0.05),
                                         churn=ChurnSpec(period=3),
                                         links=LinkSpec(bw_sigma=0.25)))

and the same spec runs on every execution path (host loop, cohort
megastep, the scanned device control plane, the compiled spmd engine) —
the world transitions are pure-jnp state folded into the compiled
dispatches (core/scenario.py).

This script runs the paper's framework ("ours") under (a) a frozen
world, (b) concept drift + churn + flaky links, and (c) a byzantine
world where one client sign-flips its updates — and prints how the
θ-filter starves the adversary of aggregation weight.

  PYTHONPATH=src python examples/dynamic_world.py

``REPRO_SMOKE=1`` runs a <=4-round miniature (the CI smoke mode).
"""
import dataclasses
import os

import numpy as np

from repro.api import (ByzantineSpec, DataSpec, ExperimentSession,
                       ExperimentSpec, ScenarioSpec, WorldSpec,
                       run_experiment)
from repro.core import scenario as scenario_mod

SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def main():
    n_clients = 4 if SMOKE else 8
    spec = ExperimentSpec(
        model="anomaly-mlp-smoke" if SMOKE else "anomaly-mlp",
        data=DataSpec(n_samples=1500 if SMOKE else 12000,
                      eval_samples=300 if SMOKE else 2000),
        world=WorldSpec(num_clients=n_clients, dropout_p=0.1),
        strategy="ours",
        strategy_kwargs=dict(batch_size=32 if SMOKE else 64,
                             dynamic_batch=False),
        rounds=4 if SMOKE else 16,
        rounds_per_dispatch=4,            # scanned device control plane
        seed=0)

    for label, scenario in (("frozen world", None),
                            ("drift+churn+links", "dynamic")):
        res = run_experiment(dataclasses.replace(spec, scenario=scenario))
        f = res.final
        print(f"[{label:18s}] acc={f.accuracy:.3f} "
              f"sim_time={f.sim_time:7.2f}s bytes={f.bytes_sent:,.0f}")

    # the round-by-round roster the churn rotates (engine-independent
    # replay of the same WorldState trajectory the engines traverse)
    scn = scenario_mod.SCENARIO_PRESETS["dynamic"]
    views = scenario_mod.replay(scn, n_clients, spec.rounds)
    rosters = ["".join("x" if ok else "." for ok in wv["live"])
               for wv in views]
    print(f"churn roster by round (x=live): {' '.join(rosters)}")

    # byzantine world: client 0 transmits sign-flipped updates; the
    # θ-filter (§IV-C) rejects them at the source, so its pass-rate EMA
    # collapses while honest clients stay near 1
    byz = dataclasses.replace(
        spec, rounds=max(spec.rounds, 8),
        # a sync barrier + iid shards isolate the adversary: non-IID
        # minority shards (and an async quorum's mixed reference) can
        # make HONEST clients θ-divergent too — a data/schedule effect,
        # not the rejection mechanism this demo shows
        data=dataclasses.replace(spec.data, partition="iid"),
        strategy_kwargs=dict(spec.strategy_kwargs, mode="sync",
                             theta=0.6),
        scenario=ScenarioSpec(byzantine=ByzantineSpec(n_byz=1, scale=2.0,
                                                      sign_flip=True)))
    session = ExperimentSession.open(byz)
    session.run(byz.rounds)
    rates = np.asarray(session.client_pass_rates())
    print(f"byzantine world: θ pass-rate EMA  adversary={rates[0]:.2f}  "
          f"honest={rates[1:].min():.2f}..{rates[1:].max():.2f}")
    print("=> the filter starves the sign-flipped client of aggregation "
          "weight" if rates[0] < rates[1:].min() else
          "=> WARNING: adversary not separated (tiny run?)")


if __name__ == "__main__":
    main()
