"""Quickstart: federated anomaly detection with adaptive client selection.

Trains the paper's 3-layer MLP detector (256-128-64) on a synthetic
UNSW-NB15 surrogate across 10 heterogeneous clients, comparing the sync
FedAvg baseline against the paper's combined framework (async + θ-filter
+ adaptive selection + Weibull checkpointing), then prints the headline
deltas: end-to-end time, transmitted bytes, accuracy.

Everything is one declarative ``ExperimentSpec`` per run:

  PYTHONPATH=src python examples/quickstart.py

``REPRO_SMOKE=1`` runs a <=2-round miniature (the CI smoke mode).
"""
import dataclasses
import os

from repro.api import (CommModel, DataSpec, ExperimentSpec, WorldSpec,
                       run_experiment)

SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def main():
    spec = ExperimentSpec(
        model="anomaly-mlp" if not SMOKE else "anomaly-mlp-smoke",
        data=DataSpec(n_samples=20000 if not SMOKE else 1500,
                      eval_samples=4000 if not SMOKE else 300, alpha=0.5),
        world=WorldSpec(num_clients=10 if not SMOKE else 4, dropout_p=0.1),
        comm=CommModel(bandwidth=5e6, latency=0.5, t_sample=2e-3,
                       t_launch=0.25),
        strategy="fedavg",
        strategy_kwargs=dict(batch_size=64, lr=3e-2, local_epochs=2),
        rounds=8 if not SMOKE else 2, seed=0)

    results = {}
    for name in ["fedavg", "ours"]:
        res = run_experiment(dataclasses.replace(spec, strategy=name))
        results[name] = res.final
        print(f"[{name:7s}] acc={res.final.accuracy:.3f} "
              f"time={res.final.sim_time:7.1f}s "
              f"sent={res.final.bytes_sent/1e6:6.1f}MB "
              f"idle={res.final.idle_time:7.1f}s")

    base, ours = results["fedavg"], results["ours"]
    print(f"\nend-to-end time reduction : "
          f"{100*(1 - ours.sim_time/base.sim_time):.1f}%")
    print(f"transmitted-bytes saving  : "
          f"{100*(1 - ours.bytes_sent/max(base.bytes_sent,1)):.1f}%")
    print(f"accuracy delta            : "
          f"{100*(ours.accuracy - base.accuracy):+.2f} pts")


if __name__ == "__main__":
    main()
