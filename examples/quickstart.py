"""Quickstart: federated anomaly detection with adaptive client selection.

Trains the paper's 3-layer MLP detector (256-128-64) on a synthetic
UNSW-NB15 surrogate across 10 heterogeneous clients, comparing the sync
FedAvg baseline against the paper's combined framework (async + θ-filter
+ adaptive selection + Weibull checkpointing), then prints the headline
deltas: end-to-end time, transmitted bytes, accuracy.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import anomaly_mlp
from repro.core import async_engine as ae
from repro.core import baselines
from repro.data import partition, synthetic


def main():
    cfg = anomaly_mlp.CONFIG
    X, y = synthetic.make_unsw_like(0, 20000, cfg.num_features,
                                    cfg.num_classes)
    parts = partition.dirichlet_partition(y, 10, alpha=0.5, seed=0)
    clients = [{"x": X[p], "y": y[p]} for p in parts]
    Xe, ye = synthetic.make_unsw_like(1, 4000, cfg.num_features,
                                      cfg.num_classes)
    eval_set = {"x": Xe, "y": ye}
    profiles = ae.heterogeneous_profiles(10, seed=1, dropout_p=0.1)
    comm = ae.CommModel(bandwidth=5e6, latency=0.5, t_sample=2e-3,
                        t_launch=0.25)

    results = {}
    for name in ["fedavg", "ours"]:
        strat = baselines.PRESETS[name](batch_size=64, lr=3e-2,
                                        local_epochs=2)
        sim = ae.FederatedSimulation(cfg, clients, eval_set, strat,
                                     profiles, comm=comm, seed=0)
        hist = sim.run(8)
        results[name] = hist[-1]
        print(f"[{name:7s}] acc={hist[-1].accuracy:.3f} "
              f"time={hist[-1].sim_time:7.1f}s "
              f"sent={hist[-1].bytes_sent/1e6:6.1f}MB "
              f"idle={hist[-1].idle_time:7.1f}s")

    base, ours = results["fedavg"], results["ours"]
    print(f"\nend-to-end time reduction : "
          f"{100*(1 - ours.sim_time/base.sim_time):.1f}%")
    print(f"transmitted-bytes saving  : "
          f"{100*(1 - ours.bytes_sent/max(base.bytes_sent,1)):.1f}%")
    print(f"accuracy delta            : "
          f"{100*(ours.accuracy - base.accuracy):+.2f} pts")


if __name__ == "__main__":
    main()
