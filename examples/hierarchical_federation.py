"""Hierarchical federation: a declarative 3-tier topology under drift.

The paper's selective-update rule charges the star topology per client;
real fleets are hierarchical — devices behind an edge gateway, gateways
behind a regional aggregator, regions behind one global server. PR 9
makes that hierarchy a first-class axis of the experiment spec::

    ExperimentSpec(topology=TopologySpec(tiers=[
        TierSpec("edge",   fanout=8),                 # leaf pods
        TierSpec("region", fanout=4, sync_every=4, theta=0.65),
        TierSpec("global", sync_every=16)]), ...)     # root

or simply ``topology="edge-region-global"`` (the preset above). The
tier tree rides ON TOP of the flat round as an accumulate-and-sync
measurement layer — the training trajectory (and hence accuracy) is
identical to the flat run by construction; what changes is WHERE bytes
flow: inter-tier syncs fire only on their cadence, and only
sign-aligned pods ship payloads upstream (vetoed pods cost one beacon).

This script runs the same drifting-world experiment flat and 3-tiered,
then prints the per-tier sync/byte ledger and the bytes-per-round
reduction vs the flat star at the SAME accuracy.

  PYTHONPATH=src python examples/hierarchical_federation.py

``REPRO_SMOKE=1`` runs a <=4-round miniature (the CI smoke mode).
"""
import dataclasses
import os

from repro.api import (DataSpec, ExperimentSpec, TierSpec, TopologySpec,
                       WorldSpec)
from repro.api.runner import build_simulation

SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def main():
    n_clients = 12 if SMOKE else 64
    rounds = 4 if SMOKE else 16
    topology = TopologySpec(tiers=(
        TierSpec("edge", fanout=4 if SMOKE else 8),
        TierSpec("region", fanout=2 if SMOKE else 4,
                 sync_every=2, theta=0.5),
        TierSpec("global", sync_every=4)))
    spec = ExperimentSpec(
        model="anomaly-mlp-smoke" if SMOKE else "anomaly-mlp",
        data=DataSpec(n_samples=1500 if SMOKE else 12000,
                      eval_samples=300 if SMOKE else 2000),
        world=WorldSpec(num_clients=n_clients),
        strategy="ours",
        strategy_kwargs=dict(batch_size=32 if SMOKE else 64,
                             dynamic_batch=False),
        scenario="drift",
        rounds=rounds,
        rounds_per_dispatch=4,            # topology inside the lax.scan
        topology=topology,
        seed=0).validate()

    # flat baseline: identical spec, no tier tree — the trajectories
    # coincide bit-for-bit (topology is measurement-only), so accuracy
    # comparisons below are *exact*, not statistical
    flat = build_simulation(dataclasses.replace(spec, topology=None))
    flat.run(rounds)
    tiered = build_simulation(spec)
    tiered.run(rounds)

    f, t = flat.history[-1], tiered.history[-1]
    print(f"[flat star ] acc={f.accuracy:.3f} "
          f"client bytes={f.bytes_sent:,.0f}")
    print(f"[3-tier tree] acc={t.accuracy:.3f} "
          f"client bytes={t.bytes_sent:,.0f} (identical by construction)")

    s = tiered.topology_summary()
    print(f"tier tree: {' -> '.join(s['tiers'])}  pods per tier "
          f"{s['pods']}")
    for b, name in enumerate(s["boundaries"]):
        print(f"  [{name:>14s}] syncs={s['syncs'][b]:3d} "
              f"accepted={s['accepts'][b]:5.0f} "
              f"vetoed={s['vetoes'][b]:4.0f} "
              f"bytes={s['tier_bytes'][b]:,.0f} "
              f"link_time={s['tier_time'][b]:.3f}s")
    print(f"inter-tier bytes/round   {s['bytes_per_round']:,.0f}")
    print(f"flat-star bytes/round    {s['flat_star_bytes_per_round']:,.0f}")
    print(f"=> hierarchy moves {100 * s['reduction']:.1f}% fewer bytes "
          "per round across the expensive inter-tier links, at the SAME "
          "accuracy")


if __name__ == "__main__":
    main()
