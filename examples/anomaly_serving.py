"""Serving example: batched network-flow scoring with the trained global
model + ROAD-style automotive CAN masquerade detection.

Trains briefly (federated, via one ``ExperimentSpec`` per dataset), then
serves two request streams:
  1. UNSW-like flow batches -> per-class probabilities + binary AUC;
  2. ROAD-like CAN windows -> masquerade alarm rate.

  PYTHONPATH=src python examples/anomaly_serving.py

``REPRO_SMOKE=1`` runs a <=2-round miniature (the CI smoke mode).
"""
import os
import time

import jax
import jax.numpy as jnp

from repro.api import DataSpec, ExperimentSpec, WorldSpec, run_experiment
from repro.configs import anomaly_mlp
from repro.data import synthetic
from repro.models import mlp_detector

SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def train(cfg, rounds=8, clients=8, seed=0, alpha=0.7):
    if SMOKE:
        rounds, clients = 2, 4
    res = run_experiment(ExperimentSpec(
        model=cfg,
        data=DataSpec(n_samples=16000 if not SMOKE else 2000,
                      eval_samples=3000 if not SMOKE else 400, alpha=alpha),
        world=WorldSpec(num_clients=clients, profile="heterogeneous",
                        profile_seed_offset=0),
        strategy="ours",
        strategy_kwargs=dict(batch_size=128, lr=3e-2, local_epochs=2),
        rounds=rounds, seed=seed))
    print(f"  trained: acc={res.final.accuracy:.3f} "
          f"(sim {res.final.sim_time:.1f}s)")
    return res.params


def main():
    print("== UNSW-like flow scoring ==")
    cfg = anomaly_mlp.CONFIG
    params = train(cfg)
    serve = jax.jit(lambda p, x: mlp_detector.predict(p, x, cfg))
    Xq, yq = synthetic.make_unsw_like(99, 4096, cfg.num_features,
                                      cfg.num_classes)
    t0 = time.time()
    probs = serve(params, jnp.asarray(Xq))
    probs.block_until_ready()
    dt = time.time() - t0
    scores = 1.0 - probs[:, 0]
    auc = float(mlp_detector.auc_roc(scores, jnp.asarray((yq != 0))
                                     .astype(jnp.float32)))
    print(f"  scored {len(Xq)} flows in {dt*1e3:.1f} ms "
          f"({len(Xq)/dt:.0f} flows/s), binary AUC-ROC={auc:.3f}")

    print("== ROAD-like CAN masquerade detection ==")
    rcfg = anomaly_mlp.ROAD_CONFIG
    # binary labels + strong Dirichlet skew give degenerate all-one-class
    # clients; use a milder split for the 2-class CAN task (alpha=5)
    rparams = train(rcfg, rounds=12, alpha=5.0)
    rserve = jax.jit(lambda p, x: mlp_detector.predict(p, x, rcfg))
    Xr, yr = synthetic.make_road_like(7, 4096, window=rcfg.num_features)
    pr = rserve(rparams, jnp.asarray(Xr))
    alarm = jnp.argmax(pr, -1)
    tp = float(((alarm == 1) & (yr == 1)).sum() / max((yr == 1).sum(), 1))
    fp = float(((alarm == 1) & (yr == 0)).sum() / max((yr == 0).sum(), 1))
    print(f"  masquerade TPR={tp:.3f} FPR={fp:.3f} "
          f"on {len(Xr)} CAN windows")


if __name__ == "__main__":
    main()
