"""Serving example: batched network-flow scoring with the trained global
model + ROAD-style automotive CAN masquerade detection.

Trains briefly (federated, via one ``ExperimentSpec`` per dataset), then
serves two request streams through ``repro.serve.ServeEngine`` (request
queue, power-of-two batch buckets, versioned model slot):
  1. UNSW-like flow batches -> per-class probabilities + binary AUC;
  2. ROAD-like CAN windows -> masquerade alarm rate.

  PYTHONPATH=src python examples/anomaly_serving.py

``REPRO_SMOKE=1`` runs a <=2-round miniature (the CI smoke mode).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import DataSpec, ExperimentSpec, WorldSpec, run_experiment
from repro.configs import anomaly_mlp
from repro.data import synthetic
from repro.models import mlp_detector
from repro.serve import ModelSlot, ServeEngine

SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def train(cfg, rounds=8, clients=8, seed=0, alpha=0.7):
    if SMOKE:
        rounds, clients = 2, 4
    res = run_experiment(ExperimentSpec(
        model=cfg,
        data=DataSpec(n_samples=16000 if not SMOKE else 2000,
                      eval_samples=3000 if not SMOKE else 400, alpha=alpha),
        world=WorldSpec(num_clients=clients, profile="heterogeneous",
                        profile_seed_offset=0),
        strategy="ours",
        strategy_kwargs=dict(batch_size=128, lr=3e-2, local_epochs=2),
        rounds=rounds, seed=seed))
    print(f"  trained: acc={res.final.accuracy:.3f} "
          f"(sim {res.final.sim_time:.1f}s)")
    return res.params


def serve_stream(cfg, params, X, max_batch=256):
    """Score a request stream through the engine; returns (responses,
    stats) — per-request scores, model versions and p50/p99 latency."""
    engine = ServeEngine(ModelSlot(params, model=cfg.name), cfg,
                         max_batch=max_batch)
    engine.submit_many(X)
    responses = engine.drain()
    return responses, engine.shutdown()


def main():
    print("== UNSW-like flow scoring ==")
    cfg = anomaly_mlp.CONFIG
    params = train(cfg)
    Xq, yq = synthetic.make_unsw_like(99, 4096, cfg.num_features,
                                      cfg.num_classes)
    responses, stats = serve_stream(cfg, params, Xq)
    scores = jnp.asarray([r.score for r in responses])
    auc = float(mlp_detector.auc_roc(scores, jnp.asarray((yq != 0))
                                     .astype(jnp.float32)))
    # busy_seconds is the engine's scoring time; the max() guard keeps a
    # fast machine from dividing by zero on a tiny smoke stream
    dt = max(stats.busy_seconds, 1e-9)
    print(f"  scored {stats.served} flows in {dt*1e3:.1f} ms "
          f"({stats.served/dt:.0f} flows/s, p50 {stats.p50_ms:.2f} ms, "
          f"p99 {stats.p99_ms:.2f} ms), binary AUC-ROC={auc:.3f}")
    assert stats.dropped == 0 and stats.errors == 0

    print("== ROAD-like CAN masquerade detection ==")
    rcfg = anomaly_mlp.ROAD_CONFIG
    # binary labels + strong Dirichlet skew give degenerate all-one-class
    # clients; use a milder split for the 2-class CAN task (alpha=5)
    rparams = train(rcfg, rounds=12, alpha=5.0)
    Xr, yr = synthetic.make_road_like(7, 4096, window=rcfg.num_features)
    rresp, rstats = serve_stream(rcfg, rparams, Xr)
    alarm = np.asarray([np.argmax(r.probs) for r in rresp])
    tp = float(((alarm == 1) & (yr == 1)).sum() / max((yr == 1).sum(), 1))
    fp = float(((alarm == 1) & (yr == 0)).sum() / max((yr == 0).sum(), 1))
    rdt = max(rstats.busy_seconds, 1e-9)
    print(f"  masquerade TPR={tp:.3f} FPR={fp:.3f} on {rstats.served} CAN "
          f"windows ({rstats.served/rdt:.0f} windows/s)")
    assert rstats.dropped == 0 and rstats.errors == 0


if __name__ == "__main__":
    main()
