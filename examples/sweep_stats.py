"""Sessions & sweeps: the paper's statistical validation as a sweep.

The headline claim (97.6% overhead reduction at 95.10% vs 95.12%
accuracy) is two Mann-Whitney U statements over repeated runs, which
this example reproduces as ONE declarative sweep instead of the
hand-rolled per-seed loops the benchmarks used to carry:

  * equal detection quality — two-sided U test on per-seed AUC-ROC of
    "ours" vs the sync FedAvg baseline: H0 (no difference) is KEPT;
  * reduced overhead — one-sided U tests on transmitted bytes and
    end-to-end simulated time: H0 rejected at alpha = 0.05 ("ours"
    stochastically smaller), the p < 0.05 comparison.

    sweep = run_sweep(spec, axes={"strategy": [...], "seed": range(N)})
    sweep.mann_whitney_u("strategy", "ours", "fedavg",
                         metric="bytes_sent", alternative="less")

The example also shows the session driver the sweep is built on:
streaming RoundRecords from an open experiment, checkpointing it
mid-run, and resuming bit-identically.

  PYTHONPATH=src python examples/sweep_stats.py

``REPRO_SMOKE=1`` runs a miniature (fewer seeds/rounds; with so few
samples the overhead p-values are only expected to clear the weaker
floor that sample size allows — the full run clears 0.05).
"""
import os
import tempfile

from repro.api import (DataSpec, ExperimentSession, ExperimentSpec,
                       WorldSpec, run_sweep)

SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def base_spec():
    return ExperimentSpec(
        model="anomaly-mlp" if not SMOKE else "anomaly-mlp-smoke",
        data=DataSpec(n_samples=8000 if not SMOKE else 1500,
                      eval_samples=2000 if not SMOKE else 300),
        world=WorldSpec(num_clients=8 if not SMOKE else 4,
                        dropout_p=0.3 if not SMOKE else 0.0),
        strategy="ours",
        strategy_kwargs=dict(batch_size=64 if not SMOKE else 32,
                             lr=3e-2, local_epochs=2),
        rounds=4 if not SMOKE else 2, seed=300)


def demo_session(spec):
    """Stream an experiment round by round, checkpoint, resume."""
    print("# --- session streaming + resume ---")
    session = ExperimentSession.open(spec)
    half = spec.rounds // 2 or 1
    for rec in session.stream(half):
        print(f"  round {rec.round}: acc={rec.accuracy:.3f} "
              f"sent={rec.bytes_sent / 1e6:.2f}MB")
    with tempfile.TemporaryDirectory() as d:
        path = session.checkpoint(os.path.join(d, "run.ckpt"))
        resumed = ExperimentSession.restore(path)
        resumed.run(spec.rounds - half)
    final = resumed.result().final
    print(f"  resumed to round {final.round}: acc={final.accuracy:.3f}")


def main():
    spec = base_spec()
    demo_session(spec)

    seeds = range(300, 300 + (10 if not SMOKE else 5))
    alpha = 0.05
    print("\n# --- multi-seed sweep (the paper's headline claim) ---")
    sweep = run_sweep(spec, axes={"strategy": ["ours", "fedavg"],
                                  "seed": seeds})
    print(sweep.report(metric="auc", baseline=None))

    # equal detection quality: two-sided — the paper's 95.10% vs 95.12%
    # is a NON-difference, so H0 should be kept
    quality = sweep.mann_whitney_u("strategy", "ours", "fedavg",
                                   metric="auc",
                                   alternative="two-sided")
    print(f"AUC ours vs fedavg (two-sided): U={quality.u:.1f} "
          f"p={quality.p_value:.4g} -> "
          f"{'DIFFER' if quality.significant(alpha) else 'equal quality'}")

    # reduced overhead: one-sided, ours stochastically SMALLER
    for metric, label in [("bytes_sent", "transmitted bytes"),
                          ("sim_time", "end-to-end time")]:
        r = sweep.mann_whitney_u("strategy", "ours", "fedavg",
                                 metric=metric, alternative="less")
        verdict = "reject_H0" if r.significant(alpha) else "keep_H0"
        ours = sweep.values(metric, strategy="ours").mean()
        base = sweep.values(metric, strategy="fedavg").mean()
        print(f"{label:18s}: ours/fedavg = {ours / max(base, 1e-9):.3f} "
              f"U={r.u:.1f} p={r.p_value:.4g} -> {verdict} "
              f"(alpha={alpha})")


if __name__ == "__main__":
    main()
