"""Hierarchical federated learning across pods (beyond-paper).

Two "pods" (datacenters) each run the paper's masked selective aggregation
over their own clients EVERY round; across pods, models synchronize only
every ``--sync-every`` rounds, and the cross-pod exchange is itself gated
by the sign-alignment test (core/hierarchy.py) — the paper's async +
selective idea applied recursively at datacenter scale. The per-pod
compiled step comes from the experiment API
(``repro.api.build_spmd_components``).

  PYTHONPATH=src python examples/hierarchical_pods.py

``REPRO_SMOKE=1`` runs a <=2-round miniature (the CI smoke mode).
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

SMOKE = bool(os.environ.get("REPRO_SMOKE"))

from repro.api import ExperimentSpec, WorldSpec, build_spmd_components
from repro.configs import anomaly_mlp
from repro.core import fl_step, hierarchy
from repro.data import partition, synthetic
from repro.models import mlp_detector
from repro.optim import adamw as optim_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--clients-per-pod", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=24 if not SMOKE else 2)
    ap.add_argument("--sync-every", type=int, default=4 if not SMOKE else 2)
    args = ap.parse_args()

    cfg = anomaly_mlp.CONFIG.replace(mlp_hidden=(64, 32), num_features=20,
                                     num_classes=5, dtype="float32")
    P, C = args.pods, args.clients_per_pod
    X, y = synthetic.make_unsw_like(0, 12000 if not SMOKE else 2000,
                                    cfg.num_features, cfg.num_classes)
    # pods see DIFFERENT non-IID slices (regional skew)
    pod_parts = partition.dirichlet_partition(y, P, alpha=1.0, seed=1)
    Xe, ye = synthetic.make_unsw_like(1, 3000, cfg.num_features,
                                      cfg.num_classes)
    ev = {"x": jnp.asarray(Xe), "y": jnp.asarray(ye)}

    spec = ExperimentSpec(
        model=cfg, world=WorldSpec(num_clients=C, profile="uniform"),
        strategy="cmfl",                       # sync + θ-filter per pod
        strategy_kwargs=dict(theta=0.6, lr=3e-2, batch_size=32),
        engine="spmd", seed=7,
        # persistent per-pod state across rounds -> momentum helps here
        # (the spec default resets it for per-round sim parity)
        optimizer=optim_mod.sgd(3e-2, momentum=0.9))
    _, _, opt, state0, step = build_spmd_components(spec)
    states = [state0] + [fl_step.init_state(jax.random.PRNGKey(7), cfg, opt)
                         for _ in range(P - 1)]
    sync = hierarchy.init_pod_sync(states[0].params)
    rng = np.random.default_rng(0)

    def pod_batch(p):
        idx = pod_parts[p]
        sel = rng.choice(idx, size=(C, 32))
        return {"x": jnp.asarray(X[sel]), "y": jnp.asarray(y[sel])}

    for r in range(args.rounds):
        metrics = []
        for p in range(P):
            states[p], m = step(states[p], pod_batch(p))
            metrics.append(m)
        # stack pod params (leading pod dim) and maybe cross-pod sync
        pod_params = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[s.params for s in states])
        pod_params, sync, sm = hierarchy.maybe_pod_sync(
            pod_params, sync, sync_every=args.sync_every, theta=0.6)
        for p in range(P):
            states[p] = states[p]._replace(
                params=jax.tree.map(lambda x, pp=p: x[pp], pod_params))
        if float(sm["synced"]) or r % 4 == 0:
            accs = [float(mlp_detector.accuracy(s.params, ev, cfg))
                    for s in states]
            spread = float(np.ptp(accs))
            tag = (f"SYNC accept={float(sm['pod_accept']):.2f}"
                   if float(sm["synced"]) else "    ")
            print(f"round {r:3d} pod-accs={['%.3f' % a for a in accs]} "
                  f"spread={spread:.3f} {tag}")

    accs = [float(mlp_detector.accuracy(s.params, ev, cfg)) for s in states]
    print(f"\nfinal: accs={['%.3f' % a for a in accs]} "
          f"(pods converge to a shared model via selective sync)")


if __name__ == "__main__":
    main()
