"""Fault-tolerance demo (paper Fig. 4 + §IV-C): clients drop out at
increasing rates; the Weibull-checkpointing framework keeps training,
while the no-checkpoint sync baseline loses client work. Also shows the
adaptive checkpoint interval reacting to the observed failure regime,
and (ISSUE 7) the verified-checkpoint recovery path: injected write
faults and a corrupted artifact degrade to ``latest_good()`` instead of
killing restore.

Each dropout level is expressed as a fault regime — a seeded
``repro.faults.FaultSpec`` plus a ``ScenarioSpec`` constant
``DropoutSchedule`` scale over the base profile dropout — the same
machinery ``benchmarks/fig4_fault_tolerance.py`` and the chaos suite
use, reproducing the legacy static-dropout patterns exactly.

  PYTHONPATH=src python examples/fault_tolerance.py

``REPRO_SMOKE=1`` runs a <=2-round miniature (the CI smoke mode).
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import DataSpec, ExperimentSpec, WorldSpec, run_experiment
from repro.checkpoint.manager import CheckpointManager
from repro.configs import anomaly_mlp
from repro.core.checkpoint_policy import fit_weibull, optimal_interval
from repro.core.scenario import DropoutSchedule, ScenarioSpec
from repro.faults import FaultInjector, FaultSpec, InjectedFault

SMOKE = bool(os.environ.get("REPRO_SMOKE"))
BASE_DROPOUT = 0.1


def fault_regime(dropout, seed=42):
    """(FaultSpec, ScenarioSpec) for one Fig.-4 dropout level: the
    schedule's constant scale makes the effective dropout
    ``BASE_DROPOUT x scale = dropout``."""
    # the write faults ride an exact `at` schedule so the demo's chaos
    # is the same on every run (saves #1 and #4 fail)
    fault = FaultSpec(seed=seed, at={"ckpt_write": (1, 4)}).validate()
    scenario = ScenarioSpec(dropout=DropoutSchedule(
        boundaries=(), scales=(dropout / BASE_DROPOUT,)))
    return fault, scenario


def checkpoint_chaos_demo(params, fault):
    """Rolling retention + verified recovery under injected IO faults:
    saves that fire ``ckpt_write`` leave the previous artifact intact,
    and a bit-flipped canonical checkpoint degrades to the newest
    digest-verified history copy (``latest_good``)."""
    inj = FaultInjector(fault)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        ok = failed = 0
        with inj.scoped():
            for i in range(6):
                try:
                    mgr.save(params, now=float(i))
                    ok += 1
                except InjectedFault:
                    failed += 1
        with open(mgr.path(), "r+b") as f:       # corrupt the newest
            f.seek(30)
            c = f.read(1)
            f.seek(30)
            f.write(bytes([c[0] ^ 0xFF]))
        good = mgr.latest_good()
        recovered = mgr.restore(jax.tree.map(jnp.zeros_like, params),
                                fallback=True)
        exact = all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(jax.tree.leaves(params),
                            jax.tree.leaves(recovered)))
        print(f"  {ok} saves ok, {failed} injected write faults absorbed "
              f"(previous artifact untouched each time)")
        print(f"  canonical bit-flipped -> latest_good() = "
              f"{os.path.basename(good)}; fallback restore "
              f"{'bit-identical' if exact else 'MISMATCH'}")


def main():
    cfg = (anomaly_mlp.CONFIG.replace(mlp_hidden=(128, 64), num_classes=10)
           if not SMOKE else anomaly_mlp.SMOKE)
    print(f"{'dropout':>8} {'ours_acc':>9} {'fedavg_acc':>11} "
          f"{'ours_deliver':>13} {'fedavg_deliver':>14}")
    last = None
    fault = None
    for p in ((0.1, 0.3, 0.5) if not SMOKE else (0.3,)):
        fault, scenario = fault_regime(p)
        accs, deliver = {}, {}
        for name in ["ours", "fedavg"]:
            res = run_experiment(ExperimentSpec(
                model=cfg,
                data=DataSpec(n_samples=12000 if not SMOKE else 1500,
                              eval_samples=3000 if not SMOKE else 300,
                              alpha=0.5),
                world=WorldSpec(num_clients=10 if not SMOKE else 4,
                                profile="uniform",
                                dropout_p=BASE_DROPOUT),
                scenario=scenario,
                strategy=name,
                strategy_kwargs=dict(batch_size=64, lr=3e-2,
                                     local_epochs=2),
                rounds=6 if not SMOKE else 2, seed=fault.seed))
            accs[name] = np.mean(res.series("accuracy")[-3:])
            deliver[name] = np.mean(res.series("accept_rate"))
            last = res
        print(f"{p:8.1f} {accs['ours']:9.3f} {accs['fedavg']:11.3f} "
              f"{deliver['ours']:13.2f} {deliver['fedavg']:14.2f}")

    print("\nadaptive checkpoint interval vs observed failure regime:")
    for mtbf in (100.0, 10.0, 1.0):
        rng = np.random.default_rng(0)
        samples = rng.exponential(mtbf, size=200)
        lam, k = fit_weibull(samples)
        t = optimal_interval(3600.0, recovery_time=5.0, lam=lam, k=k,
                             write_cost=0.5)
        print(f"  MTBF≈{mtbf:6.1f}s -> fitted (λ={lam:6.1f}, k={k:.2f}) "
              f"-> checkpoint every {t:7.2f}s")

    print("\nverified-checkpoint recovery under injected IO chaos:")
    checkpoint_chaos_demo(last.params, fault)


if __name__ == "__main__":
    main()
