"""Fault-tolerance demo (paper Fig. 4 + §IV-C): clients drop out at
increasing rates; the Weibull-checkpointing framework keeps training,
while the no-checkpoint sync baseline loses client work. Also shows the
adaptive checkpoint interval reacting to the observed failure regime.

  PYTHONPATH=src python examples/fault_tolerance.py

``REPRO_SMOKE=1`` runs a <=2-round miniature (the CI smoke mode).
"""
import os

import numpy as np

from repro.api import DataSpec, ExperimentSpec, WorldSpec, run_experiment
from repro.configs import anomaly_mlp
from repro.core.checkpoint_policy import fit_weibull, optimal_interval

SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def main():
    cfg = (anomaly_mlp.CONFIG.replace(mlp_hidden=(128, 64), num_classes=10)
           if not SMOKE else anomaly_mlp.SMOKE)
    print(f"{'dropout':>8} {'ours_acc':>9} {'fedavg_acc':>11} "
          f"{'ours_deliver':>13} {'fedavg_deliver':>14}")
    for p in ((0.1, 0.3, 0.5) if not SMOKE else (0.3,)):
        accs, deliver = {}, {}
        for name in ["ours", "fedavg"]:
            res = run_experiment(ExperimentSpec(
                model=cfg,
                data=DataSpec(n_samples=12000 if not SMOKE else 1500,
                              eval_samples=3000 if not SMOKE else 300,
                              alpha=0.5),
                world=WorldSpec(num_clients=10 if not SMOKE else 4,
                                profile="uniform", dropout_p=p),
                strategy=name,
                strategy_kwargs=dict(batch_size=64, lr=3e-2,
                                     local_epochs=2),
                rounds=6 if not SMOKE else 2, seed=42))
            accs[name] = np.mean(res.series("accuracy")[-3:])
            deliver[name] = np.mean(res.series("accept_rate"))
        print(f"{p:8.1f} {accs['ours']:9.3f} {accs['fedavg']:11.3f} "
              f"{deliver['ours']:13.2f} {deliver['fedavg']:14.2f}")

    print("\nadaptive checkpoint interval vs observed failure regime:")
    for mtbf in (100.0, 10.0, 1.0):
        rng = np.random.default_rng(0)
        samples = rng.exponential(mtbf, size=200)
        lam, k = fit_weibull(samples)
        t = optimal_interval(3600.0, recovery_time=5.0, lam=lam, k=k,
                             write_cost=0.5)
        print(f"  MTBF≈{mtbf:6.1f}s -> fitted (λ={lam:6.1f}, k={k:.2f}) "
              f"-> checkpoint every {t:7.2f}s")


if __name__ == "__main__":
    main()
