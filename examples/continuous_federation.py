"""Continuous federation: the full train -> serve -> drift ->
re-federate -> hot-swap loop (ISSUE 6 acceptance demo).

1. Federate an initial global detector (``ExperimentSession``) and put
   it behind a ``repro.serve`` scoring engine with an online drift
   monitor referenced to the training distribution.
2. Stream clean UNSW-like traffic windows — AUC is high, monitor quiet.
3. Inject label-conditional concept drift into the traffic (the
   ``DriftSpec`` transform from ``core/scenario.py``, here applied to
   LIVE requests instead of simulated clients) — the frozen model's AUC
   degrades and the monitor's shift statistic climbs.
4. After ``patience`` consecutive over-threshold windows the monitor
   fires; a background re-federation trains on the drifted
   distribution, checkpoints (sidecar-validated), and hot-swaps the
   refreshed model into the serving slot between micro-batches. Serving
   NEVER pauses: requests keep scoring during re-federation and none
   are dropped across the swap.
5. Post-swap windows recover AUC on the drifted traffic.

The whole loop runs UNDER INJECTED CHAOS (ISSUE 7): a deterministic
``repro.faults`` schedule fails one scoring dispatch (absorbed — the
batch re-queues and retries), fails the FIRST re-federation attempt
(retried with backoff), and slams one synthetic traffic burst into the
bounded queue (overflow shed at admission, every ACCEPTED request still
answered). The final health snapshot and the zero-dropped assertion
prove graceful degradation end to end.

  PYTHONPATH=src python examples/continuous_federation.py

``REPRO_SMOKE=1`` runs the miniature CI configuration.
"""
import os
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.api import DataSpec, ExperimentSession, ExperimentSpec, WorldSpec
from repro.configs import anomaly_mlp
from repro.core import scenario as scenario_mod
from repro.core.scenario import DriftSpec
from repro.data import synthetic
from repro.faults import BurstSpec, FaultInjector, FaultSpec
from repro.models import mlp_detector
from repro.serve import (DriftMonitor, ModelSlot, Refederator, ServeEngine,
                         health_snapshot)

SMOKE = bool(os.environ.get("REPRO_SMOKE"))

CFG = anomaly_mlp.SMOKE if SMOKE else anomaly_mlp.CONFIG
ROUNDS = 2 if SMOKE else 6                   # initial federation
REFED_ROUNDS = 2 if SMOKE else 6             # per re-federation
CLIENTS = 4 if SMOKE else 8
N_TRAIN = 2000 if SMOKE else 12000
WINDOW = 256                                 # flows per traffic window
DRIFT_AMP = 0.7                              # attacks drift 70% of the way
                                             # toward the Normal-class mean
CLEAN_WINDOWS = 3
RECOVER_WINDOWS = 3 if SMOKE else 5

# The drift transform is the scenario engine's label-conditional shift
# (x <- x + amp * dir[y], ``scenario.apply_drift``) applied to LIVE
# traffic instead of simulated clients. The direction field is the
# masquerade/evasion regime: each ATTACK class's cloud moves toward the
# Normal class's mean (dir[c] = mu_normal - mu_c, dir[normal] = 0), so a
# frozen detector scores drifted attacks as normal — AUC degrades and
# the served score distribution collapses, which is exactly what the
# online monitor watches. (Random per-class directions, DriftSpec's
# default, shuffle clouds without fooling the detector much — the
# adversarial field makes the demo's degradation unmistakable.)
DRIFT = DriftSpec(rate=1.0, max_amp=DRIFT_AMP, seed=11)

# The chaos schedule (everything deterministic — `at` indices, not
# probabilities): scoring dispatch #1 (clean window 1) raises and is
# absorbed; the first re-federation attempt fails and retries; the burst
# phase offers mult x WINDOW flows against the bounded queue.
QUEUE_LIMIT = 8 * WINDOW
FAULTS = FaultSpec(seed=7,
                   at={"scorer": (1,), "refederate": (0,)},
                   burst=BurstSpec(period=1, mult=16)).validate()


def _masquerade_dirs():
    X, y = synthetic.make_unsw_like(2024, 8192, CFG.num_features,
                                    CFG.num_classes)
    mu = np.stack([X[y == c].mean(0) for c in range(CFG.num_classes)])
    dirs = mu[0][None, :] - mu
    dirs[0] = 0.0
    return dirs.astype(np.float32)


DIRS = _masquerade_dirs()


def traffic(seed, n, amp):
    """One window of live flows; ``amp`` is the fraction of the distance
    each attack class has drifted toward the Normal mean (0 -> the
    training distribution, 1 -> class means coincide)."""
    X, y = synthetic.make_unsw_like(seed, n, CFG.num_features,
                                    CFG.num_classes)
    if amp:
        X = np.asarray(
            scenario_mod.apply_drift({"x": X, "y": y}, amp, DIRS)["x"])
    return X, y


def train_spec(amp, seed, rounds):
    """Federation spec whose data factory draws from the CURRENT traffic
    distribution (the factory makes the spec unpicklable — the sidecar +
    explicit spec pass-through handle that)."""
    return ExperimentSpec(
        model=CFG,
        data=DataSpec(n_samples=N_TRAIN, eval_samples=max(N_TRAIN // 5, 256),
                      factory=lambda s, n: traffic(s, n, amp)),
        world=WorldSpec(num_clients=CLIENTS, profile="heterogeneous"),
        strategy="ours",
        strategy_kwargs=dict(batch_size=64, lr=3e-2, local_epochs=2),
        rounds=rounds, seed=seed)


def window_auc(responses, y):
    scores = jnp.asarray([r.score for r in responses])
    return float(mlp_detector.auc_roc(
        scores, jnp.asarray((y != 0)).astype(jnp.float32)))


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="continuous_fed_")

    print("== phase 0: initial federation ==")
    session = ExperimentSession.open(train_spec(0.0, seed=0, rounds=ROUNDS))
    session.run()
    res = session.result()
    print(f"  trained {ROUNDS} rounds: acc={res.final.accuracy:.3f}")

    # serving stack: slot + engine + monitor referenced to the training
    # distribution under the JUST-TRAINED model's scores
    slot = ModelSlot(res.params, model=CFG.name, round_idx=ROUNDS)
    Xref, _yref = traffic(seed=123, n=1024, amp=0.0)
    ref_scores = 1.0 - np.asarray(
        mlp_detector.predict(res.params, jnp.asarray(Xref), CFG))[:, 0]
    # clean windows sit near the sampling-noise floor (~0.1 normalized
    # shift at n=256); the masquerade drift plateaus around 0.4 — 0.25
    # splits the two with margin on both sides
    monitor = DriftMonitor.from_sample(Xref, ref_scores,
                                       threshold=0.25, patience=2)
    injector = FaultInjector(FAULTS)
    refed = Refederator(
        slot, lambda k: train_spec(DRIFT_AMP, seed=100 + k,
                                   rounds=REFED_ROUNDS),
        ckpt_dir=ckpt_dir, monitor=monitor, background=True,
        max_retries=2, backoff_base=0.05, seed=FAULTS.seed,
        injector=injector)
    engine = ServeEngine(slot, CFG, max_batch=WINDOW, monitor=monitor,
                         queue_limit=QUEUE_LIMIT, deadline_ms=60_000.0,
                         injector=injector)
    engine.on_trigger = refed.fire

    def stream(w, amp):
        X, y = traffic(seed=1000 + w, n=WINDOW, amp=amp)
        engine.submit_many(X)
        responses = engine.drain()
        auc = window_auc(responses, y)
        v = responses[-1].model_version
        print(f"  window {w:2d} amp={amp:.1f} model=v{v} "
              f"AUC={auc:.3f} drift-stat={monitor.statistic:.2f}"
              f"{'  <- TRIGGER' if monitor.triggered and v == 0 else ''}")
        return auc, v

    print("== phase 1: clean traffic ==")
    w = 0
    clean = []
    for _ in range(CLEAN_WINDOWS):
        auc, _v = stream(w, 0.0)
        clean.append(auc)
        w += 1
    assert not monitor.triggered, "monitor must stay quiet on clean traffic"

    print("== phase 1b: synthetic burst — admission control sheds the "
          "overflow, every accepted flow is still answered ==")
    offered = FAULTS.burst.size(0, WINDOW)
    Xb, _yb = traffic(seed=555, n=offered, amp=0.0)
    accepted = engine.submit_many(Xb, best_effort=True)
    answered = engine.drain()
    shed = engine.stats().shed
    print(f"  offered {offered} flows against queue_limit={QUEUE_LIMIT}: "
          f"accepted {len(accepted)}, shed {shed}, answered "
          f"{len(answered)}")
    assert len(answered) == len(accepted) == QUEUE_LIMIT
    assert shed == offered - QUEUE_LIMIT
    assert not monitor.triggered, "a clean burst is load, not drift"

    print("== phase 2: drift injected — serving continues while the "
          "monitor detects and re-federation runs in the background ==")
    drifted = []
    OVERLAP = 4   # windows served concurrently with the background run
    # old model keeps serving drifted traffic until the refreshed
    # checkpoint is published AND flips in at a batch boundary
    for _ in range(40):
        auc, v = stream(w, DRIFT_AMP)
        w += 1
        if v > 0:
            recovered = [auc]       # first post-swap window
            break
        drifted.append(auc)
        # last_error is transient while retries are in flight (the
        # injected refederate fault is SUPPOSED to appear here); only a
        # terminal outcome aborts the demo
        if refed.last_outcome == "failed":
            raise refed.last_error
        if refed.fired and refed.busy and len(drifted) >= OVERLAP:
            # scoring never paused while training ran; now let the
            # background federation finish so the demo stays bounded —
            # the NEXT window's batch boundary flips the new model in
            refed.join(timeout=600)
    else:
        raise RuntimeError(
            f"no hot-swap after {len(drifted)} drifted windows "
            f"(trigger fired: {monitor.trigger_count}, "
            f"re-federations completed: {refed.completed})")

    # the swap changed the SCORE distribution too (the refreshed model
    # scores drifted attacks high again) — re-reference the monitor
    # under the new model's own scores so the improvement is not itself
    # read as drift (adopt_current carried the old model's moments)
    Xr2, _y2 = traffic(seed=777, n=1024, amp=DRIFT_AMP)
    p_new, _meta = slot.acquire()
    s_new = 1.0 - np.asarray(
        mlp_detector.predict(p_new, jnp.asarray(Xr2), CFG))[:, 0]
    monitor.rearm(reference=scenario_mod.reference_snapshot(
        jnp.asarray(Xr2), jnp.asarray(s_new)))

    print("== phase 3: post-swap recovery on drifted traffic ==")
    for _ in range(RECOVER_WINDOWS - 1):
        auc, _v = stream(w, DRIFT_AMP)
        recovered.append(auc)
        w += 1

    refed.join(timeout=600)     # no daemon thread may outlive the demo
    health = health_snapshot(engine, refederator=refed)
    stats = engine.shutdown()
    auc_clean = float(np.mean(clean))
    auc_drifted = float(np.mean(drifted))
    auc_recovered = float(np.mean(recovered))
    print(f"AUC: clean {auc_clean:.3f} -> drifted (stale model) "
          f"{auc_drifted:.3f} -> re-federated {auc_recovered:.3f}; "
          f"swaps={slot.swaps} versions={engine.versions_served} "
          f"served={stats.served}/{stats.submitted} "
          f"dropped={stats.dropped} errors={stats.errors}")
    print(f"health: status={health.status} shed={health.shed} "
          f"deadline_miss={health.deadline_miss} "
          f"dispatch_errors={health.dispatch_errors} "
          f"breaker={health.breaker_state} "
          f"refed_retries={health.refederation_retries} "
          f"last_refederation={health.last_refederation}")

    # the acceptance loop UNDER CHAOS: trigger fired, the injected
    # re-federation failure was retried to success, the injected scorer
    # fault was absorbed, the burst was shed at admission — and every
    # ACCEPTED request was answered (zero dropped)
    assert monitor.trigger_count >= 1, "drift monitor never fired"
    assert refed.completed >= 1 and refed.last_error is None
    assert refed.retries >= 1, "the injected refederate fault never fired"
    assert refed.breaker_state == "closed"
    assert slot.swaps >= 1 and max(engine.versions_served) >= 1
    assert stats.dropped == 0 and stats.deadline_miss == 0
    assert stats.errors == 1, "exactly one injected scorer fault"
    assert stats.served == stats.submitted
    assert auc_recovered > auc_drifted, (
        f"re-federation did not recover AUC: {auc_recovered:.3f} vs "
        f"drifted {auc_drifted:.3f}")


if __name__ == "__main__":
    main()
