"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp oracle vs the
fused selective-update path. On CPU the interesting number is the ORACLE
row (XLA-compiled jnp) — interpret-mode Pallas measures correctness, not
speed; on TPU the same harness times the real kernels. Prints
``name,us_per_call,derived`` CSV per the harness contract.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import masked_agg as ma
from repro.kernels import ops, ref
from repro.kernels import quantize as qz
from repro.kernels import sign_align as sa


def _time(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    key = jax.random.PRNGKey(0)
    R, C = 128, 16                      # ~131k-param update, 16 clients
    g = jax.random.normal(key, (R, ops.LANE))
    r = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1),
                                   (R, ops.LANE))).astype(jnp.int8)
    u = jax.random.normal(jax.random.fold_in(key, 2), (C, R, ops.LANE))
    w = jnp.full((C,), 1.0 / C)
    p = jax.random.normal(jax.random.fold_in(key, 3), (R, ops.LANE))

    jit_ref_align = jax.jit(ref.per_client_sign_align)
    jit_ref_agg = jax.jit(ref.masked_agg)
    jit_ref_fused = jax.jit(ref.fused_update)
    jit_ref_q = jax.jit(ref.quantize_q8)

    rows = [
        ["oracle_per_client_align", _time(jit_ref_align, u, r),
         f"C={C},R={R}"],
        ["oracle_masked_agg", _time(jit_ref_agg, u, w), f"C={C},R={R}"],
        ["oracle_fused_update", _time(jit_ref_fused, p, u, w),
         "agg+apply fused"],
        ["oracle_quantize_q8", _time(jit_ref_q, g), "4x bytes saved"],
        ["pallas_interp_align", _time(
            lambda: sa.per_client_sign_align(u, r, interpret=True)),
         "correctness mode"],
        ["pallas_interp_agg", _time(
            lambda: ma.masked_agg(u, w, interpret=True)),
         "correctness mode"],
        ["pallas_interp_quant", _time(
            lambda: qz.quantize_q8(g, interpret=True)),
         "correctness mode"],
    ]
    # two-pass (align then agg) vs fused single pass, oracle timing
    def two_pass(p, u, w):
        agg = ref.masked_agg(u, w)
        return (p - 0.01 * agg).astype(p.dtype)
    rows.append(["oracle_two_pass_update", _time(jax.jit(two_pass), p, u, w),
                 "unfused baseline"])
    print("name,us_per_call,derived")
    for n, t, d in rows:
        print(f"{n},{t:.1f},{d}")
    return rows


if __name__ == "__main__":
    run()
