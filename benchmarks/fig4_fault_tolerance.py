"""Paper Fig. 4: fault tolerance across dropout rates 0.1–0.5, ours vs
CMFL vs ACFL vs FedL2P, averaged over multiple random dropout patterns
(paper: 100 runs; default here: configurable --runs, lighter on CPU).

Each dropout level is a ``common.fault_regime``: a seeded
``repro.faults.FaultSpec`` naming the regime plus a ``ScenarioSpec``
whose constant ``DropoutSchedule`` scale delivers the level's effective
dropout (profile base x scale). The engines draw failure uniforms
independently of the threshold, so this reproduces the legacy static
``dropout_p`` patterns — and the figure — exactly, while routing the
fault model through the same scenario machinery the chaos suite
exercises."""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run(dropouts=(0.1, 0.3, 0.5), runs=3, rounds=8):
    rows = []
    for p in dropouts:
        fault, scenario = common.fault_regime(p, seed=100)
        accs = {}
        for name in ["ours", "cmfl", "acfl", "fedl2p"]:
            vals = []
            for r in range(runs):
                res = common.run(common.UNSW, name,
                                 strategy_kwargs=dict(batch_size=64,
                                                      lr=3e-2,
                                                      local_epochs=2),
                                 num_clients=10, rounds=rounds,
                                 dropout=common.BASE_DROPOUT,
                                 scenario=scenario,
                                 seed=fault.seed + r)
                vals.append(np.mean(res.series("accuracy")[-2:]))
            accs[name] = float(np.mean(vals))
        rows.append([p] + [round(accs[n] * 100, 2)
                           for n in ["ours", "cmfl", "acfl", "fedl2p"]])
    print(f"# mean over {runs} dropout patterns; ours must degrade least"
          " (paper Fig. 4)")
    return common.emit(rows, ["dropout", "ours_pct", "cmfl_pct",
                              "acfl_pct", "fedl2p_pct"])


if __name__ == "__main__":
    run()
