"""Paper Table IV: sensitivity analysis of the alignment threshold θ on
UNSW-like data — θ ∈ {0.50, 0.60, 0.65, 0.70, 0.75}.

Expected shape (paper §V-D): low θ admits noisy updates (more bytes /
overhead), high θ rejects too much (accuracy dips); 0.65 balances.
"""
from __future__ import annotations

from benchmarks import common


def run(thetas=(0.50, 0.60, 0.65, 0.70, 0.75), rounds=8):
    rows = []
    for theta in thetas:
        res = common.run(common.UNSW, "ours",
                         strategy_kwargs=dict(batch_size=64, lr=3e-2,
                                              theta=theta,
                                              dynamic_batch=False),
                         num_clients=10, rounds=rounds)
        m = res.final
        accept = sum(res.series("accept_rate")) / rounds
        rows.append([theta, round(m.accuracy * 100, 2),
                     round(m.comm_time, 1), round(m.bytes_sent / 1e6, 1),
                     round(accept, 3)])
    return common.emit(rows, ["theta", "acc_pct", "overhead_s", "MB_sent",
                              "accept_rate"])


if __name__ == "__main__":
    run()
