"""Serving latency/throughput benchmark + CI regression gate (ISSUE 6).

  PYTHONPATH=src python -m benchmarks.serve_bench --json BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.serve_bench --json /tmp/b.json \
      --check-against BENCH_serve.json

Times the ``repro.serve.ServeEngine`` scoring loop on the paper's
detector across fixed power-of-two batch buckets, reporting per-bucket
p50/p99 request latency and flows/sec, plus the raw jitted
``mlp_detector.predict`` dispatch rate as the machine-speed reference.
The engine's efficiency (engine flows/sec over raw flows/sec at the
largest bucket) isolates queueing + padding + accounting overhead from
model compute — the ratio the regression gate really guards.

``--check-against`` mirrors ``benchmarks/run.py::_check_regression``:
per-bucket flows/sec must stay within ``tolerance`` of the committed
JSON after normalizing out machine speed via the raw-dispatch reference,
and the benchmark refuses to compare across different measurement
protocols (buckets / batches / arch) rather than spuriously pass.
"""
from __future__ import annotations

import argparse
import json
import time

DEFAULT_BUCKETS = (64, 256)
DEFAULT_BATCHES = 30          # timed micro-batches per bucket
WARMUP = 3                    # absorbs the per-bucket jit compile
TOLERANCE = 0.30
BURST_MULT = 8                # burst offers this x max_batch flows...
BURST_QUEUE = 4               # ...against a queue_limit of this x


def _raw_flows_per_sec(cfg, params, batch: int, batches: int) -> float:
    """Machine-speed reference: the bare jitted predict dispatch, no
    queue, no padding, no accounting — what the hardware gives."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import mlp_detector

    fn = jax.jit(lambda p, x: mlp_detector.predict(p, x, cfg))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(batch, cfg.num_features))
                    .astype(np.float32))
    fn(params, x).block_until_ready()            # compile
    t0 = time.perf_counter()
    for _ in range(batches):
        out = fn(params, x)
    out.block_until_ready()
    return batch * batches / (time.perf_counter() - t0)


def _bench_burst(cfg, params, bucket: int, batches: int) -> dict:
    """Burst-overload cell (ISSUE 7): each round offers
    ``BURST_MULT x bucket`` flows against a ``BURST_QUEUE x bucket``
    admission limit, so the shed rate is a deterministic property of the
    protocol (not the machine) while p50/p99/flows-per-sec measure the
    engine's latency for the flows it DID accept under overload."""
    import numpy as np

    from repro.faults import BurstSpec
    from repro.serve import ModelSlot, ServeEngine

    burst = BurstSpec(period=1, mult=BURST_MULT)
    limit = BURST_QUEUE * bucket
    engine = ServeEngine(ModelSlot(params, model=cfg.name), cfg,
                         max_batch=bucket, queue_limit=limit)
    rng = np.random.default_rng(2)
    X = rng.normal(size=(burst.size(0, bucket), cfg.num_features)
                   ).astype(np.float32)
    for _ in range(WARMUP):
        engine.submit_many(X, best_effort=True)
        engine.drain()
    engine.reset_stats()
    for _ in range(batches):
        engine.submit_many(X, best_effort=True)
        engine.drain()
    stats = engine.shutdown()
    offered = X.shape[0] * batches
    assert stats.dropped == 0 and stats.errors == 0
    assert stats.submitted + stats.shed == offered
    return {"offered": offered, "accepted": stats.submitted,
            "shed": stats.shed,
            "shed_rate": round(stats.shed / offered, 4),
            "p50_ms": stats.p50_ms, "p99_ms": stats.p99_ms,
            "flows_per_sec": stats.flows_per_sec}


def bench_serve(json_path: str, buckets=DEFAULT_BUCKETS,
                batches: int = DEFAULT_BATCHES,
                check_against: str = None) -> dict:
    import jax
    import numpy as np

    from repro.configs import anomaly_mlp
    from repro.models import api as model_api
    from repro.serve import ModelSlot, ServeEngine

    cfg = anomaly_mlp.CONFIG
    params = model_api.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)

    out = {"config": {"arch": cfg.name, "buckets": sorted(buckets),
                      "batches": batches, "warmup": WARMUP,
                      "burst_mult": BURST_MULT,
                      "burst_queue": BURST_QUEUE}}
    biggest = max(buckets)
    for bucket in sorted(buckets):
        engine = ServeEngine(ModelSlot(params, model=cfg.name), cfg,
                             max_batch=bucket)
        X = rng.normal(size=(bucket, cfg.num_features)).astype(np.float32)
        for _ in range(WARMUP):                  # compile + warm the jit
            engine.submit_many(X)
            engine.drain()
        engine.reset_stats()     # steady state only — same compiled jit
        for _ in range(batches):
            engine.submit_many(X)
            engine.drain()
        stats = engine.shutdown()
        assert stats.dropped == 0 and stats.errors == 0
        b = stats.by_bucket[bucket]
        out[f"bucket_{bucket}"] = {
            "rows": b["rows"], "p50_ms": b["p50_ms"],
            "p99_ms": b["p99_ms"],
            "flows_per_sec": b["flows_per_sec"]}

    out["burst"] = _bench_burst(cfg, params, biggest, batches)
    out["raw"] = {"flows_per_sec": round(
        _raw_flows_per_sec(cfg, params, biggest, batches), 1)}
    out["engine_efficiency"] = round(
        out[f"bucket_{biggest}"]["flows_per_sec"]
        / out["raw"]["flows_per_sec"], 3)

    with open(json_path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(json.dumps(out, indent=2))
    print(f"# wrote {json_path}: " + "; ".join(
        f"bucket {k.split('_')[1]}: "
        f"{out[k]['flows_per_sec']:.0f} flows/s "
        f"(p50 {out[k]['p50_ms']:.2f} ms, p99 {out[k]['p99_ms']:.2f} ms)"
        for k in out if k.startswith("bucket_"))
        + f"; engine efficiency {out['engine_efficiency']:.0%} of the "
        f"raw dispatch rate")
    print(f"# burst overload (x{BURST_MULT} offered, queue "
          f"{BURST_QUEUE}x{biggest}): shed rate "
          f"{out['burst']['shed_rate']:.0%}, accepted flows p99 "
          f"{out['burst']['p99_ms']:.2f} ms at "
          f"{out['burst']['flows_per_sec']:.0f} flows/s")
    if check_against:
        _check_regression(out, check_against)
    return out


def _check_regression(out: dict, committed_path: str,
                      tolerance: float = TOLERANCE) -> None:
    """Fail (exit 1) when any bucket's flows/sec drops >``tolerance``
    below the committed number after machine-speed normalization via the
    raw jitted-dispatch reference (same idiom as ``run.py``'s sim
    guard)."""
    with open(committed_path) as f:
        committed = json.load(f)
    proto = ["arch", "buckets", "batches", "warmup", "burst_mult",
             "burst_queue"]
    mismatch = {k: (out["config"].get(k), committed["config"].get(k))
                for k in proto
                if out["config"].get(k) != committed["config"].get(k)}
    if mismatch:
        raise SystemExit(
            f"serve-bench config mismatch vs {committed_path}: "
            f"{mismatch} — run with the committed protocol "
            f"(--buckets/--batches) to use --check-against")
    scale = (out["raw"]["flows_per_sec"]
             / max(committed["raw"]["flows_per_sec"], 1e-9))
    failures = []
    for key in sorted(k for k in committed if k.startswith("bucket_")):
        if key not in out:
            continue
        floor = (1.0 - tolerance) * committed[key]["flows_per_sec"] * scale
        got = out[key]["flows_per_sec"]
        status = "ok" if got >= floor else "REGRESSION"
        print(f"# serve-guard [{key}] flows/sec={got:.0f} "
              f"floor={floor:.0f} (committed="
              f"{committed[key]['flows_per_sec']:.0f} x machine-scale "
              f"{scale:.2f} x {1 - tolerance:.2f}) {status}")
        if got < floor:
            failures.append(key)
    if "burst" in committed and "burst" in out:
        # the shed rate is protocol-determined — any change means the
        # admission path itself changed, so it must match EXACTLY
        if out["burst"]["shed_rate"] != committed["burst"]["shed_rate"]:
            print(f"# serve-guard [burst] shed_rate="
                  f"{out['burst']['shed_rate']} committed="
                  f"{committed['burst']['shed_rate']} REGRESSION")
            failures.append("burst.shed_rate")
        floor = ((1.0 - tolerance)
                 * committed["burst"]["flows_per_sec"] * scale)
        got = out["burst"]["flows_per_sec"]
        status = "ok" if got >= floor else "REGRESSION"
        print(f"# serve-guard [burst] accepted flows/sec={got:.0f} "
              f"floor={floor:.0f} (p99 {out['burst']['p99_ms']:.2f} ms "
              f"vs committed {committed['burst']['p99_ms']:.2f} ms) "
              f"{status}")
        if got < floor:
            failures.append("burst.flows_per_sec")
    if failures:
        raise SystemExit(
            f"serve-bench regression >{tolerance:.0%} on: {failures} "
            f"(see floors above; refresh BENCH_serve.json only with a "
            f"justified perf change)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_serve.json", metavar="PATH")
    ap.add_argument("--buckets", default=",".join(
        str(b) for b in DEFAULT_BUCKETS),
        help="comma-separated power-of-two batch buckets to time")
    ap.add_argument("--batches", type=int, default=DEFAULT_BATCHES)
    ap.add_argument("--check-against", default=None, metavar="PATH",
                    help="committed BENCH_serve.json to guard against: "
                         "fail if any bucket's flows/sec drops >30%% "
                         "below it (machine-speed normalized via the raw "
                         "jit dispatch reference)")
    args = ap.parse_args(argv)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    bench_serve(args.json, buckets=buckets, batches=args.batches,
                check_against=args.check_against)


if __name__ == "__main__":
    main()
