"""Component ablation — the paper's central claim is that the COMBINATION
matters ("effective communication overhead reduction requires a
multi-faceted approach rather than relying on single optimization
techniques", §V-D). One factor at a time vs all-on vs all-off.
"""
from __future__ import annotations

from benchmarks import common
from repro.api import StrategyConfig


def _cfg(async_=False, theta=None, selection=False, ckpt=False,
         dyn_batch=False):
    return StrategyConfig(
        mode="async" if async_ else "sync", theta=theta,
        selection=selection, select_fraction=0.8 if selection else 1.0,
        dynamic_batch=dyn_batch, checkpointing=ckpt,
        batch_size=64, lr=3e-2, local_epochs=2)


def _all(quantize=False):
    c = _cfg(async_=True, theta=0.65, selection=True, ckpt=True,
             dyn_batch=True)
    c.quantize_updates = quantize
    return c


CASES = [
    ("none (sync fedavg)", _cfg()),
    ("+async only", _cfg(async_=True)),
    ("+filter only", _cfg(theta=0.65)),
    ("+selection only", _cfg(selection=True)),
    ("+ckpt only", _cfg(ckpt=True)),
    ("+dyn-batch only", _cfg(dyn_batch=True)),
    ("all combined", _all()),
    # beyond-paper §VI hybrid: int8+error-feedback on top of everything
    ("all + int8 EF", _all(quantize=True)),
]


def run(rounds=6, dropout=0.2):
    rows = []
    for name, strat in CASES:
        res = common.run(common.UNSW, strat, num_clients=10,
                         rounds=rounds, dropout=dropout)
        m = res.final
        rows.append([name, round(m.accuracy, 3), round(m.sim_time, 1),
                     round(m.idle_time, 1), round(m.bytes_sent / 1e6, 1)])
    combined = next(r for r in rows if r[0] == "all combined")
    singles = [r for r in rows if r[0].startswith("+")]
    best_single_time = min(r[2] for r in singles)
    print(f"# combination beats best single lever on time: "
          f"{combined[2]:.1f}s vs {best_single_time:.1f}s "
          f"(paper §V-D synergy claim)")
    return common.emit(rows, ["components", "accuracy", "sim_time_s",
                              "idle_s", "MB_sent"])


if __name__ == "__main__":
    run()
