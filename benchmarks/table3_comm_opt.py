"""Paper Table III: DDP results — batch size × client selection ×
async/sync, communication time.

Configs mirror the paper's rows: Sync baseline / Sync+selection /
Async+selection at batch 64, and Sync vs Async+selection at 512 / 1024.
The headline claim: Async+selection at batch 1024 cuts end-to-end time by
~97% vs the 64-batch sync baseline while accuracy recovers with longer
training (19 rounds in the paper).
"""
from __future__ import annotations

from benchmarks import common
from repro.api import StrategyConfig


def _strat(mode, theta, selection, bs, lr=3e-2):
    return StrategyConfig(mode=mode, theta=theta, selection=selection,
                          select_fraction=0.8 if selection else 1.0,
                          dynamic_batch=False, checkpointing=False,
                          batch_size=bs, lr=lr)


def run():
    rows = []
    cases = [
        ("sync_baseline", "sync", None, False, 64, 6),
        ("sync+selection", "sync", 0.65, True, 64, 6),
        ("async+selection", "async", 0.65, True, 64, 6),
        ("sync_baseline", "sync", None, False, 512, 6),
        ("async+selection", "async", 0.65, True, 512, 6),
        ("sync_baseline", "sync", None, False, 1024, 6),
        ("async+selection", "async", 0.65, True, 1024, 6),
        # paper: extended training restores accuracy at batch 1024
        ("async+sel(19rnd)", "async", 0.65, True, 1024, 19),
    ]
    for name, mode, theta, sel, bs, rounds in cases:
        res = common.run(common.UNSW, _strat(mode, theta, sel, bs),
                         num_clients=10, rounds=rounds)
        m = res.final
        rows.append([name, bs, rounds, round(m.accuracy, 4),
                     round(m.sim_time, 1), round(m.comm_time, 1),
                     round(m.idle_time, 1),
                     round(m.bytes_sent / 1e6, 1)])
    base = next(r for r in rows if r[0] == "sync_baseline" and r[1] == 64)
    best = next(r for r in rows
                if r[0] == "async+selection" and r[1] == 1024)
    print(f"# end-to-end reduction, async+sel@1024 vs sync@64 (6 rounds "
          f"each): {100 * (1 - best[4] / base[4]):.1f}% "
          f"(paper: 97.6%, 700.0s -> 16.8s)")
    return common.emit(rows, ["config", "batch", "rounds", "accuracy",
                              "sim_time_s", "comm_s", "idle_s", "MB_sent"])


if __name__ == "__main__":
    run()
