"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything (quick)
  PYTHONPATH=src python -m benchmarks.run --only table3_comm_opt
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale repeats
  PYTHONPATH=src python -m benchmarks.run --list     # strategy smoke mode
  PYTHONPATH=src python -m benchmarks.run --bench-json BENCH_sim.json
                                                     # sim-engine perf run

Each module prints a CSV block headed by its paper-table provenance; the
roofline table (deliverable g) is rendered from the dry-run JSONL by
``roofline_report``. ``--list`` instantiates every registered strategy
(no training) — a cheap registry/CI smoke check. ``--bench-json`` times
the fixed 32-client heterogeneous sim config on both execution paths
(reference per-client loop vs compiled cohort megastep) and writes
rounds/sec + dispatches/round so the perf trajectory is tracked in CI.
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

MODULES = [
    "table1_baseline_grid",
    "table2_sota",
    "table3_comm_opt",
    "table4_threshold",
    "table56_profiling",
    "fig3_scaling",
    "fig4_fault_tolerance",
    "table7_mannwhitney",
    "ablation_components",
    "roofline_report",
    "kernel_bench",
]


def list_strategies() -> None:
    """Smoke mode: build every registered strategy without training."""
    import csv
    import sys

    from repro.api import STRATEGY_REGISTRY

    w = csv.writer(sys.stdout)
    w.writerow(["name", "mode", "theta", "selection", "dynamic_batch",
                "checkpointing", "description"])
    for name in sorted(STRATEGY_REGISTRY):
        strat = STRATEGY_REGISTRY[name]
        cfg = strat.build()                    # must not raise
        w.writerow([name, cfg.mode, cfg.theta, cfg.selection,
                    cfg.dynamic_batch, cfg.checkpointing,
                    (strat.description or "").split("\n")[0]])
    print(f"# {len(STRATEGY_REGISTRY)} strategies instantiated OK")


def bench_sim(json_path: str, rounds: int = 20, clients: int = 32,
              warmup: int = 2) -> dict:
    """Sim-engine perf benchmark (ISSUE 2 acceptance metric): the fixed
    ``clients``-client heterogeneous config, timed on BOTH execution
    paths. Reports rounds/sec and compiled dispatches/round; the
    megastep path must hold O(1) dispatches while the reference loop
    pays O(clients).

    The config is the communication-centric FedSGD setting the paper's
    Tables V-VI profile (one local step per client per round,
    ``max_samples_per_round == batch_size``), where per-client dispatch /
    transfer / sync overhead dominates — the effect this benchmark
    exists to track. Compute-bound configs (16 local steps) still gain
    ~2.3x from batched cohort math; see README "Performance". Two warmup
    rounds per path absorb jit compiles (round 1 re-specializes the
    megastep on ``has_ref``)."""
    import json

    from repro.api import DataSpec, ExperimentSpec, WorldSpec, get_strategy
    from repro.core import async_engine as ae

    spec = ExperimentSpec(
        model="anomaly-mlp",
        data=DataSpec(n_samples=20000, eval_samples=2000),
        world=WorldSpec(num_clients=clients, profile="heterogeneous"),
        strategy=get_strategy("ours").build(batch_size=64,
                                            dynamic_batch=False,
                                            max_samples_per_round=64),
        seed=0)
    cfg = spec.resolve_model()
    world = spec.build_world()

    out = {"config": {"model": "anomaly-mlp", "clients": clients,
                      "rounds": rounds, "strategy": "ours",
                      "batch_size": 64, "max_samples_per_round": 64,
                      "local_steps": 1, "profile": "heterogeneous"}}
    for name, megastep in (("loop", False), ("megastep", True)):
        sim = ae.FederatedSimulation(cfg, world.client_arrays,
                                     world.eval_arrays,
                                     spec.resolve_strategy(), world.profiles,
                                     seed=0, megastep=megastep)
        for r in range(warmup):
            sim.run_round(r)
        d0 = sim.dispatches
        t0 = time.perf_counter()
        for r in range(rounds):
            sim.run_round(warmup + r)
        dt = time.perf_counter() - t0
        out[name] = {"seconds": round(dt, 3),
                     "rounds_per_sec": round(rounds / dt, 3),
                     "dispatches_per_round": (sim.dispatches - d0) / rounds}
    out["speedup"] = round(out["megastep"]["rounds_per_sec"]
                           / out["loop"]["rounds_per_sec"], 2)
    with open(json_path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(json.dumps(out, indent=2))
    print(f"# wrote {json_path}: {out['speedup']}x rounds/sec "
          f"({out['loop']['dispatches_per_round']:.1f} -> "
          f"{out['megastep']['dispatches_per_round']:.1f} dispatches/round)")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale repeat counts (slow on CPU)")
    ap.add_argument("--list", action="store_true",
                    help="instantiate every registered strategy and exit")
    ap.add_argument("--bench-json", default=None, metavar="PATH",
                    help="run the sim-engine perf benchmark and write "
                         "rounds/sec + dispatches/round JSON to PATH")
    ap.add_argument("--bench-rounds", type=int, default=20,
                    help="timed rounds for --bench-json (CI uses fewer)")
    ap.add_argument("--bench-clients", type=int, default=32)
    args = ap.parse_args(argv)
    if args.list:
        list_strategies()
        return
    if args.bench_json:
        bench_sim(args.bench_json, rounds=args.bench_rounds,
                  clients=args.bench_clients)
        return
    mods = [args.only] if args.only else MODULES
    failures = []
    for name in mods:
        print(f"\n===== benchmarks.{name} =====")
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            if args.full and name == "fig4_fault_tolerance":
                mod.run(runs=100)
            elif args.full and name == "table7_mannwhitney":
                mod.run(runs=30)
            else:
                mod.run()
            print(f"# [{name}] done in {time.time() - t0:.1f}s")
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
