"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything (quick)
  PYTHONPATH=src python -m benchmarks.run --only table3_comm_opt
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale repeats
  PYTHONPATH=src python -m benchmarks.run --list     # strategy smoke mode

Each module prints a CSV block headed by its paper-table provenance; the
roofline table (deliverable g) is rendered from the dry-run JSONL by
``roofline_report``. ``--list`` instantiates every registered strategy
(no training) — a cheap registry/CI smoke check.
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

MODULES = [
    "table1_baseline_grid",
    "table2_sota",
    "table3_comm_opt",
    "table4_threshold",
    "table56_profiling",
    "fig3_scaling",
    "fig4_fault_tolerance",
    "table7_mannwhitney",
    "ablation_components",
    "roofline_report",
    "kernel_bench",
]


def list_strategies() -> None:
    """Smoke mode: build every registered strategy without training."""
    import csv
    import sys

    from repro.api import STRATEGY_REGISTRY

    w = csv.writer(sys.stdout)
    w.writerow(["name", "mode", "theta", "selection", "dynamic_batch",
                "checkpointing", "description"])
    for name in sorted(STRATEGY_REGISTRY):
        strat = STRATEGY_REGISTRY[name]
        cfg = strat.build()                    # must not raise
        w.writerow([name, cfg.mode, cfg.theta, cfg.selection,
                    cfg.dynamic_batch, cfg.checkpointing,
                    (strat.description or "").split("\n")[0]])
    print(f"# {len(STRATEGY_REGISTRY)} strategies instantiated OK")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale repeat counts (slow on CPU)")
    ap.add_argument("--list", action="store_true",
                    help="instantiate every registered strategy and exit")
    args = ap.parse_args(argv)
    if args.list:
        list_strategies()
        return
    mods = [args.only] if args.only else MODULES
    failures = []
    for name in mods:
        print(f"\n===== benchmarks.{name} =====")
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            if args.full and name == "fig4_fault_tolerance":
                mod.run(runs=100)
            elif args.full and name == "table7_mannwhitney":
                mod.run(runs=30)
            else:
                mod.run()
            print(f"# [{name}] done in {time.time() - t0:.1f}s")
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
