"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything (quick)
  PYTHONPATH=src python -m benchmarks.run --only table3_comm_opt
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale repeats
  PYTHONPATH=src python -m benchmarks.run --list     # strategy smoke mode
  PYTHONPATH=src python -m benchmarks.run --bench-json BENCH_sim.json --sweep
                                                     # sim-engine perf run

Each module prints a CSV block headed by its paper-table provenance; the
roofline table (deliverable g) is rendered from the dry-run JSONL by
``roofline_report``. ``--list`` instantiates every registered strategy
(no training) — a cheap registry/CI smoke check. ``--bench-json`` times
the fixed 32-client heterogeneous sim config on both execution paths
(reference per-client loop vs compiled cohort megastep) and writes
rounds/sec + dispatches/round so the perf trajectory is tracked in CI.
``--sweep`` adds the multi-seed sweep benchmark: the serial per-seed
spmd loop vs run_sweep's ONE vmapped seed-stacked state.
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

MODULES = [
    "table1_baseline_grid",
    "table2_sota",
    "table3_comm_opt",
    "table4_threshold",
    "table56_profiling",
    "fig3_scaling",
    "fig4_fault_tolerance",
    "table7_mannwhitney",
    "ablation_components",
    "roofline_report",
    "kernel_bench",
]


def list_strategies() -> None:
    """Smoke mode: build every registered strategy without training."""
    import csv
    import sys

    from repro.api import STRATEGY_REGISTRY

    w = csv.writer(sys.stdout)
    w.writerow(["name", "mode", "theta", "selection", "dynamic_batch",
                "checkpointing", "description"])
    for name in sorted(STRATEGY_REGISTRY):
        strat = STRATEGY_REGISTRY[name]
        cfg = strat.build()                    # must not raise
        w.writerow([name, cfg.mode, cfg.theta, cfg.selection,
                    cfg.dynamic_batch, cfg.checkpointing,
                    (strat.description or "").split("\n")[0]])
    print(f"# {len(STRATEGY_REGISTRY)} strategies instantiated OK")


SCAN_R = 8          # rounds per dispatch on the scanned control plane
SCENARIO_PRESET = "dynamic"   # the scenario config timed on the scanned
                              # path (drift + churn + link walks + dropout
                              # regimes — core/scenario.py); the world
                              # transitions run INSIDE the lax.scan, so
                              # their overhead must stay <10% of the
                              # static scanned path (ISSUE 5 acceptance)

# multi-seed sweep protocol (--sweep): the Table VII regime — MANY small
# repeated runs — where per-seed dispatch overhead dominates and folding
# the seed axis into the cohort dispatch pays the most
SWEEP_SEEDS = 16
SWEEP_CLIENTS = 4
SWEEP_BATCH = 32
SWEEP_ROUNDS = 50
SWEEP_REPS = 3      # best-of-N timing: the windows are short (dispatch-
                    # bound micro-runs), min over reps kills scheduler noise


def bench_sweep(rounds: int = SWEEP_ROUNDS, seeds: int = SWEEP_SEEDS,
                clients: int = SWEEP_CLIENTS,
                batch_size: int = SWEEP_BATCH,
                reps: int = SWEEP_REPS) -> dict:
    """Multi-seed spmd sweep throughput: the serial per-seed loop vs ONE
    vmapped seed-stacked state (``run_sweep``'s vectorized path,
    ``fl_step.build_seed_batched_step``). Fixed cohort batches reused
    every round (the ``_bench_spmd_engine`` idiom) isolate dispatch +
    compute from host sampling; rounds/sec counts seeds x rounds
    simulated rounds. Both sides share one compiled step build; the
    serial loop still pays one dispatch per seed per round, the vmapped
    path exactly one per round for ALL seeds."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import anomaly_mlp
    from repro.core import fl_step
    from repro.optim import adamw as optim_mod

    cfg = anomaly_mlp.SMOKE
    opt = optim_mod.sgd(5e-3, momentum=0.0)
    rng = np.random.default_rng(0)
    batches = [{"x": jnp.asarray(rng.normal(
                    size=(clients, batch_size, cfg.num_features))
                    .astype(np.float32)),
                "y": jnp.asarray(rng.integers(
                    0, cfg.num_classes, (clients, batch_size)))}
               for _ in range(seeds)]

    step = fl_step.build_fl_train_step(cfg, opt, theta=0.65, donate=False)
    states = [fl_step.init_state(jax.random.PRNGKey(s), cfg, opt)
              for s in range(seeds)]
    for i in range(seeds):                              # compile + warm
        states[i], m = step(states[i], batches[i])
    jax.block_until_ready(m)
    dt_serial = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(rounds):
            for i in range(seeds):
                states[i], m = step(states[i], batches[i])
        jax.block_until_ready(m)
        dt_serial = min(dt_serial, time.perf_counter() - t0)

    vstep = fl_step.build_seed_batched_step(cfg, opt, theta=0.65)
    vstate = fl_step.init_seed_batched_state(range(seeds), cfg, opt)
    vbatch = {k: jnp.stack([b[k] for b in batches]) for k in batches[0]}
    vstate, m = vstep(vstate, vbatch)                   # compile + warm
    jax.block_until_ready(m)
    dt_vmap = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(rounds):
            vstate, m = vstep(vstate, vbatch)
        jax.block_until_ready(m)
        dt_vmap = min(dt_vmap, time.perf_counter() - t0)

    total = seeds * rounds
    out = {
        "serial": {"seconds": round(dt_serial, 3),
                   "rounds_per_sec": round(total / dt_serial, 3),
                   "dispatches_per_round": float(seeds)},
        "vmapped": {"seconds": round(dt_vmap, 3),
                    "rounds_per_sec": round(total / dt_vmap, 3),
                    "dispatches_per_round": 1.0},
        "speedup": round(dt_serial / dt_vmap, 2),
    }
    print(f"# sweep bench ({seeds} seeds x {rounds} rounds, "
          f"{clients} clients, batch {batch_size}): vmapped "
          f"{out['speedup']}x serial rounds/sec")
    return out


def bench_sim(json_path: str, rounds: int = 20, clients: int = 32,
              warmup: int = 2, check_against: str = None,
              sweep: bool = False) -> dict:
    """Sim-engine perf benchmark (ISSUE 2/3 acceptance metric): the fixed
    ``clients``-client heterogeneous config, timed on every execution
    path. Reports rounds/sec and compiled dispatches/round: the
    reference loop pays O(clients) dispatches/round, the per-round
    megastep O(1), the scanned device-control-plane path O(1/R)
    (amortized BELOW one), the fused path (eval folded into the scan
    carry, ``fused_eval=True``) EXACTLY ceil(rounds/R)/rounds — no eval
    dispatches at all — and the compiled spmd engine exactly one
    training dispatch per round.

    The config is the communication-centric FedSGD setting the paper's
    Tables V-VI profile (one local step per client per round,
    ``max_samples_per_round == batch_size``), where per-client dispatch /
    transfer / sync overhead dominates — the effect this benchmark
    exists to track. Compute-bound configs (16 local steps) still gain
    ~2.3x from batched cohort math; see README "Performance". Warmup
    rounds per path absorb jit compiles (round 1 re-specializes the
    megastep on ``has_ref``).

    ``check_against``: path to a committed BENCH JSON — fails (exit 1)
    if any shared path's rounds/sec regresses more than 30% after
    normalizing out machine speed via the reference loop's ratio (CI
    runners and dev boxes differ in absolute speed; the loop path is the
    uncompiled-control baseline both sides measure)."""
    import json

    from repro.api import DataSpec, ExperimentSpec, WorldSpec, get_strategy
    from repro.core import async_engine as ae

    spec = ExperimentSpec(
        model="anomaly-mlp",
        data=DataSpec(n_samples=20000, eval_samples=2000),
        world=WorldSpec(num_clients=clients, profile="heterogeneous"),
        strategy=get_strategy("ours").build(batch_size=64,
                                            dynamic_batch=False,
                                            max_samples_per_round=64),
        seed=0)
    cfg = spec.resolve_model()
    world = spec.build_world()

    out = {"config": {"model": "anomaly-mlp", "clients": clients,
                      "rounds": rounds, "strategy": "ours",
                      "batch_size": 64, "max_samples_per_round": 64,
                      "local_steps": 1, "profile": "heterogeneous",
                      "scan_rounds_per_dispatch": SCAN_R,
                      "scenario": SCENARIO_PRESET,
                      "fused_eval_every": SCAN_R}}
    for name, kwargs in (("loop", dict(megastep=False)),
                         ("megastep", dict(megastep=True)),
                         ("scanned", dict(megastep=True,
                                          rounds_per_dispatch=SCAN_R)),
                         ("scanned_scenario",
                          dict(megastep=True, rounds_per_dispatch=SCAN_R,
                               scenario=SCENARIO_PRESET)),
                         # whole-experiment fusion: eval joins the scan
                         # carry, so the ONLY dispatches are the scans
                         # themselves (no per-chunk host eval readback);
                         # eval_every=SCAN_R matches the post-hoc row's
                         # effective chunk-end cadence — same number of
                         # eval computations, zero extra dispatches
                         ("fused", dict(megastep=True,
                                        rounds_per_dispatch=SCAN_R,
                                        fused_eval=True,
                                        eval_every=SCAN_R))):
        sim = ae.FederatedSimulation(cfg, world.client_arrays,
                                     world.eval_arrays,
                                     spec.resolve_strategy(), world.profiles,
                                     seed=0, **kwargs)
        if kwargs.get("rounds_per_dispatch"):
            # warmup compiles BOTH trace lengths the timed run will use
            # (full R-dispatches plus the remainder-length scan, if any)
            sim.run(SCAN_R + rounds % SCAN_R)
            d0 = sim.dispatches
            t0 = time.perf_counter()
            sim.run(rounds)
            dt = time.perf_counter() - t0
        else:
            for r in range(warmup):
                sim.run_round(r)
            d0 = sim.dispatches
            t0 = time.perf_counter()
            for r in range(rounds):
                sim.run_round(warmup + r)
            dt = time.perf_counter() - t0
        out[name] = {"seconds": round(dt, 3),
                     "rounds_per_sec": round(rounds / dt, 3),
                     "dispatches_per_round": (sim.dispatches - d0) / rounds}

    out["spmd"] = _bench_spmd_engine(rounds, clients)
    if sweep:
        out["config"].update({"sweep_seeds": SWEEP_SEEDS,
                              "sweep_clients": SWEEP_CLIENTS,
                              "sweep_batch": SWEEP_BATCH,
                              "sweep_rounds": SWEEP_ROUNDS})
        out["sweep"] = bench_sweep()
    out["speedup"] = round(out["megastep"]["rounds_per_sec"]
                           / out["loop"]["rounds_per_sec"], 2)
    out["scan_speedup"] = round(out["scanned"]["rounds_per_sec"]
                                / out["loop"]["rounds_per_sec"], 2)
    out["fused_speedup"] = round(out["fused"]["rounds_per_sec"]
                                 / out["loop"]["rounds_per_sec"], 2)
    # dynamic-world cost on the scanned path: static/scenario rounds-per-
    # sec ratio (>1 means the scenario is slower; acceptance bound 1.10)
    out["scenario_overhead"] = round(
        out["scanned"]["rounds_per_sec"]
        / out["scanned_scenario"]["rounds_per_sec"], 3)
    with open(json_path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(json.dumps(out, indent=2))
    print(f"# wrote {json_path}: megastep {out['speedup']}x / scanned "
          f"{out['scan_speedup']}x / fused {out['fused_speedup']}x "
          f"rounds/sec vs loop "
          f"({out['loop']['dispatches_per_round']:.1f} -> "
          f"{out['megastep']['dispatches_per_round']:.1f} -> "
          f"{out['scanned']['dispatches_per_round']:.2f} -> "
          f"{out['fused']['dispatches_per_round']:.2f} dispatches/round); "
          f"'{SCENARIO_PRESET}' scenario overhead "
          f"{out['scenario_overhead']}x on the scanned path")
    if check_against:
        _check_regression(out, check_against)
    return out


def _bench_spmd_engine(rounds: int, clients: int) -> dict:
    """Compiled spmd engine with the device control plane attached
    (sync + θ-filter + adaptive selection): raw step throughput, exactly
    one training dispatch per round by construction."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.api import DataSpec, ExperimentSpec, WorldSpec
    from repro.api.runner import build_spmd_components
    from repro.core.async_engine import StrategyConfig

    st = StrategyConfig(mode="sync", theta=0.65, selection=True,
                        select_fraction=0.5, dynamic_batch=False,
                        checkpointing=False, batch_size=64,
                        max_samples_per_round=64)
    spec = ExperimentSpec(
        model="anomaly-mlp",
        data=DataSpec(n_samples=20000, eval_samples=2000),
        world=WorldSpec(num_clients=clients, profile="heterogeneous"),
        strategy=st, engine="spmd", seed=0)
    world = spec.build_world()
    cfg, st, _opt, state, step = build_spmd_components(spec, world=world)
    rng = np.random.default_rng(0)
    xs = np.stack([c["x"][rng.integers(0, len(c["x"]), 64)]
                   for c in world.client_arrays])
    ys = np.stack([c["y"][rng.integers(0, len(c["y"]), 64)]
                   for c in world.client_arrays])
    batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
    state, m = step(state, batch)                      # compile
    jax.block_until_ready(m)
    t0 = time.perf_counter()
    for _ in range(rounds):
        state, m = step(state, batch)
    jax.block_until_ready(m)
    dt = time.perf_counter() - t0
    return {"seconds": round(dt, 3),
            "rounds_per_sec": round(rounds / dt, 3),
            "dispatches_per_round": 1.0}


def _check_regression(out: dict, committed_path: str,
                      tolerance: float = 0.30) -> None:
    """CI bench-regression guard: compare rounds/sec per path against
    the committed JSON, normalized by the loop path's machine-speed
    ratio; fail on a >``tolerance`` drop."""
    import json

    with open(committed_path) as f:
        committed = json.load(f)
    # the guard is only meaningful under the committed measurement
    # protocol: a different round count changes the scanned path's trace
    # length / eval amortization and a different client count changes
    # every path's work — refuse rather than spuriously pass or fail
    proto = ["clients", "rounds", "batch_size", "max_samples_per_round",
             "scan_rounds_per_dispatch", "scenario"]
    if "sweep" in out and "sweep" in committed:
        proto += ["sweep_seeds", "sweep_clients", "sweep_batch",
                  "sweep_rounds"]
    if "fused" in out and "fused" in committed:
        proto += ["fused_eval_every"]
    mismatch = {k: (out["config"].get(k), committed["config"].get(k))
                for k in proto
                if out["config"].get(k) != committed["config"].get(k)}
    if mismatch:
        raise SystemExit(
            f"bench-guard config mismatch vs {committed_path}: "
            f"{mismatch} — run with the committed protocol "
            f"(--bench-rounds/--bench-clients) to use --check-against")
    scale = (out["loop"]["rounds_per_sec"]
             / max(committed["loop"]["rounds_per_sec"], 1e-9))
    failures = []
    # the ISSUE 5 acceptance bound: world transitions inside the scan
    # must cost <10% of the static scanned path's rounds/sec — a same-
    # machine ratio, so no normalization is needed
    overhead = out.get("scenario_overhead")
    if overhead is not None:
        status = "ok" if overhead <= 1.10 else "REGRESSION"
        print(f"# bench-guard [scenario] scanned overhead x{overhead:.3f} "
              f"(bound x1.10) {status}")
        if overhead > 1.10:
            failures.append("scenario_overhead")
    for path in ("megastep", "scanned", "scanned_scenario", "fused",
                 "spmd"):
        if path not in committed or path not in out:
            continue
        floor = (1.0 - tolerance) * committed[path]["rounds_per_sec"] * scale
        got = out[path]["rounds_per_sec"]
        status = "ok" if got >= floor else "REGRESSION"
        print(f"# bench-guard [{path}] rounds/sec={got:.2f} "
              f"floor={floor:.2f} (committed="
              f"{committed[path]['rounds_per_sec']:.2f} x machine-scale "
              f"{scale:.2f} x {1 - tolerance:.2f}) {status}")
        if got < floor:
            failures.append(path)
    if "sweep" in out and "sweep" in committed:
        # the sweep claim is the vmapped/serial RATIO — both sides are
        # dispatch-bound micro-runs whose absolute rounds/sec doesn't
        # track the loop path's machine scale, but their ratio does not
        # depend on machine speed at all
        floor = (1.0 - tolerance) * committed["sweep"]["speedup"]
        got = out["sweep"]["speedup"]
        status = "ok" if got >= floor else "REGRESSION"
        print(f"# bench-guard [sweep] vmapped/serial speedup={got:.2f} "
              f"floor={floor:.2f} (committed="
              f"{committed['sweep']['speedup']:.2f} x "
              f"{1 - tolerance:.2f}) {status}")
        if got < floor:
            failures.append("sweep")
    if failures:
        raise SystemExit(
            f"bench regression >{tolerance:.0%} on: {failures} "
            f"(see floors above; refresh BENCH_sim.json only with a "
            f"justified perf change)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale repeat counts (slow on CPU)")
    ap.add_argument("--list", action="store_true",
                    help="instantiate every registered strategy and exit")
    ap.add_argument("--bench-json", default=None, metavar="PATH",
                    help="run the sim-engine perf benchmark and write "
                         "rounds/sec + dispatches/round JSON to PATH")
    ap.add_argument("--bench-rounds", type=int, default=20,
                    help="timed rounds for --bench-json (CI uses fewer)")
    ap.add_argument("--bench-clients", type=int, default=32)
    ap.add_argument("--check-against", default=None, metavar="PATH",
                    help="committed BENCH JSON to guard against: fail if "
                         "any path's rounds/sec drops >30%% below it "
                         "(machine-speed normalized via the loop path)")
    ap.add_argument("--sweep", action="store_true",
                    help="time the vectorized (vmapped seed-stacked) vs "
                         "serial multi-seed spmd sweep; with --bench-json "
                         "its numbers join the JSON and the "
                         "--check-against regression guard")
    args = ap.parse_args(argv)
    if args.list:
        list_strategies()
        return
    if args.bench_json:
        bench_sim(args.bench_json, rounds=args.bench_rounds,
                  clients=args.bench_clients,
                  check_against=args.check_against, sweep=args.sweep)
        return
    if args.sweep:
        import json
        print(json.dumps(bench_sweep(), indent=2))
        return
    mods = [args.only] if args.only else MODULES
    failures = []
    for name in mods:
        print(f"\n===== benchmarks.{name} =====")
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            if args.full and name == "fig4_fault_tolerance":
                mod.run(runs=100)
            elif args.full and name == "table7_mannwhitney":
                mod.run(runs=30)
            else:
                mod.run()
            print(f"# [{name}] done in {time.time() - t0:.1f}s")
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
