"""Paper Table II: comparison with state-of-the-art methods.

Ours vs CMFL vs FedL2P (+FedAvg, ACFL) at 10 clients on UNSW-like data:
simulated end-to-end time, accuracy, AUC; plus scalability at 100 clients
and fault tolerance at 0.5 dropout (the paper's Scale*/FT† columns).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import baselines


def run(rounds=5):
    rows = []
    for name in ["ours", "cmfl", "fedl2p", "acfl", "fedavg"]:
        strat = baselines.PRESETS[name](batch_size=64, lr=3e-2, local_epochs=2)
        sim, hist, wall = common.run_sim(common.UNSW, strat, num_clients=10,
                                         rounds=rounds)
        m = hist[-1]
        # scalability: relative accuracy at 100 clients vs 10
        _, hist100, _ = common.run_sim(
            common.UNSW, baselines.PRESETS[name](batch_size=64, lr=3e-2, local_epochs=2),
            num_clients=100, rounds=3, n=30000)
        scale = hist100[-1].accuracy / max(m.accuracy, 1e-9)
        # fault tolerance: accuracy at 0.5 dropout
        _, hist_ft, _ = common.run_sim(
            common.UNSW, baselines.PRESETS[name](batch_size=64, lr=3e-2, local_epochs=2),
            num_clients=10, rounds=rounds, dropout=0.5, seed=2)
        ft = np.mean([h.accuracy for h in hist_ft[-2:]])
        rows.append([name, round(m.sim_time, 1), round(m.accuracy * 100, 2),
                     round(common.auc_of(sim), 3),
                     "Stable" if scale > 0.9 else "Deg.",
                     round(ft * 100, 1)])
    return common.emit(rows, ["method", "time_s", "acc_pct", "auc",
                              "scale_100c", "ft_at_0.5_drop_pct"])


if __name__ == "__main__":
    run()
