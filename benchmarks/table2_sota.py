"""Paper Table II: comparison with state-of-the-art methods.

Ours vs CMFL vs FedL2P (+FedAvg, ACFL) at 10 clients on UNSW-like data:
simulated end-to-end time, accuracy, AUC; plus scalability at 100 clients
and fault tolerance at 0.5 dropout (the paper's Scale*/FT† columns).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common

KW = dict(batch_size=64, lr=3e-2, local_epochs=2)


def run(rounds=5):
    rows = []
    for name in ["ours", "cmfl", "fedl2p", "acfl", "fedavg"]:
        res = common.run(common.UNSW, name, strategy_kwargs=KW,
                         num_clients=10, rounds=rounds)
        m = res.final
        # scalability: relative accuracy at 100 clients vs 10
        res100 = common.run(common.UNSW, name, strategy_kwargs=KW,
                            num_clients=100, rounds=3, n=30000)
        scale = res100.final.accuracy / max(m.accuracy, 1e-9)
        # fault tolerance: accuracy at 0.5 dropout
        res_ft = common.run(common.UNSW, name, strategy_kwargs=KW,
                            num_clients=10, rounds=rounds, dropout=0.5,
                            seed=2)
        ft = np.mean([h.accuracy for h in res_ft.records[-2:]])
        rows.append([name, round(m.sim_time, 1), round(m.accuracy * 100, 2),
                     round(common.auc_of(res), 3),
                     "Stable" if scale > 0.9 else "Deg.",
                     round(ft * 100, 1)])
    return common.emit(rows, ["method", "time_s", "acc_pct", "auc",
                              "scale_100c", "ft_at_0.5_drop_pct"])


if __name__ == "__main__":
    run()
