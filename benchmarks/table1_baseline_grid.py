"""Paper Table I: baseline performance across batch sizes × client counts.

Sync FedAvg at every (clients ∈ {10,50,100}) × (batch ∈ {32,64,128,256});
reports accuracy, AUC-ROC and simulated end-to-end time. Expected trends
(paper §V-C): time falls with batch size, rises with client count;
accuracy degrades at (many clients × large batch).
"""
from __future__ import annotations

from benchmarks import common


def run(clients_list=(10, 50, 100), batches=(32, 64, 128, 256), rounds=3):
    rows = []
    for nc in clients_list:
        for bs in batches:
            res = common.run(common.UNSW, "fedavg",
                             strategy_kwargs=dict(batch_size=bs, lr=3e-2,
                                                  local_epochs=1),
                             num_clients=nc, rounds=rounds,
                             n=4000 * (1 + nc // 25))
            m = res.final
            rows.append([nc, bs, round(m.accuracy, 4),
                         round(common.auc_of(res), 4),
                         round(m.sim_time, 1), round(res.wall_time, 1)])
    return common.emit(rows, ["clients", "batch", "accuracy", "auc_roc",
                              "sim_time_s", "container_wall_s"])


if __name__ == "__main__":
    run()
