"""Deliverable (g): render the roofline table from the dry-run JSONL.

Reads experiments/dryrun_results.jsonl (written by repro.launch.dryrun)
and prints, per (arch × shape × mesh): the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and bytes/device. If the
JSONL is missing (dry-run not yet executed in this container), prints the
command to produce it instead of failing the bench suite.
"""
from __future__ import annotations

import os

from repro.roofline import analysis

RESULTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun_results.jsonl")


def run(path: str = None):
    path = path or os.path.abspath(RESULTS)
    if not os.path.exists(path):
        print(f"# no dry-run results at {path}")
        print("# produce them with: PYTHONPATH=src python -m "
              "repro.launch.dryrun")
        return []
    rows = analysis.load_jsonl(path)
    # keep the LAST row per combo (later rows = re-runs after perf changes)
    latest = {}
    for r in rows:
        latest[(r["arch"], r["shape"], r["mesh"])] = r
    header = ["arch", "shape", "mesh", "t_compute_s", "t_memory_s",
              "t_collective_s", "dominant", "useful_ratio",
              "hlo_gflops_per_dev", "coll_MB_per_dev", "peak_GB_per_dev"]
    print(",".join(header))
    out = []
    for key in sorted(latest):
        r = latest[key]
        peak = (r.get("bytes_per_device") or {}).get("peak_bytes")
        arg = (r.get("bytes_per_device") or {}).get("argument_bytes")
        per_dev_gb = round(((peak or 0) + (arg or 0)) / 1e9, 2)
        row = [r["arch"], r["shape"], r["mesh"],
               f"{r['t_compute']:.3e}", f"{r['t_memory']:.3e}",
               f"{r['t_collective']:.3e}", r["dominant"],
               round(r["useful_ratio"], 3),
               round(r["hlo_flops"] / r["chips"] / 1e9, 1),
               round(r["collective_bytes"] / r["chips"] / 1e6, 1),
               per_dev_gb]
        print(",".join(str(x) for x in row))
        out.append(row)
    doms = {}
    for row in out:
        doms[row[6]] = doms.get(row[6], 0) + 1
    print(f"# dominant-term distribution: {doms}")
    return out


if __name__ == "__main__":
    run()
