"""Paper Table VII: Mann-Whitney U statistical validation.

Per-run AUC-ROC samples of ours vs each baseline on BOTH datasets
(UNSW-like and ROAD-like surrogates); H1: ours stochastically larger.
The paper rejects H0 at α=0.05 for all six comparisons.
"""
from __future__ import annotations

import numpy as np
from scipy.stats import mannwhitneyu

from benchmarks import common


def _auc_samples(cfg, name, runs, rounds=4):
    vals = []
    for r in range(runs):
        res = common.run(cfg, name,
                         strategy_kwargs=dict(batch_size=64, lr=3e-2,
                                              local_epochs=2),
                         num_clients=8, rounds=rounds, dropout=0.3,
                         seed=300 + r, n=8000)
        vals.append(common.auc_of(res))
    return np.array(vals)


def run(runs=10):
    rows = []
    for cfg, ds in [(common.UNSW, "UNSW-like"), (common.ROAD, "ROAD-like")]:
        ours = _auc_samples(cfg, "ours", runs)
        for base in ["cmfl", "acfl", "fedl2p"]:
            them = _auc_samples(cfg, base, runs)
            u, p = mannwhitneyu(ours, them, alternative="greater")
            rows.append([f"ours_vs_{base}", ds, round(float(u), 1),
                         f"{p:.3g}", "reject_H0" if p < 0.05 else "keep_H0",
                         round(float(ours.mean()), 4),
                         round(float(them.mean()), 4)])
    return common.emit(rows, ["comparison", "dataset", "U", "p_value",
                              "alpha_0.05", "ours_auc", "baseline_auc"])


if __name__ == "__main__":
    run()
