"""Paper Table VII: Mann-Whitney U statistical validation.

Per-run AUC-ROC samples of ours vs each baseline on BOTH datasets
(UNSW-like and ROAD-like surrogates); H1: ours stochastically larger.
The paper rejects H0 at α=0.05 for all six comparisons.

Runs as ONE ``run_sweep`` per dataset (strategy × seed cross-product)
and tests with the dependency-free ``repro.api.stats`` U implementation
(pinned to scipy's asymptotic method in tests/test_sweep.py) — the
hand-rolled per-seed loop this module used to carry now lives in the
experiment layer.
"""
from __future__ import annotations

from benchmarks import common
from repro.api import run_sweep


def run(runs=10):
    rows = []
    for cfg, ds in [(common.UNSW, "UNSW-like"), (common.ROAD, "ROAD-like")]:
        base = common.spec_for(cfg, "ours",
                               strategy_kwargs=dict(batch_size=64, lr=3e-2,
                                                    local_epochs=2),
                               num_clients=8, rounds=4, dropout=0.3,
                               n=8000)
        sweep = run_sweep(base, axes={
            "strategy": ["ours", "cmfl", "acfl", "fedl2p"],
            "seed": range(300, 300 + runs)})
        ours_auc = sweep.values("auc", strategy="ours")
        for baseline in ["cmfl", "acfl", "fedl2p"]:
            r = sweep.mann_whitney_u("strategy", "ours", baseline,
                                     metric="auc", alternative="greater")
            them_auc = sweep.values("auc", strategy=baseline)
            rows.append([f"ours_vs_{baseline}", ds, round(float(r.u), 1),
                         f"{r.p_value:.3g}",
                         "reject_H0" if r.significant(0.05) else "keep_H0",
                         round(float(ours_auc.mean()), 4),
                         round(float(them_auc.mean()), 4)])
    return common.emit(rows, ["comparison", "dataset", "U", "p_value",
                              "alpha_0.05", "ours_auc", "baseline_auc"])


if __name__ == "__main__":
    run()
