"""Paper Fig. 3: (left) update frequency per round; (right) communication
time scaling with client count — sync baseline vs optimized framework."""
from __future__ import annotations

from benchmarks import common
from repro.core import baselines


def run(client_counts=(10, 25, 50, 100), rounds=3):
    rows = []
    for nc in client_counts:
        sync_sim, sync_hist, _ = common.run_sim(
            common.UNSW, baselines.fedavg(batch_size=64, lr=3e-2),
            num_clients=nc, rounds=rounds, n=3000 + 300 * nc)
        ours_sim, ours_hist, _ = common.run_sim(
            common.UNSW, baselines.ours(batch_size=64, lr=3e-2,
                                        dynamic_batch=False),
            num_clients=nc, rounds=rounds, n=3000 + 300 * nc)
        sync_updates = sum(h.updates_applied for h in sync_hist) / rounds
        ours_updates = sum(h.updates_applied for h in ours_hist) / rounds
        rows.append([nc,
                     round(sync_updates, 1), round(ours_updates, 1),
                     round(sync_hist[-1].sim_time, 1),
                     round(ours_hist[-1].sim_time, 1)])
    print("# ours: updates/round must GROW with clients; sync stays at 1."
          " time scaling must stay flat-ish for ours (paper Fig. 3)")
    return common.emit(rows, ["clients", "sync_updates_per_round",
                              "ours_updates_per_round", "sync_time_s",
                              "ours_time_s"])


if __name__ == "__main__":
    run()
