"""Paper Fig. 3: (left) update frequency per round; (right) communication
time scaling with client count — sync baseline vs optimized framework."""
from __future__ import annotations

from benchmarks import common


def run(client_counts=(10, 25, 50, 100), rounds=3):
    rows = []
    for nc in client_counts:
        sync = common.run(common.UNSW, "fedavg",
                          strategy_kwargs=dict(batch_size=64, lr=3e-2),
                          num_clients=nc, rounds=rounds, n=3000 + 300 * nc)
        ours = common.run(common.UNSW, "ours",
                          strategy_kwargs=dict(batch_size=64, lr=3e-2,
                                               dynamic_batch=False),
                          num_clients=nc, rounds=rounds, n=3000 + 300 * nc)
        sync_updates = sum(sync.series("updates_applied")) / rounds
        ours_updates = sum(ours.series("updates_applied")) / rounds
        rows.append([nc,
                     round(sync_updates, 1), round(ours_updates, 1),
                     round(sync.final.sim_time, 1),
                     round(ours.final.sim_time, 1)])
    print("# ours: updates/round must GROW with clients; sync stays at 1."
          " time scaling must stay flat-ish for ours (paper Fig. 3)")
    return common.emit(rows, ["clients", "sync_updates_per_round",
                              "ours_updates_per_round", "sync_time_s",
                              "ours_time_s"])


if __name__ == "__main__":
    run()
