"""Paper Fig. 3 + the million-client population scaling curve.

Two modes:

* legacy (no flags): the paper's Fig. 3 — update frequency per round and
  communication-time scaling at 10-100 clients, sync vs ours.
* ``--population``: the 1k → 10k → 100k → 1M POPULATION-ONLY sweep
  behind ``BENCH_scale.json``. Each round is {score → two-stage
  selection → synthetic cohort observations → full control update}
  (core/population.build_population_round) with training held at a
  fixed cohort — isolating the selection+control cost that becomes the
  bottleneck at scale. Per cell it times single-stage (global argsort
  top-k) vs two-stage (sharded candidate pre-filter) rounds, asserts
  ``frac=1.0`` bit-exactness and shard_map parity, and measures the
  lazy-world cohort materialization peak (host memory bounded by cohort
  size, not population). ``--check-against BENCH_scale.json`` is the CI
  regression gate (mirrors benchmarks/run.py): machine-speed normalized
  rounds/sec floors per cell, memory caps, parity flags.

The module top stays stdlib-only ON PURPOSE: ``--host-devices N`` must
set XLA_FLAGS before the first jax import (the launch/dryrun.py
import-order trick), which is how CI's scale-smoke step runs the 1k cell
on 8 forced host devices and genuinely exercises the multi-device
shard_map path.

Usage:
  python -m benchmarks.fig3_scaling                       # paper Fig. 3
  python -m benchmarks.fig3_scaling --population          # full 1k->1M
  python -m benchmarks.fig3_scaling --population \
      --clients 1000 --host-devices 8 \
      --check-against BENCH_scale.json                    # CI smoke cell
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
import tracemalloc

DEFAULT_CLIENTS = (1_000, 10_000, 100_000, 1_000_000)
DEFAULT_ROUNDS = 20
DEFAULT_COHORT = 64
DEFAULT_FRAC = 0.02
DEFAULT_SHARDS = 8
DEFAULT_SAMPLES_PER_CLIENT = 256


# ---------------------------------------------------------------------------
# legacy paper Fig. 3 (unchanged protocol; imports deferred so the
# module top stays jax-free for the --host-devices trick)
# ---------------------------------------------------------------------------

def run(client_counts=(10, 25, 50, 100), rounds=3):
    from benchmarks import common
    rows = []
    for nc in client_counts:
        sync = common.run(common.UNSW, "fedavg",
                          strategy_kwargs=dict(batch_size=64, lr=3e-2),
                          num_clients=nc, rounds=rounds, n=3000 + 300 * nc)
        ours = common.run(common.UNSW, "ours",
                          strategy_kwargs=dict(batch_size=64, lr=3e-2,
                                               dynamic_batch=False),
                          num_clients=nc, rounds=rounds, n=3000 + 300 * nc)
        sync_updates = sum(sync.series("updates_applied")) / rounds
        ours_updates = sum(ours.series("updates_applied")) / rounds
        rows.append([nc,
                     round(sync_updates, 1), round(ours_updates, 1),
                     round(sync.final.sim_time, 1),
                     round(ours.final.sim_time, 1)])
    print("# ours: updates/round must GROW with clients; sync stays at 1."
          " time scaling must stay flat-ish for ours (paper Fig. 3)")
    return common.emit(rows, ["clients", "sync_updates_per_round",
                              "ours_updates_per_round", "sync_time_s",
                              "ours_time_s"])


# ---------------------------------------------------------------------------
# population sweep
# ---------------------------------------------------------------------------

def _seeded_state(n: int):
    """ControlState with non-degenerate statistics so the top-k has
    real structure to rank (fresh init scores are all identical)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import control
    rng = np.random.default_rng(7)
    st = control.init_control(n)
    return st._replace(
        avail=jnp.asarray(rng.uniform(0.2, 1.0, n).astype(np.float32)),
        pass_rate=jnp.asarray(rng.uniform(0.5, 1.0, n).astype(np.float32)),
        round_time=jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32)))


def _time_rounds(round_fn, state, rounds: int):
    """Compiled lax.scan over ``rounds`` population-only rounds; returns
    (ms_per_round, final_state)."""
    import jax
    import jax.numpy as jnp

    def body(st, r):
        st, _cohort = round_fn(st, r)
        return st, ()

    f = jax.jit(lambda st: jax.lax.scan(
        body, st, jnp.arange(rounds, dtype=jnp.int32))[0])
    out = f(state)
    jax.block_until_ready(out)              # compile outside the clock
    t0 = time.perf_counter()
    out = f(state)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    return dt * 1e3 / rounds, out


def _frac1_bitexact(n: int, k: int, shards: int) -> bool:
    """candidate_frac=1.0 must reproduce single-stage selections
    bit-exactly at THIS population size (the engine-level four-path
    assertion lives in tests/harness.assert_candidate_frac_noop)."""
    import numpy as np

    from repro.core import control
    scores = control.score(_seeded_state(n))
    single = np.asarray(control.select_topk_epsilon(scores, k))
    two = np.asarray(control.two_stage_select(
        scores, k, candidate_frac=1.0, candidate_shards=shards))
    return bool((single == two).all())


def _sharded_parity(n: int, k: int, frac: float, rounds: int) -> bool:
    """shard_map (real mesh over every host device) vs single-device
    transitions + selection: bitwise-identical states and cohorts. The
    candidate union depends on the shard count at frac < 1, so the
    logical reference uses candidate_shards = mesh devices."""
    import jax
    import numpy as np

    from repro.core import population
    from repro.launch import mesh as mesh_mod
    mesh = mesh_mod.make_population_mesh()
    ndev = mesh.shape["data"]
    ref_fn = population.build_population_round(n, k, candidate_frac=frac,
                                               candidate_shards=ndev)
    shd_fn = population.build_population_round(n, k, candidate_frac=frac,
                                               mesh=mesh)
    ref_st, shd_st = _seeded_state(n), _seeded_state(n)
    for r in range(rounds):
        r = jax.numpy.int32(r)
        ref_st, ref_cohort = ref_fn(ref_st, r)
        shd_st, shd_cohort = shd_fn(shd_st, r)
        if not (np.asarray(ref_cohort) == np.asarray(shd_cohort)).all():
            return False
        for f in population._FIELDS:
            a = np.asarray(getattr(ref_st, f))
            b = np.asarray(getattr(shd_st, f))
            if not (a == b).all():
                return False
    return True


def _cohort_peak_mb(n: int, cohort: int, samples_per_client: int) -> dict:
    """Materialize 2×cohort distinct clients through a cohort-capacity
    LoaderPool over a non-resident world; the traced peak is the host
    data-memory bound (eviction keeps it at cohort size regardless of
    the population)."""
    from repro.api import DataSpec, ExperimentSpec, WorldSpec
    from repro.data.loader import LoaderPool
    spec = ExperimentSpec(
        data=DataSpec(samples_per_client=samples_per_client,
                      eval_samples=64),
        world=WorldSpec(num_clients=n, resident=False),
        rounds=1).validate()
    world = spec.build_world()
    pool = LoaderPool(world.client_arrays, lambda cid: 64, seed=0,
                      capacity=cohort)
    stride = max(1, n // (2 * cohort))
    cids = [(i * stride) % n for i in range(2 * cohort)]
    tracemalloc.start()
    for cid in cids:
        pool[cid].sample()
    _cur, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return {"cohort_peak_mb": round(peak / 2**20, 2),
            "resident_loaders": pool.resident}


def population_curve(clients=DEFAULT_CLIENTS, rounds=DEFAULT_ROUNDS,
                     cohort=DEFAULT_COHORT, frac=DEFAULT_FRAC,
                     shards=DEFAULT_SHARDS,
                     samples_per_client=DEFAULT_SAMPLES_PER_CLIENT) -> dict:
    import jax

    from repro.core import population
    out = {
        "config": {"rounds": int(rounds), "cohort": int(cohort),
                   "candidate_frac": float(frac),
                   "candidate_shards": int(shards),
                   "samples_per_client": int(samples_per_client),
                   # informational, NOT part of the gate protocol: the
                   # gated timings use logical shards (device-count
                   # independent); parity additionally runs shard_map
                   # over however many devices this host has
                   "host_devices": len(jax.devices())},
        "cells": {},
    }
    for n in clients:
        k = min(int(cohort), int(n))
        single_fn = population.build_population_round(n, k)
        two_fn = population.build_population_round(
            n, k, candidate_frac=frac, candidate_shards=shards)
        state = _seeded_state(n)
        single_ms, _ = _time_rounds(single_fn, state, rounds)
        two_ms, _ = _time_rounds(two_fn, state, rounds)
        cell = {
            "single_stage_ms": round(single_ms, 3),
            "two_stage_ms": round(two_ms, 3),
            "single_stage_rounds_per_sec": round(1e3 / single_ms, 2),
            "two_stage_rounds_per_sec": round(1e3 / two_ms, 2),
            "speedup": round(single_ms / two_ms, 3),
            "frac1_bitexact": _frac1_bitexact(n, k, shards),
            "sharded_parity": _sharded_parity(n, k, frac, rounds=3),
        }
        cell.update(_cohort_peak_mb(n, cohort, samples_per_client))
        out["cells"][str(n)] = cell
        print(f"# {n:>9} clients: single {single_ms:8.3f} ms/round, "
              f"two-stage {two_ms:8.3f} ms/round "
              f"(x{cell['speedup']:.2f}), cohort peak "
              f"{cell['cohort_peak_mb']:.1f} MB, frac1 bit-exact "
              f"{cell['frac1_bitexact']}, sharded parity "
              f"{cell['sharded_parity']}")
    cells = sorted(((int(c), v) for c, v in out["cells"].items()))
    if len(cells) >= 2:
        (n0, c0), (n1, c1) = cells[0], cells[-1]
        span = math.log(n1 / n0)
        out["scaling_exponent"] = {
            "single_stage": round(
                math.log(c1["single_stage_ms"] / c0["single_stage_ms"])
                / span, 3),
            "two_stage": round(
                math.log(c1["two_stage_ms"] / c0["two_stage_ms"])
                / span, 3)}
        print(f"# scaling exponent (ms/round ~ N^e over "
              f"{n0}->{n1}): single "
              f"{out['scaling_exponent']['single_stage']}, two-stage "
              f"{out['scaling_exponent']['two_stage']} "
              f"(< 1.0 = sub-linear)")
    return out


# ---------------------------------------------------------------------------
# hierarchical topology cell (PR 9): flat star vs 3-tier bytes + speed
# ---------------------------------------------------------------------------

TOPOLOGY_ROUNDS = 8
TOPOLOGY_CLIENTS = 24


def topology_cell(rounds=TOPOLOGY_ROUNDS,
                  num_clients=TOPOLOGY_CLIENTS) -> dict:
    """Flat-star vs 3-tier federation on the scanned sim path: identical
    trajectories by construction (topology is an accumulate-and-sync
    measurement layer), so the cell gates three things — the inter-tier
    bytes/round must come in strictly below the flat star at the same
    accuracy, attaching the topology must not perturb any round record,
    and the TopologyState carry must be bit-exact under dispatch
    regrouping (R=4 vs R=1)."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.api import (DataSpec, ExperimentSpec, TierSpec,
                           TopologySpec, WorldSpec)
    from repro.api.runner import build_simulation

    topology = TopologySpec(tiers=(
        TierSpec("edge", fanout=4),
        TierSpec("region", fanout=3, sync_every=2, theta=0.5),
        TierSpec("global", sync_every=4)))
    spec = ExperimentSpec(
        model="anomaly-mlp-smoke",
        data=DataSpec(n_samples=1800, eval_samples=300),
        world=WorldSpec(num_clients=num_clients),
        strategy="ours",
        strategy_kwargs=dict(batch_size=32, dynamic_batch=False),
        rounds=rounds, rounds_per_dispatch=4,
        topology=topology, seed=0).validate()
    flat_spec = dataclasses.replace(spec, topology=None)

    def timed(s):
        build_simulation(s).run(rounds)          # compile pass
        sim = build_simulation(s)
        t0 = time.perf_counter()
        sim.run(rounds)
        return sim, rounds / (time.perf_counter() - t0)

    flat_sim, flat_rps = timed(flat_spec)
    topo_sim, topo_rps = timed(spec)

    # parity flag 1: attaching the topology changed NOTHING downstream
    # (NaN-tolerant: unmeasured accuracy rounds are NaN on both sides)
    def _rec_eq(a, b):
        for fld in dataclasses.fields(a):
            va, vb = getattr(a, fld.name), getattr(b, fld.name)
            if va != va and vb != vb:
                continue
            if va != vb:
                return False
        return True

    unchanged = len(flat_sim.history) == len(topo_sim.history) and all(
        _rec_eq(a, b) for a, b in zip(flat_sim.history, topo_sim.history))
    # parity flag 2: dispatch regrouping keeps the topology carry
    # bit-exact (scanned R=4 above vs R=1 here)
    r1_sim = build_simulation(
        dataclasses.replace(spec, rounds_per_dispatch=1))
    r1_sim.run(rounds)
    scan_bitexact = all(
        bool(jnp.array_equal(a, b))
        for a, b in zip(jax.tree.leaves(topo_sim._topo_state),
                        jax.tree.leaves(r1_sim._topo_state)))

    s = topo_sim.topology_summary()
    cell = {
        "rounds": int(rounds),
        "num_clients": int(num_clients),
        "tiers": s["tiers"],
        "pods": s["pods"],
        "syncs": s["syncs"],
        "flat_rounds_per_sec": round(flat_rps, 2),
        "topo_rounds_per_sec": round(topo_rps, 2),
        "overhead_frac": round(max(0.0, 1.0 - topo_rps / flat_rps), 4),
        "inter_tier_bytes_per_round": round(s["bytes_per_round"], 1),
        "flat_star_bytes_per_round": round(s["flat_star_bytes_per_round"],
                                           1),
        "reduction": round(s["reduction"], 4),
        "final_accuracy": round(float(topo_sim.history[-1].accuracy), 4),
        "trajectory_unchanged": bool(unchanged),
        "scan_bitexact": bool(scan_bitexact),
    }
    print(f"# topology: flat {flat_rps:.2f} rounds/s, 3-tier "
          f"{topo_rps:.2f} rounds/s (overhead "
          f"{100 * cell['overhead_frac']:.1f}%), inter-tier "
          f"{cell['inter_tier_bytes_per_round']:,.0f} B/round vs "
          f"flat-star {cell['flat_star_bytes_per_round']:,.0f} "
          f"(-{100 * cell['reduction']:.1f}%), trajectory unchanged "
          f"{unchanged}, scan bit-exact {scan_bitexact}")
    return cell


def check_topology(got: dict, ref: dict, tolerance: float = 0.30) -> list:
    """The --topology slice of the scale-guard: parity flags must hold,
    inter-tier bytes must stay strictly below the flat star, and the
    topology-attached round rate must not regress >tolerance after
    machine-speed normalization through the flat run."""
    failures = []
    for flag in ("trajectory_unchanged", "scan_bitexact"):
        ok = bool(got.get(flag, False))
        print(f"# scale-guard [topology] {flag}={ok} "
              f"{'ok' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(f"topology:{flag}")
    below = (got["inter_tier_bytes_per_round"]
             < got["flat_star_bytes_per_round"])
    print(f"# scale-guard [topology] inter-tier "
          f"{got['inter_tier_bytes_per_round']:,.0f} B/round < flat-star "
          f"{got['flat_star_bytes_per_round']:,.0f} "
          f"{'ok' if below else 'REGRESSION'}")
    if not below:
        failures.append("topology:bytes_per_round")
    proto = ("rounds", "num_clients")
    if any(got.get(k) != ref.get(k) for k in proto):
        print("# scale-guard [topology] protocol mismatch vs committed "
              "cell — skipping the rounds/sec floor")
        return failures
    scale = got["flat_rounds_per_sec"] / max(ref["flat_rounds_per_sec"],
                                             1e-9)
    floor = (1.0 - tolerance) * ref["topo_rounds_per_sec"] * scale
    rps = got["topo_rounds_per_sec"]
    ok = rps >= floor
    print(f"# scale-guard [topology] rounds/sec={rps:.2f} "
          f"floor={floor:.2f} (committed "
          f"{ref['topo_rounds_per_sec']:.2f} x machine-scale "
          f"{scale:.2f} x {1 - tolerance:.2f}) "
          f"{'ok' if ok else 'REGRESSION'}")
    if not ok:
        failures.append("topology:rounds_per_sec")
    return failures


# ---------------------------------------------------------------------------
# CI regression gate (mirrors benchmarks/run.py::_check_regression)
# ---------------------------------------------------------------------------

def check_against(out: dict, committed_path: str,
                  tolerance: float = 0.30) -> None:
    with open(committed_path) as f:
        committed = json.load(f)
    failures = []
    if "topology" in out:
        failures += check_topology(out["topology"],
                                   committed.get("topology", {}),
                                   tolerance)
    if not out.get("cells"):
        if failures:
            raise SystemExit(f"scale-guard FAILED: {failures}")
        if "topology" in out:
            print("# scale-guard: topology checks ok")
            return
        raise SystemExit("scale-guard: nothing measured to check")
    proto = ["rounds", "cohort", "candidate_frac", "candidate_shards",
             "samples_per_client"]
    mismatch = {k: (out["config"].get(k), committed["config"].get(k))
                for k in proto
                if out["config"].get(k) != committed["config"].get(k)}
    if mismatch:
        raise SystemExit(
            f"scale-guard config mismatch vs {committed_path}: "
            f"{mismatch} — run with the committed protocol to use "
            f"--check-against")
    shared = sorted((int(c) for c in out["cells"]
                     if c in committed["cells"]))
    if not shared:
        raise SystemExit(
            f"scale-guard: no population cell in common with "
            f"{committed_path} (committed "
            f"{sorted(committed['cells'])}, measured "
            f"{sorted(out['cells'])})")
    # machine-speed normalization from the smallest shared cell's
    # single-stage path (the fixed-protocol reference workload)
    ref = str(shared[0])
    scale = (out["cells"][ref]["single_stage_rounds_per_sec"]
             / max(committed["cells"][ref]["single_stage_rounds_per_sec"],
                   1e-9))
    for n in shared:
        got_cell, ref_cell = out["cells"][str(n)], committed["cells"][str(n)]
        floor = (1.0 - tolerance) * ref_cell["two_stage_rounds_per_sec"] \
            * scale
        got = got_cell["two_stage_rounds_per_sec"]
        status = "ok" if got >= floor else "REGRESSION"
        print(f"# scale-guard [{n}] two-stage rounds/sec={got:.2f} "
              f"floor={floor:.2f} (committed="
              f"{ref_cell['two_stage_rounds_per_sec']:.2f} x "
              f"machine-scale {scale:.2f} x {1 - tolerance:.2f}) {status}")
        if got < floor:
            failures.append(f"{n}:rounds_per_sec")
        # cohort memory is machine-speed independent: a population-
        # proportional leak shows up as a blown cap
        cap = ref_cell["cohort_peak_mb"] * (1.0 + tolerance)
        mem = got_cell["cohort_peak_mb"]
        status = "ok" if mem <= cap else "REGRESSION"
        print(f"# scale-guard [{n}] cohort peak {mem:.1f} MB "
              f"(cap {cap:.1f}) {status}")
        if mem > cap:
            failures.append(f"{n}:cohort_peak_mb")
        for flag in ("frac1_bitexact", "sharded_parity"):
            if not got_cell.get(flag, False):
                print(f"# scale-guard [{n}] {flag}=False REGRESSION")
                failures.append(f"{n}:{flag}")
    exp = out.get("scaling_exponent")
    ref_exp = committed.get("scaling_exponent")
    if exp is not None and ref_exp is not None:
        got, cap = exp["two_stage"], min(ref_exp["two_stage"] + 0.15, 1.0)
        status = "ok" if got <= cap else "REGRESSION"
        print(f"# scale-guard [exponent] two-stage e={got:.3f} "
              f"(cap {cap:.3f}, sub-linear < 1.0) {status}")
        if got > cap:
            failures.append("scaling_exponent")
    if failures:
        raise SystemExit(f"scale-guard FAILED: {failures}")
    print("# scale-guard: all checks ok")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--population", action="store_true",
                    help="run the 1k->1M population scaling sweep")
    ap.add_argument("--topology", action="store_true",
                    help="run the flat-vs-3-tier hierarchical topology "
                         "cell (bytes/round + rounds/sec + parity flags)")
    ap.add_argument("--clients", default=None,
                    help="comma-separated population sizes "
                         f"(default {','.join(map(str, DEFAULT_CLIENTS))})")
    ap.add_argument("--rounds", type=int, default=DEFAULT_ROUNDS)
    ap.add_argument("--cohort", type=int, default=DEFAULT_COHORT)
    ap.add_argument("--candidate-frac", type=float, default=DEFAULT_FRAC)
    ap.add_argument("--candidate-shards", type=int, default=DEFAULT_SHARDS)
    ap.add_argument("--samples-per-client", type=int,
                    default=DEFAULT_SAMPLES_PER_CLIENT)
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force N XLA host devices (must act before the "
                         "first jax import — the dryrun trick)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the result JSON here")
    ap.add_argument("--check-against", default=None, metavar="PATH",
                    help="compare against a committed BENCH_scale.json "
                         "and exit non-zero on regression")
    args = ap.parse_args(argv)
    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices} "
            + os.environ.get("XLA_FLAGS", ""))
    if not args.population and not args.topology:
        run()
        return
    out = {}
    if args.population:
        clients = (DEFAULT_CLIENTS if args.clients is None else
                   tuple(int(c) for c in args.clients.split(",")))
        out = population_curve(clients=clients, rounds=args.rounds,
                               cohort=args.cohort,
                               frac=args.candidate_frac,
                               shards=args.candidate_shards,
                               samples_per_client=args.samples_per_client)
    if args.topology:
        out["topology"] = topology_cell()
    if args.out:
        if not args.population and os.path.exists(args.out):
            # topology-only run: update the section in place, keep the
            # committed population cells
            with open(args.out) as f:
                merged = json.load(f)
            merged["topology"] = out["topology"]
            out_blob = merged
        else:
            out_blob = out
        with open(args.out, "w") as f:
            json.dump(out_blob, f, indent=2, sort_keys=False)
            f.write("\n")
        print(f"# wrote {args.out}")
    if args.check_against:
        check_against(out, args.check_against)


if __name__ == "__main__":
    main()
