"""Shared benchmark scaffolding.

All paper-table benchmarks run the SAME simulation engine with the same
synthetic UNSW-NB15 / ROAD surrogates (DESIGN.md §10), differing only in
strategy/profile/scale knobs — mirroring how the paper varies one factor
per table. Timing columns are SIMULATED cluster seconds (the engine's
communication model), not container wall time; the container also reports
real wall time per run for transparency.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import anomaly_mlp
from repro.core import async_engine as ae
from repro.core import baselines
from repro.data import partition, synthetic

# communication model scaled so the sync 10-client baseline lands in the
# paper's hundreds-of-seconds regime (Table I: 450-950 s). t_launch is the
# per-step dispatch overhead that large batches amortize (Tables V-VI).
COMM = ae.CommModel(bandwidth=5e6, latency=0.5, t_sample=2e-3,
                    t_launch=0.25)

UNSW = anomaly_mlp.CONFIG           # 49 features, 10 classes
ROAD = anomaly_mlp.ROAD_CONFIG      # 32-sample CAN windows, binary


def make_world(cfg, num_clients: int, n: int = 20000, seed: int = 0,
               alpha: float = 0.5):
    if cfg.name.endswith("road"):
        X, y = synthetic.make_road_like(seed, n, window=cfg.num_features)
    else:
        X, y = synthetic.make_unsw_like(seed, n, cfg.num_features,
                                        cfg.num_classes)
    parts = partition.dirichlet_partition(y, num_clients, alpha=alpha,
                                          seed=seed)
    clients = [{"x": X[p], "y": y[p]} for p in parts]
    if cfg.name.endswith("road"):
        Xe, ye = synthetic.make_road_like(seed + 1, 4000,
                                          window=cfg.num_features)
    else:
        Xe, ye = synthetic.make_unsw_like(seed + 1, 4000, cfg.num_features,
                                          cfg.num_classes)
    return clients, {"x": Xe, "y": ye}


def run_sim(cfg, strategy, num_clients=10, rounds=6, dropout=0.0, seed=0,
            speed_sigma=0.6, comm=None, n=20000):
    clients, ev = make_world(cfg, num_clients, n=n, seed=seed)
    profiles = ae.heterogeneous_profiles(num_clients, seed=seed + 1,
                                         dropout_p=dropout,
                                         speed_sigma=speed_sigma)
    t0 = time.time()
    sim = ae.FederatedSimulation(cfg, clients, ev, strategy, profiles,
                                 comm=comm or COMM, seed=seed)
    hist = sim.run(rounds)
    wall = time.time() - t0
    return sim, hist, wall


def auc_of(sim) -> float:
    """Binary-ised AUC-ROC on the eval split (attack vs Normal)."""
    import jax
    import jax.numpy as jnp
    from repro.models import mlp_detector
    ev = jax.tree.map(jnp.asarray, sim.eval_arrays)
    probs = mlp_detector.predict(sim.params, ev["x"], sim.cfg)
    scores = 1.0 - probs[:, 0]                     # P(not Normal)
    labels = (ev["y"] != 0).astype(jnp.float32)
    return float(mlp_detector.auc_roc(scores, labels))


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows


STRATS = baselines.PRESETS
