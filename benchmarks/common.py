"""Shared benchmark scaffolding over ``repro.api``.

All paper-table benchmarks run the SAME engines with the same synthetic
UNSW-NB15 / ROAD surrogates (DESIGN.md §10), differing only in spec
knobs — mirroring how the paper varies one factor per table. Timing
columns are SIMULATED cluster seconds (the CommModel), not container
wall time; each run also reports real wall time for transparency.

``make_world`` / ``run_sim`` are DEPRECATED shims kept for external
callers; benchmark scripts now build ``ExperimentSpec``s directly.
"""
from __future__ import annotations

from repro.api import (CommModel, DataSpec, ExperimentSpec, WorldSpec,
                       build_world, run_experiment)
from repro.api.strategies import PRESETS
from repro.configs import anomaly_mlp
from repro.core.scenario import DropoutSchedule, ScenarioSpec
from repro.faults import FaultSpec

# communication model scaled so the sync 10-client baseline lands in the
# paper's hundreds-of-seconds regime (Table I: 450-950 s). t_launch is the
# per-step dispatch overhead that large batches amortize (Tables V-VI).
COMM = CommModel(bandwidth=5e6, latency=0.5, t_sample=2e-3, t_launch=0.25)

UNSW = anomaly_mlp.CONFIG           # 49 features, 10 classes
ROAD = anomaly_mlp.ROAD_CONFIG      # 32-sample CAN windows, binary


# the base profile dropout every fault regime scales from: a regime's
# effective dropout is BASE_DROPOUT x its DropoutSchedule scale, and the
# engines draw failure uniforms independently of the threshold, so a
# scaled schedule reproduces the legacy static dropout_p patterns exactly
BASE_DROPOUT = 0.1


def fault_regime(dropout, seed=0, base=BASE_DROPOUT):
    """Map a Fig.-4 dropout level onto the ISSUE-7 fault machinery:
    ``(FaultSpec, ScenarioSpec)`` where the FaultSpec seeds the regime's
    deterministic fault patterns and the ScenarioSpec's constant
    DropoutSchedule scale makes the world's effective dropout
    ``dropout`` (profile ``dropout_p=base`` x ``dropout/base``)."""
    fault = FaultSpec(seed=seed).validate()
    scenario = ScenarioSpec(dropout=DropoutSchedule(
        boundaries=(), scales=(float(dropout) / base,)))
    return fault, scenario


def spec_for(cfg, strategy, num_clients=10, rounds=6, dropout=0.0, seed=0,
             speed_sigma=0.6, comm=None, n=20000, alpha=0.5,
             strategy_kwargs=None, engine="sim",
             scenario=None) -> ExperimentSpec:
    """The benchmarks' shared spec shape (UNSW/ROAD surrogate world,
    heterogeneous profiles, paper-scaled CommModel). ``scenario`` forwards
    a ``ScenarioSpec`` (or preset name) — dropout REGIMES should ride on
    it via :func:`fault_regime` rather than on a static ``dropout``."""
    return ExperimentSpec(
        model=cfg,
        data=DataSpec(n_samples=n, eval_samples=4000, alpha=alpha),
        world=WorldSpec(num_clients=num_clients, dropout_p=dropout,
                        speed_sigma=speed_sigma),
        comm=comm or COMM, strategy=strategy,
        strategy_kwargs=strategy_kwargs or {}, engine=engine,
        scenario=scenario, rounds=rounds, seed=seed)


def run(cfg, strategy, **kw):
    """run_experiment over the shared benchmark spec shape."""
    return run_experiment(spec_for(cfg, strategy, **kw))


def auc_of(result) -> float:
    """Binary-ised AUC-ROC on the eval split (attack vs Normal).

    Accepts an ``ExperimentResult`` (or any object with .params /
    .eval_arrays / .cfg, e.g. a legacy FederatedSimulation)."""
    from repro.api.result import ExperimentResult

    return ExperimentResult.auc_roc(result)


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows


# ---------------------------------------------------------------------------
# DEPRECATED shims (pre-repro.api call signatures)
# ---------------------------------------------------------------------------

def make_world(cfg, num_clients: int, n: int = 20000, seed: int = 0,
               alpha: float = 0.5):
    """DEPRECATED: use ``ExperimentSpec(...).build_world()``."""
    world = build_world(spec_for(cfg, "fedavg", num_clients=num_clients,
                                 n=n, seed=seed, alpha=alpha))
    return world.client_arrays, world.eval_arrays


def run_sim(cfg, strategy, num_clients=10, rounds=6, dropout=0.0, seed=0,
            speed_sigma=0.6, comm=None, n=20000):
    """DEPRECATED: use ``repro.api.run_experiment``. Returns the legacy
    (sim-like result, history, wall_time) tuple."""
    result = run(cfg, strategy, num_clients=num_clients, rounds=rounds,
                 dropout=dropout, seed=seed, speed_sigma=speed_sigma,
                 comm=comm, n=n)
    return result, result.records, result.wall_time


STRATS = PRESETS
