"""Paper Tables V-VI: profiling vs batch size — the TPU analogue.

The paper profiles CUDA with Nsight (NVTX ranges, cudaLaunchKernel /
cudaMemcpyAsync / cudaStreamSync counts falling ~90% from batch 64→1024).
Our analogue: compile the LOCAL CLIENT training step per batch size and
census the optimized HLO — instruction count, collective ops, loop-aware
FLOPs/traffic, plus measured CPU step time. Expected trend: per-sample
op density and launch count fall as batch grows (the paper's core
profiling insight).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.models import api
from repro.optim import adamw as optim_mod
from repro.roofline import hlo_census


def dispatch_census(rounds=8, clients=5, scan_r=4):
    """The launch-count half of the paper's profiling story, per
    execution path: compiled dispatches per simulated round (the TPU
    analogue of the cudaLaunchKernel census). The reference loop pays
    O(clients), the megastep O(1), the scanned path 1/R plus a host
    eval per dispatch chunk, and whole-experiment fusion
    (``fused_eval``) exactly 1/R — eval rides the scan carry, so the
    dispatch stream never breaks until the run ends."""
    import dataclasses

    from repro.api import (DataSpec, ExperimentSession, ExperimentSpec,
                           WorldSpec)

    base = ExperimentSpec(
        model="anomaly-mlp-smoke",
        data=DataSpec(n_samples=1200, eval_samples=300, partition="iid"),
        world=WorldSpec(num_clients=clients, profile="heterogeneous"),
        rounds=rounds, seed=0)
    paths = (
        ("loop", dict(megastep=False)),
        ("megastep", dict(megastep=True)),
        ("scanned", dict(megastep=True, rounds_per_dispatch=scan_r)),
        ("fused", dict(megastep=True, rounds_per_dispatch=scan_r,
                       fused_eval=True)),
    )
    rows = []
    for name, kw in paths:
        sess = ExperimentSession.open(dataclasses.replace(base, **kw))
        sess.run(rounds)
        d = sess._driver.sim.dispatches
        rows.append([name, d, round(d / rounds, 3)])
    print(f"# compiled dispatches per round, {clients} clients x "
          f"{rounds} rounds (scan R={scan_r}): the launch-count trend "
          "the paper measures with Nsight — fusion ends at 1/R")
    return common.emit(rows, ["path", "dispatches", "dispatches_per_round"])


def run(batches=(64, 128, 256, 512, 1024), steps=5):
    cfg = common.UNSW
    opt = optim_mod.sgd(1e-2)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    rows = []
    for bs in batches:
        def one_step(p, s, batch):
            loss, g = jax.value_and_grad(
                lambda q: api.loss_fn(q, batch, cfg))(p)
            p2, s2 = opt.update(g, s, p)
            return p2, s2, loss

        x = jnp.zeros((bs, cfg.num_features), jnp.float32)
        y = jnp.zeros((bs,), jnp.int32)
        jitted = jax.jit(one_step)
        compiled = jitted.lower(params, opt_state,
                                {"x": x, "y": y}).compile()
        census = hlo_census.analyze(compiled.as_text())
        # measured wall time per step (jitted, after warmup)
        batch = {"x": jnp.asarray(np.random.randn(bs, cfg.num_features),
                                  jnp.float32),
                 "y": jnp.zeros((bs,), jnp.int32)}
        p, s = params, opt_state
        p, s, _ = jitted(p, s, batch)
        jax.block_until_ready(p)
        t0 = time.time()
        for _ in range(steps):
            p, s, _ = jitted(p, s, batch)
        jax.block_until_ready(p)
        dt = (time.time() - t0) / steps
        rows.append([bs, census["total_instructions"],
                     round(census["flops"] / 1e6, 2),
                     round(census["traffic_bytes"] / 1e6, 2),
                     round(census["flops"] / bs, 0),
                     round(dt * 1e3, 2),
                     round(dt * 1e6 / bs, 2)])
    print("# per-sample instruction/flop density must FALL with batch size"
          " (paper Table V-VI trend)")
    out = common.emit(rows, ["batch", "hlo_instructions", "MFLOPs",
                             "traffic_MB", "flops_per_sample",
                             "step_ms", "us_per_sample"])
    dispatch_census()
    return out


if __name__ == "__main__":
    run()
