"""Checkpoint I/O roundtrips + Weibull adaptive-interval policy (§IV-C),
plus the ISSUE-7 integrity layer: content digests, corruption detection
and verified fallback recovery."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import io
from repro.checkpoint.io import CheckpointCorruptError
from repro.checkpoint.manager import CheckpointManager
from repro.core import checkpoint_policy as cp


def test_io_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16),
                  "d": jnp.asarray(7, jnp.int32)}}
    path = str(tmp_path / "ckpt.msgpack")
    io.save(path, tree)
    back = io.restore(path, jax.tree.map(jnp.zeros_like, tree))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_io_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "c.msgpack")
    io.save(path, {"a": jnp.ones((3,))})
    with pytest.raises(ValueError):
        io.restore(path, {"a": jnp.ones((4,))})


class TestCorruption:
    """Satellite (c): every corruption mode raises
    ``CheckpointCorruptError`` naming the path, never pickle/msgpack
    garbage; ``verify`` is the matching non-raising probe."""

    def _saved(self, tmp_path):
        tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)}
        path = str(tmp_path / "c.msgpack")
        io.save(path, tree)
        return path, tree

    def test_truncated_file(self, tmp_path):
        path, tree = self._saved(tmp_path)
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size // 2)
        with pytest.raises(CheckpointCorruptError, match="c.msgpack"):
            io.restore(path, tree)
        assert not io.verify(path)

    def test_bit_flipped_payload(self, tmp_path):
        path, tree = self._saved(tmp_path)
        with open(path, "r+b") as f:
            f.seek(os.path.getsize(path) - 8)
            c = f.read(1)
            f.seek(os.path.getsize(path) - 8)
            f.write(bytes([c[0] ^ 0x01]))
        with pytest.raises(CheckpointCorruptError) as ei:
            io.restore(path, tree)
        assert ei.value.path == path
        assert not io.verify(path)

    def test_digest_mismatch_names_path(self, tmp_path):
        """A stale digest over a valid body is still rejected — the
        envelope's sha256 must match the bytes actually present."""
        import msgpack
        path, tree = self._saved(tmp_path)
        with open(path, "rb") as f:
            outer = msgpack.unpackb(f.read(), raw=False)
        outer["sha256"] = "0" * 64
        with open(path, "wb") as f:
            f.write(msgpack.packb(outer, use_bin_type=True))
        with pytest.raises(CheckpointCorruptError, match="digest mismatch"):
            io.restore(path, tree)
        assert not io.verify(path)

    def test_not_an_envelope(self, tmp_path):
        import msgpack
        path = str(tmp_path / "junk.msgpack")
        with open(path, "wb") as f:
            f.write(msgpack.packb({"something": "else"},
                                  use_bin_type=True))
        with pytest.raises(CheckpointCorruptError, match="envelope"):
            io.restore(path, {"w": jnp.ones((2,))})

    def test_legacy_pre_digest_checkpoint_still_restores(self, tmp_path):
        """A v1 bare-payload file (what the repo wrote before ISSUE 7)
        has no digest to verify but must keep restoring."""
        import msgpack
        tree = {"w": jnp.ones((2, 2), jnp.float32)}
        leaves, treedef = jax.tree.flatten(tree)
        legacy = {"treedef": str(treedef),
                  "leaves": [{"dtype": "float32", "shape": [2, 2],
                              "data": np.asarray(leaves[0]).tobytes()}]}
        path = str(tmp_path / "v1.msgpack")
        with open(path, "wb") as f:
            f.write(msgpack.packb(legacy, use_bin_type=True))
        back = io.restore(path, jax.tree.map(jnp.zeros_like, tree))
        np.testing.assert_array_equal(np.asarray(back["w"]), 1.0)
        assert io.verify(path)

    def test_verify_missing_file_false(self, tmp_path):
        assert not io.verify(str(tmp_path / "never.msgpack"))

    def test_manager_latest_good_and_fallback_bit_identical(self, tmp_path):
        """Corrupting the canonical artifact degrades restore to the
        newest verified history copy with byte-identical leaves."""
        mgr = CheckpointManager(str(tmp_path), keep=3)
        rng = np.random.default_rng(0)
        tree = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)}
        mgr.save(tree, now=0.0)
        with open(mgr.path(), "r+b") as f:
            f.seek(20)
            c = f.read(1)
            f.seek(20)
            f.write(bytes([c[0] ^ 0xFF]))
        with pytest.raises(CheckpointCorruptError):
            mgr.restore(jax.tree.map(jnp.zeros_like, tree))
        good = mgr.latest_good()
        assert good is not None and good != mgr.path()
        back = mgr.restore(jax.tree.map(jnp.zeros_like, tree),
                           fallback=True)
        assert np.asarray(back["w"]).tobytes() \
            == np.asarray(tree["w"]).tobytes()


def test_weibull_cdf_properties():
    assert cp.weibull_cdf(0.0, 10.0, 1.5) == 0.0
    assert 0.999 < cp.weibull_cdf(1e6, 10.0, 1.5) <= 1.0
    t = np.linspace(0.1, 50, 100)
    f = cp.weibull_cdf(t, 10.0, 1.5)
    assert np.all(np.diff(f) >= 0), "CDF must be monotone"


def test_interval_shrinks_with_failure_rate():
    """Higher failure rate (smaller λ) -> checkpoint more often."""
    t_stable = cp.optimal_interval(1000.0, 5.0, lam=10000.0, k=1.2)
    t_flaky = cp.optimal_interval(1000.0, 5.0, lam=20.0, k=1.2)
    assert t_flaky < t_stable


def test_interval_grows_with_write_cost():
    """Expensive checkpoint writes -> amortize over longer intervals."""
    t_cheap = cp.optimal_interval(1000.0, 5.0, lam=50.0, k=1.2,
                                  write_cost=0.1)
    t_costly = cp.optimal_interval(1000.0, 5.0, lam=50.0, k=1.2,
                                   write_cost=10.0)
    assert t_costly > t_cheap


def test_interval_young_daly_form():
    """With exponential failures the optimum ~ sqrt(2·t_w·MTBF)."""
    lam, tw = 100.0, 0.5
    t = cp.optimal_interval(10000.0, 5.0, lam=lam, k=1.0, write_cost=tw)
    expected = (2 * tw * lam) ** 0.5
    assert 0.7 * expected < t < 1.4 * expected


@settings(max_examples=15, deadline=None)
@given(st.floats(1.0, 200.0), st.floats(0.5, 3.0), st.integers(5, 60),
       st.integers(0, 2 ** 31 - 1))
def test_weibull_fit_recovers_scale(lam, k, n, seed):
    rng = np.random.default_rng(seed)
    samples = lam * rng.weibull(k, size=n * 10)
    lam_hat, k_hat = cp.fit_weibull(samples)
    # loose recovery bounds (MLE over a grid of k)
    assert 0.4 * lam < lam_hat < 2.5 * lam
    assert 0.3 * k < k_hat < 3.0 * k


def test_fit_weibull_degenerate_inputs():
    lam, k = cp.fit_weibull([])
    assert lam > 1e8          # "no failures" -> effectively never checkpoint
    lam1, _ = cp.fit_weibull([5.0])
    assert lam1 == 5.0


def test_manager_adapts_interval(tmp_path):
    mgr = CheckpointManager(str(tmp_path), total_time=1000.0,
                            recovery_time=5.0)
    before = mgr.interval
    for t in np.cumsum(np.full(20, 3.0)):   # failures every 3s
        mgr.record_failure(float(t))
    assert mgr.interval < before
    tree = {"w": jnp.ones((3,))}
    assert mgr.maybe_save(tree, now=0.0)
    assert not mgr.maybe_save(tree, now=mgr.interval * 0.1)
    assert mgr.maybe_save(tree, now=mgr.interval * 1.1)
    back = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(back["w"]), 1.0)
    assert os.path.exists(mgr.path())
