"""Sharding-rule validation on an AbstractMesh of the production shape
(no devices needed): every spec axis must divide its dim."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import registry
from repro.configs.shapes import SHAPES
from repro.launch import sharding
from repro.models import api
from repro.optim import adamw as optim_mod

# jax 0.4.37 AbstractMesh takes ((name, size), ...) pairs
SINGLE = AbstractMesh((("data", 16), ("model", 16)))
MULTI = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _check_divisible(shapes_tree, specs_tree, mesh, where=""):
    flat_s = jax.tree.leaves(shapes_tree)
    flat_p = jax.tree.leaves(
        specs_tree, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p), where
    for sds, spec in zip(flat_s, flat_p):
        ents = tuple(spec)
        assert len(ents) <= len(sds.shape), (where, sds.shape, spec)
        for dim, entry in zip(sds.shape, ents):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (where, sds.shape, spec)


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("arch", registry.ASSIGNED_ARCHS)
def test_param_specs_divide(arch, mesh):
    cfg = registry.get_config(arch)
    shapes = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0),
                                                    cfg))
    specs = sharding.param_pspecs(cfg, mesh)
    _check_divisible(shapes, specs, mesh, where=arch)


@pytest.mark.parametrize("arch", registry.ASSIGNED_ARCHS)
def test_state_specs_divide(arch):
    cfg = registry.get_config(arch)
    opt = optim_mod.for_config(cfg)
    from repro.core import fl_step
    state_shapes = jax.eval_shape(
        lambda: fl_step.init_state(jax.random.PRNGKey(0), cfg, opt))
    specs = sharding.state_pspecs(cfg, SINGLE, opt)
    _check_divisible(state_shapes, specs, SINGLE, where=arch)


@pytest.mark.parametrize("arch", registry.ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_divide(arch, shape_name):
    if shape_name == "long_500k" and arch in registry.LONG_CTX_SKIP:
        pytest.skip("skipped by design")
    cfg = registry.config_for_shape(arch, shape_name)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        specs = api.input_specs(cfg, shape, num_clients=16)
        pspecs = sharding.train_batch_pspecs(cfg, SINGLE, specs["batch"])
        _check_divisible(specs["batch"], pspecs, SINGLE,
                         where=f"{arch}/{shape_name}")
    elif shape.kind == "prefill":
        specs = api.input_specs(cfg, shape)
        pspecs = sharding.infer_batch_pspecs(SINGLE, specs["batch"])
        _check_divisible(specs["batch"], pspecs, SINGLE,
                         where=f"{arch}/{shape_name}")
    else:
        specs = api.input_specs(cfg, shape)
        cspecs = sharding.cache_pspecs(cfg, SINGLE, specs["cache"])
        _check_divisible(specs["cache"], cspecs, SINGLE,
                         where=f"{arch}/{shape_name}")


def test_expert_parallel_only_for_arctic():
    for arch in registry.ASSIGNED_ARCHS:
        cfg = registry.get_config(arch)
        if arch == "arctic-480b":
            assert cfg.expert_parallel and cfg.client_axes == ("pod",)
        else:
            assert not cfg.expert_parallel


def test_arctic_expert_sharding():
    cfg = registry.get_config("arctic-480b")
    specs = sharding.param_pspecs(cfg, SINGLE)
    wg = specs["layers"]["moe"]["wg"]       # (L, E, d, ff)
    assert tuple(wg) == (None, "data", None, "model")
    wd = specs["layers"]["moe"]["wd"]       # (L, E, ff, d)
    assert tuple(wd) == (None, "data", "model", None)


def test_sharded_step_runs_on_debug_mesh():
    """The sharded lowering path executes on a 1-device mesh."""
    from repro.core import fl_step
    from repro.launch import mesh as mesh_mod
    import numpy as np
    cfg = registry.get_config("qwen2-1.5b", smoke=True)
    mesh = mesh_mod.make_debug_mesh()
    opt = optim_mod.for_config(cfg)
    state = fl_step.init_state(jax.random.PRNGKey(0), cfg, opt)
    sspec = sharding.state_pspecs(cfg, mesh, opt)
    batch = {
        "tokens": jnp.zeros((2, 2, 16), jnp.int32),
        "labels": jnp.zeros((2, 2, 16), jnp.int32),
    }
    bspec = sharding.train_batch_pspecs(cfg, mesh, jax.eval_shape(
        lambda: batch))
    step = jax.jit(fl_step.make_raw_step(cfg, opt, theta=0.65),
                   in_shardings=(sharding.to_named(mesh, sspec),
                                 sharding.to_named(mesh, bspec)),
                   out_shardings=(sharding.to_named(mesh, sspec), None))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
