"""End-to-end behaviour tests for the paper's system.

The headline claims, validated at CPU scale on synthetic surrogates:
  1. the combined framework (async + θ-filter + selection + checkpointing)
     cuts end-to-end time AND transmitted bytes vs the sync baseline
     while keeping accuracy comparable (Table II / III);
  2. fault tolerance: under dropout, ours degrades less than sync FedAvg
     (Fig. 4);
  3. the production mesh step trains a real LM federatedly;
  4. the beyond-paper int8 update-compression path roundtrips.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import anomaly_mlp, registry
from repro.core import async_engine as ae
from repro.core import baselines, fl_step
from repro.data import partition, synthetic
from repro.optim import adamw as optim_mod

CFG = anomaly_mlp.CONFIG.replace(mlp_hidden=(64, 32), num_features=20,
                                 num_classes=5)


def _world(n_clients, seed=0, n=3000):
    X, y = synthetic.make_unsw_like(seed, n, CFG.num_features, CFG.num_classes)
    parts = partition.dirichlet_partition(y, n_clients, alpha=0.5, seed=seed)
    clients = [{"x": X[p], "y": y[p]} for p in parts]
    Xe, ye = synthetic.make_unsw_like(seed + 1, 800, CFG.num_features,
                                      CFG.num_classes)
    return clients, {"x": Xe, "y": ye}


def test_combined_framework_beats_sync_baseline():
    clients, ev = _world(8)
    profiles = ae.heterogeneous_profiles(8, seed=4, speed_sigma=1.0)
    comm = ae.CommModel(bandwidth=2e7, latency=0.05, t_sample=5e-5)

    sync = ae.FederatedSimulation(
        CFG, clients, ev, baselines.fedavg(batch_size=64, lr=3e-2, local_epochs=2),
        profiles, comm=comm, seed=0).run(8)
    ours = ae.FederatedSimulation(
        CFG, clients, ev, baselines.ours(batch_size=64, lr=3e-2, local_epochs=2,
                                         dynamic_batch=False),
        profiles, comm=comm, seed=0).run(8)

    assert ours[-1].sim_time < sync[-1].sim_time, "async must beat barrier"
    assert ours[-1].bytes_sent <= sync[-1].bytes_sent, "filter must save bytes"
    assert ours[-1].accuracy > sync[-1].accuracy - 0.10, \
        "accuracy must stay comparable"


def test_fault_tolerance_ordering():
    """At 0.5 dropout: ours (checkpointing) >= sync fedavg (no ckpt)."""
    accs = {}
    for name, strat in [("ours", baselines.ours(batch_size=64, lr=3e-2, local_epochs=2,
                                                dynamic_batch=False)),
                        ("fedavg", baselines.fedavg(batch_size=64, lr=3e-2,
                                                    local_epochs=2))]:
        clients, ev = _world(8, seed=11)
        profiles = ae.uniform_profiles(8, dropout_p=0.5)
        sim = ae.FederatedSimulation(CFG, clients, ev, strat, profiles,
                                     seed=3)
        accs[name] = np.mean([m.accuracy for m in sim.run(6)[-3:]])
    assert accs["ours"] >= accs["fedavg"] - 0.05


def test_production_step_trains_tiny_lm():
    cfg = registry.get_config("qwen2-1.5b", smoke=True).replace(
        num_layers=2, vocab_size=256)
    opt = optim_mod.adamw(3e-3)
    state = fl_step.init_state(jax.random.PRNGKey(0), cfg, opt)
    step = fl_step.build_fl_train_step(cfg, opt, theta=0.55, donate=False)
    t, l = synthetic.make_lm_tokens(0, 8, 32, cfg.vocab_size)
    batch = {"tokens": jnp.asarray(t.reshape(4, 2, 32)),
             "labels": jnp.asarray(l.reshape(4, 2, 32))}
    losses = []
    for _ in range(10):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], "LM must overfit a fixed batch"


def test_quantized_communication_path():
    """Beyond-paper int8 update compression roundtrips within tolerance."""
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    tree = {"w": jax.random.normal(key, (300,)) * 0.01,
            "b": jax.random.normal(jax.random.fold_in(key, 1), (7, 13))}
    q, s, n = ops.quantize_tree(tree)
    assert q.dtype == jnp.int8
    back = ops.dequantize_tree(q, s, tree)
    # per-element error bounded by half the (row-wise) scale; leaves share
    # lane rows, so bound by the max scale across the flattened matrix
    bound = float(np.max(np.asarray(s))) * 0.51 + 1e-9
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        err = np.abs(np.asarray(a) - np.asarray(b)).max()
        assert err <= bound
