"""Synthetic data generators + non-IID partitioning."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data import loader, partition, synthetic


def test_unsw_like_shapes_and_imbalance():
    X, y = synthetic.make_unsw_like(0, 5000)
    assert X.shape == (5000, 49) and y.shape == (5000,)
    assert X.dtype == np.float32
    counts = np.bincount(y, minlength=10)
    assert counts[0] > counts[1:].max(), "Normal must be the majority class"
    assert np.all(np.abs(X.mean(0)) < 0.1)      # standardized


def test_road_like_attack_separability():
    X, y = synthetic.make_road_like(0, 4000, window=32)
    assert X.shape == (4000, 32)
    assert 0.1 < y.mean() < 0.4
    # injected flat segments reduce within-window variance on raw signal;
    # check attacks are at least statistically distinguishable
    v_norm = X[y == 0].std(1).mean()
    v_att = X[y == 1].std(1).mean()
    assert abs(v_norm - v_att) > 0.01


def test_lm_tokens():
    t, l = synthetic.make_lm_tokens(0, 4, 32, 100)
    assert t.shape == (4, 32) and l.shape == (4, 32)
    np.testing.assert_array_equal(t[:, 1:], l[:, :-1])
    assert t.max() < 100 and t.min() >= 0


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 12), st.floats(0.05, 5.0), st.integers(0, 10 ** 6))
def test_dirichlet_partition_covers_everyone(nc, alpha, seed):
    _, y = synthetic.make_unsw_like(seed % 100, 2000)
    parts = partition.dirichlet_partition(y, nc, alpha=alpha, seed=seed)
    assert len(parts) == nc
    for p in parts:
        assert len(p) >= 8                      # floor guarantee
    all_idx = np.concatenate(parts)
    assert len(np.unique(all_idx)) >= 0.95 * len(y)


def test_dirichlet_skew_increases_with_small_alpha():
    _, y = synthetic.make_unsw_like(0, 6000)

    def skew(alpha):
        parts = partition.dirichlet_partition(y, 8, alpha=alpha, seed=0)
        dists = []
        for p in parts:
            c = np.bincount(y[p], minlength=10).astype(float)
            dists.append(c / c.sum())
        return np.std(np.array(dists), axis=0).mean()

    assert skew(0.1) > skew(10.0)


def test_loader_epoch_and_dynamic_batch():
    X = np.arange(100, dtype=np.float32).reshape(100, 1)
    y = np.zeros(100, dtype=np.int32)
    ld = loader.ArrayLoader({"x": X, "y": y}, batch_size=32, seed=0)
    batches = list(ld.epoch())
    assert len(batches) == 3                    # drop_last
    assert all(b["x"].shape == (32, 1) for b in batches)
    ld.set_batch_size(8)
    assert len(list(ld.epoch())) == 12
    s = ld.sample()
    assert s["x"].shape == (8, 1)
