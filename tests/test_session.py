"""ExperimentSession: streaming, callbacks, and bit-exact
checkpoint/resume on both engines (including the scanned
rounds_per_dispatch path and quantized error-feedback state)."""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.api import (CheckpointMismatchError, DataSpec, ExperimentSession,
                       ExperimentSpec, StrategyConfig, WorldSpec,
                       get_strategy, run_experiment)
from repro.api import session as session_mod
from repro.checkpoint.io import CheckpointCorruptError

SMALL = dict(model="anomaly-mlp-smoke",
             data=DataSpec(n_samples=1500, eval_samples=300),
             rounds=6, seed=0)


def _sim_spec(**kw):
    """Full-feature sim spec: selection + dropout + θ + dynamic batch +
    checkpointing — every piece of engine state a resume must restore."""
    base = dict(SMALL,
                world=WorldSpec(num_clients=5, profile="heterogeneous",
                                dropout_p=0.25),
                strategy=get_strategy("ours").build(batch_size=32,
                                                    select_fraction=0.8))
    return ExperimentSpec(**{**base, **kw})


def _spmd_spec(**kw):
    st = StrategyConfig(mode="sync", theta=0.65, selection=True,
                        select_fraction=0.5, dynamic_batch=False,
                        checkpointing=False, batch_size=32, lr=3e-2,
                        max_samples_per_round=64)
    base = dict(SMALL, engine="spmd", strategy=st,
                world=WorldSpec(num_clients=4, profile="heterogeneous",
                                dropout_p=0.2))
    return ExperimentSpec(**{**base, **kw})


def _assert_records_equal(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        fx, fy = dataclasses.astuple(x), dataclasses.astuple(y)
        # exact equality, NaN-tolerant (pre-first-eval scanned rounds)
        np.testing.assert_equal(fx, fy)


def _assert_params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def _resume_case(spec, k, tmp_path, total=None):
    total = total or spec.rounds
    full = ExperimentSession.open(spec)
    full.run(total)

    part = ExperimentSession.open(spec)
    part.run(k)
    path = str(tmp_path / "session.ckpt")
    part.checkpoint(path)

    resumed = ExperimentSession.restore(path)
    assert resumed.rounds_done == k
    resumed.run(total - k)
    _assert_records_equal(full.records, resumed.records)
    _assert_params_equal(full.result().params, resumed.result().params)


# ---------------------------------------------------------------------------
# bit-exact resume: engine x execution path
# ---------------------------------------------------------------------------

def test_resume_bit_exact_sim_megastep(tmp_path):
    _resume_case(_sim_spec(), k=3, tmp_path=tmp_path)


def test_resume_bit_exact_sim_loop(tmp_path):
    _resume_case(_sim_spec(megastep=False), k=3, tmp_path=tmp_path)


def test_resume_bit_exact_sim_scanned_r4(tmp_path):
    # checkpoint at a dispatch boundary: records (incl. the amortized
    # per-dispatch accuracy samples) match the uninterrupted run exactly
    _resume_case(_sim_spec(rounds_per_dispatch=4, rounds=8), k=4,
                 tmp_path=tmp_path)


def test_resume_bit_exact_sim_quantized(tmp_path):
    # int8 + error-feedback arenas are part of the serialized state
    _resume_case(ExperimentSpec(
        **SMALL, world=WorldSpec(num_clients=4, profile="uniform"),
        strategy=get_strategy("ours").build(batch_size=32,
                                            dynamic_batch=False,
                                            quantize_updates=True)),
        k=3, tmp_path=tmp_path)


def test_resume_bit_exact_spmd(tmp_path):
    _resume_case(_spmd_spec(), k=3, tmp_path=tmp_path)


# ---------------------------------------------------------------------------
# dynamic-world scenarios: checkpoint/restore mid-drift (WorldState is
# part of the serialized engine state on every path)
# ---------------------------------------------------------------------------

def test_resume_bit_exact_sim_scenario_megastep(tmp_path):
    # checkpoint at k=3 of 6: the drift amplitude is mid-ramp, the link
    # walk mid-trajectory and the churn roster mid-rotation — a resumed
    # run must replay the identical world
    _resume_case(_sim_spec(scenario="dynamic"), k=3, tmp_path=tmp_path)


def test_resume_bit_exact_sim_scenario_loop(tmp_path):
    _resume_case(_sim_spec(scenario="dynamic", megastep=False), k=3,
                 tmp_path=tmp_path)


def test_resume_bit_exact_sim_scenario_scanned_r4(tmp_path):
    # WorldState rides in the lax.scan carry; a dispatch-boundary
    # checkpoint must hand the exact carry back to the next dispatch
    _resume_case(_sim_spec(scenario="dynamic", rounds_per_dispatch=4,
                           rounds=8), k=4, tmp_path=tmp_path)


def test_resume_bit_exact_spmd_scenario(tmp_path):
    # FLState.world serializes through the driver state_dict
    _resume_case(_spmd_spec(scenario="dynamic"), k=3, tmp_path=tmp_path)


def test_restore_scenario_mismatch_raises(tmp_path):
    spec = _sim_spec(scenario="dynamic", rounds=2)
    s = ExperimentSession.open(spec)
    s.run(2)
    path = str(tmp_path / "scn.ckpt")
    s.checkpoint(path)
    with pytest.raises(CheckpointMismatchError, match="scenario"):
        ExperimentSession.restore(
            path, dataclasses.replace(spec, scenario="drift"))
    with pytest.raises(CheckpointMismatchError, match="scenario"):
        ExperimentSession.restore(
            path, dataclasses.replace(spec, scenario=None))


def test_resume_scanned_midchunk_trajectory(tmp_path):
    """Checkpointing INSIDE a dispatch group (k not a multiple of R):
    the trajectory — every scan-computed field and the final params —
    is still bit-identical (per-round keys fold from the absolute round
    index); only the accuracy SAMPLING points may shift, because eval
    is amortized once per dispatch."""
    spec = _sim_spec(rounds_per_dispatch=4, rounds=8)
    full = ExperimentSession.open(spec)
    full.run(8)
    part = ExperimentSession.open(spec)
    part.run(3)                                   # mid-dispatch
    path = str(tmp_path / "mid.ckpt")
    part.checkpoint(path)
    resumed = ExperimentSession.restore(path)
    resumed.run(5)
    for a, b in zip(full.records, resumed.records):
        for f in ("round", "sim_time", "comm_time", "idle_time",
                  "bytes_sent", "updates_applied", "accept_rate", "loss"):
            assert getattr(a, f) == getattr(b, f), f
    _assert_params_equal(full.result().params, resumed.result().params)


# ---------------------------------------------------------------------------
# restore validation
# ---------------------------------------------------------------------------

def test_restore_mismatched_spec_raises(tmp_path):
    spec = _sim_spec(rounds=2)
    s = ExperimentSession.open(spec)
    s.run(2)
    path = str(tmp_path / "m.ckpt")
    s.checkpoint(path)
    with pytest.raises(CheckpointMismatchError, match="seed"):
        ExperimentSession.restore(path, dataclasses.replace(spec, seed=7))
    with pytest.raises(CheckpointMismatchError, match="engine"):
        ExperimentSession.restore(
            path, _spmd_spec(rounds=2, seed=0))
    # a different round BUDGET is not a mismatch (sessions extend runs)
    resumed = ExperimentSession.restore(
        path, dataclasses.replace(spec, rounds=5))
    resumed.run(3)
    assert resumed.rounds_done == 5


def test_checkpoint_is_atomic_and_restorable_without_spec(tmp_path):
    spec = _sim_spec(rounds=2)
    s = ExperimentSession.open(spec)
    s.run(1)
    path = str(tmp_path / "a.ckpt")
    s.checkpoint(path)
    assert os.path.exists(path) and not os.path.exists(path + ".tmp")
    # plain specs are embedded: restore() needs no spec argument
    assert ExperimentSession.restore(path).rounds_done == 1


# ---------------------------------------------------------------------------
# corruption detection + verified fallback (ISSUE 7 satellite)
# ---------------------------------------------------------------------------

def _corruption_case(tmp_path, spec):
    """Two checkpoints, newest corrupted: restore must refuse it by
    name and ``fallback=True`` must recover the older verified one
    bit-identically."""
    s = ExperimentSession.open(spec)
    s.run(1)
    old = str(tmp_path / "old.ckpt")
    s.checkpoint(old)
    params_at_1 = jax.tree.map(np.asarray, s.result().params)
    s.run(1)
    new = str(tmp_path / "new.ckpt")
    s.checkpoint(new)

    with open(new, "r+b") as f:               # bit-flip the newest
        f.seek(100)
        c = f.read(1)
        f.seek(100)
        f.write(bytes([c[0] ^ 0xFF]))
    with pytest.raises(CheckpointCorruptError, match="new.ckpt"):
        ExperimentSession.restore(new)
    assert session_mod.latest_good_checkpoint(str(tmp_path)) == old

    resumed = ExperimentSession.restore(new, fallback=True)
    assert resumed.rounds_done == 1           # recovered from old.ckpt
    for x, y in zip(jax.tree.leaves(params_at_1),
                    jax.tree.leaves(resumed.result().params)):
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes()


def test_corrupt_restore_falls_back_bit_identical_sim(tmp_path):
    _corruption_case(tmp_path, _sim_spec(rounds=2))


def test_corrupt_restore_falls_back_bit_identical_spmd(tmp_path):
    _corruption_case(tmp_path, _spmd_spec(rounds=2))


def test_corrupt_modes_all_named(tmp_path):
    """Truncation, sidecar stripping and a stale sidecar digest each
    raise ``CheckpointCorruptError`` pointing at the artifact — pickle
    never sees untrusted bytes."""
    import json
    import shutil

    spec = _sim_spec(rounds=1)
    s = ExperimentSession.open(spec)
    s.run(1)
    path = str(tmp_path / "base.ckpt")
    s.checkpoint(path)
    meta = session_mod.read_sidecar(path)
    assert meta["sha256"] and meta["payload_bytes"] == \
        os.path.getsize(path)

    trunc = str(tmp_path / "trunc.ckpt")
    shutil.copyfile(path, trunc)
    shutil.copyfile(session_mod.sidecar_path(path),
                    session_mod.sidecar_path(trunc))
    with open(trunc, "r+b") as f:
        f.truncate(os.path.getsize(trunc) // 2)
    with pytest.raises(CheckpointCorruptError, match="trunc.ckpt"):
        ExperimentSession.restore(trunc)

    orphan = str(tmp_path / "orphan.ckpt")
    shutil.copyfile(path, orphan)             # no sidecar copied
    with pytest.raises(CheckpointCorruptError, match="sidecar"):
        ExperimentSession.restore(orphan)

    stale = str(tmp_path / "stale.ckpt")
    shutil.copyfile(path, stale)
    bad = dict(meta, sha256="0" * 64)
    with open(session_mod.sidecar_path(stale), "w") as f:
        json.dump(bad, f)
    with pytest.raises(CheckpointCorruptError, match="digest mismatch"):
        ExperimentSession.restore(stale)

    # the intact original still restores (and the corrupt variants are
    # exactly what latest_good_checkpoint must skip)
    assert session_mod.latest_good_checkpoint(str(tmp_path)) == path
    assert ExperimentSession.restore(path).rounds_done == 1


# ---------------------------------------------------------------------------
# streaming + callbacks
# ---------------------------------------------------------------------------

def test_stream_yields_rounds_in_order():
    s = ExperimentSession.open(_sim_spec(rounds=4))
    rounds = [r.round for r in s.stream(4)]
    assert rounds == [0, 1, 2, 3]
    assert s.rounds_done == 4


def test_iter_runs_spec_budget():
    s = ExperimentSession.open(_sim_spec(rounds=3))
    assert len(list(s)) == 3


def test_callback_early_stop():
    s = ExperimentSession.open(_sim_spec(rounds=6))
    seen = []

    def stop_after_two(rec):
        seen.append(rec.round)
        if rec.round >= 1:
            return False                      # early-stop hook

    s.add_callback(stop_after_two)
    list(s.stream(6))
    assert s.stopped
    assert s.rounds_done == 2 and seen == [0, 1]
    assert s.run(4) == []                     # stopped sessions stay put


def test_run_then_more_rounds_continues_numbering():
    s = ExperimentSession.open(_sim_spec(rounds=4))
    s.run(2)
    more = s.run(2)
    assert [r.round for r in more] == [2, 3]
    assert [r.round for r in s.records] == [0, 1, 2, 3]


def test_run_experiment_is_session_wrapper():
    spec = _sim_spec(rounds=3)
    res = run_experiment(spec)
    sess = ExperimentSession.open(spec)
    sess.run(3)
    _assert_records_equal(res.records, sess.result().records)
    _assert_params_equal(res.params, sess.result().params)
