"""core/hierarchy.py pod-sync unit tests, pinned to a seeded host oracle.

The hierarchical (cross-pod) selective synchronization was only
import-covered before: these tests pin (i) the ``sync_every`` gating of
``maybe_pod_sync``'s lax.cond, (ii) the bootstrap/fallback acceptance
rules, and (iii) the sign-alignment cross-pod VETO — a pod whose
aggregate movement disagrees with the global direction is excluded from
the cross-pod mean — against a pure-numpy reimplementation fed the same
seeded trajectories (f32-vs-f64 tolerance only).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hierarchy

P = 3                       # pods
SHAPES = {"w": (4, 2), "b": (3,)}


def _tree(fn):
    return {k: fn(s) for k, s in SHAPES.items()}


def _pod_tree(rng, scale=1.0):
    return {k: jnp.asarray(rng.normal(scale=scale,
                                      size=(P,) + s).astype(np.float32))
            for k, s in SHAPES.items()}


def _np_tree(tree):
    return {k: np.asarray(v) for k, v in tree.items()}


# ---------------------------------------------------------------------------
# host oracle (numpy twin of maybe_pod_sync's do_sync branch)
# ---------------------------------------------------------------------------

def oracle_sync(pod_params, last_global, ref_sign, has_ref, theta):
    deltas = {k: pod_params[k] - last_global[k][None] for k in pod_params}
    total = sum(np.prod(s) for s in SHAPES.values())
    aligned = np.zeros(P)
    for k in deltas:
        eq = (np.sign(deltas[k]).astype(np.int8)
              == ref_sign[k][None]).reshape(P, -1)
        aligned += eq.sum(axis=1)
    ratios = aligned / total
    passed = (ratios >= theta).astype(np.float32)
    mask = passed if (passed.sum() > 0 and has_ref) \
        else np.ones(P, np.float32)
    denom = max(mask.sum(), 1e-9)
    agg = {k: np.tensordot(mask, deltas[k], axes=(0, 0)) / denom
           for k in deltas}
    new_global = {k: last_global[k] + agg[k] for k in agg}
    new_ref = {k: np.sign(agg[k]).astype(np.int8) for k in agg}
    metrics = {"synced": 1.0, "pod_accept": float(mask.mean()),
               "pod_alignment": float(ratios.mean())}
    return new_global, new_ref, mask, metrics


# ---------------------------------------------------------------------------
# sync_every gating
# ---------------------------------------------------------------------------

def test_sync_every_gating_and_counter_reset():
    rng = np.random.default_rng(0)
    pod = _pod_tree(rng)
    state = hierarchy.init_pod_sync(jax.tree.map(lambda x: x[0], pod))
    synced, counts = [], []
    for _ in range(7):
        pod, state, m = hierarchy.maybe_pod_sync(pod, state,
                                                 sync_every=3, theta=0.6)
        synced.append(int(m["synced"]))
        counts.append(int(state.rounds_since_sync))
        # drift the pods between calls so syncs have real deltas
        pod = jax.tree.map(
            lambda x: x + jnp.asarray(
                rng.normal(scale=0.1, size=x.shape).astype(np.float32)),
            pod)
    assert synced == [0, 0, 1, 0, 0, 1, 0]
    assert counts == [1, 2, 0, 1, 2, 0, 1]


def test_off_rounds_leave_params_untouched():
    rng = np.random.default_rng(1)
    pod = _pod_tree(rng)
    state = hierarchy.init_pod_sync(jax.tree.map(lambda x: x[0], pod))
    new_pod, state, m = hierarchy.maybe_pod_sync(pod, state,
                                                 sync_every=5, theta=0.6)
    assert m["synced"] == 0.0
    for a, b in zip(jax.tree.leaves(new_pod), jax.tree.leaves(pod)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# bootstrap: first due sync has no reference -> accept all, broadcast
# ---------------------------------------------------------------------------

def test_first_sync_accepts_all_pods_and_broadcasts_mean():
    rng = np.random.default_rng(2)
    pod = _pod_tree(rng)
    g0 = jax.tree.map(lambda x: x[0] * 0.0, pod)    # zeros global
    state = hierarchy.init_pod_sync(g0)
    new_pod, state, m = hierarchy.maybe_pod_sync(pod, state,
                                                 sync_every=1, theta=0.6)
    assert m["synced"] == 1.0 and m["pod_accept"] == 1.0
    for k in SHAPES:
        mean = np.asarray(pod[k]).mean(axis=0)
        got = np.asarray(new_pod[k])
        for p in range(P):
            np.testing.assert_allclose(got[p], mean, rtol=1e-5,
                                       atol=1e-6)
        np.testing.assert_allclose(np.asarray(state.last_global[k]),
                                   mean, rtol=1e-5, atol=1e-6)
    assert int(state.rounds_since_sync) == 0


# ---------------------------------------------------------------------------
# the cross-pod veto, pinned to the host oracle
# ---------------------------------------------------------------------------

def _establish_ref(seed=3, step=0.5):
    """One bootstrap sync (+step movement -> ref_sign = +1). The sync
    sets ``has_ref``, so the veto is armed IMMEDIATELY — no off-round
    needed, even at sync_every=1 (the counter-based ``no_ref`` rule this
    replaced could only arm the veto with sync_every >= 2)."""
    base = _tree(lambda s: jnp.ones(s, jnp.float32))
    state = hierarchy.init_pod_sync(base)
    pod = {k: jnp.stack([base[k] + step * (i + 1) for i in range(P)])
           for k in SHAPES}
    pod, state, m = hierarchy.maybe_pod_sync(pod, state, sync_every=1,
                                             theta=0.6)
    assert m["synced"] == 1.0 and bool(state.has_ref)
    return pod, state


def test_anti_aligned_pod_is_vetoed_matching_oracle():
    pod, state = _establish_ref()
    # pods 0/1 keep moving WITH the global direction; pod 2 moves
    # against it — the sign-alignment test must exclude pod 2. This sync
    # runs at sync_every=1, the cadence where the old counter-based
    # ``no_ref`` rule silently disarmed the veto.
    moved = {k: pod[k].at[0].add(0.3).at[1].add(0.2).at[2].add(-0.4)
             for k in SHAPES}
    exp_global, exp_ref, exp_mask, exp_m = oracle_sync(
        _np_tree(moved), _np_tree(state.last_global),
        _np_tree(state.global_ref_sign), bool(state.has_ref),
        theta=0.6)
    np.testing.assert_array_equal(exp_mask, [1.0, 1.0, 0.0])  # the veto
    new_pod, new_state, m = hierarchy.maybe_pod_sync(
        moved, state, sync_every=1, theta=0.6)
    assert m["synced"] == 1.0
    np.testing.assert_allclose(float(m["pod_accept"]),
                               exp_m["pod_accept"], rtol=1e-6)
    np.testing.assert_allclose(float(m["pod_alignment"]),
                               exp_m["pod_alignment"], rtol=1e-5)
    for k in SHAPES:
        np.testing.assert_allclose(np.asarray(new_state.last_global[k]),
                                   exp_global[k], rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(
            np.asarray(new_state.global_ref_sign[k]), exp_ref[k])
        for p in range(P):
            np.testing.assert_allclose(np.asarray(new_pod[k])[p],
                                       exp_global[k], rtol=1e-5,
                                       atol=1e-6)


def test_all_pods_vetoed_falls_back_to_accept_all():
    pod, state = _establish_ref()
    moved = {k: pod[k] - 0.3 for k in SHAPES}       # everyone anti-aligned
    exp_global, _ref, exp_mask, exp_m = oracle_sync(
        _np_tree(moved), _np_tree(state.last_global),
        _np_tree(state.global_ref_sign), bool(state.has_ref),
        theta=0.6)
    np.testing.assert_array_equal(exp_mask, np.ones(P))
    _pod, new_state, m = hierarchy.maybe_pod_sync(moved, state,
                                                  sync_every=1, theta=0.6)
    assert m["synced"] == 1.0 and float(m["pod_accept"]) == 1.0
    assert float(m["pod_alignment"]) < 0.6          # genuinely misaligned
    for k in SHAPES:
        np.testing.assert_allclose(np.asarray(new_state.last_global[k]),
                                   exp_global[k], rtol=1e-5, atol=1e-6)


def test_seeded_trajectory_matches_oracle():
    """A 6-call random walk (syncs every 2nd call) replayed against the
    oracle: states, params and metrics agree at every sync."""
    rng = np.random.default_rng(4)
    base = _tree(lambda s: jnp.zeros(s, jnp.float32))
    state = hierarchy.init_pod_sync(base)
    pod = {k: jnp.zeros((P,) + s, jnp.float32) for k, s in SHAPES.items()}
    np_global = _np_tree(state.last_global)
    np_ref = _np_tree(state.global_ref_sign)
    count = 0
    has_ref = False
    for step in range(6):
        pod = jax.tree.map(
            lambda x: x + jnp.asarray(
                rng.normal(scale=0.2, size=x.shape).astype(np.float32)),
            pod)
        due = (count + 1) >= 2
        if due:
            np_global, np_ref, _mask, exp_m = oracle_sync(
                _np_tree(pod), np_global, np_ref, has_ref, theta=0.55)
            has_ref = True
        pod, state, m = hierarchy.maybe_pod_sync(pod, state,
                                                 sync_every=2, theta=0.55)
        if due:
            count = 0
            assert m["synced"] == 1.0
            np.testing.assert_allclose(float(m["pod_accept"]),
                                       exp_m["pod_accept"], rtol=1e-6)
            for k in SHAPES:
                np.testing.assert_allclose(
                    np.asarray(state.last_global[k]), np_global[k],
                    rtol=1e-4, atol=1e-5)
                np.testing.assert_array_equal(
                    np.asarray(state.global_ref_sign[k]), np_ref[k])
        else:
            count += 1
            assert m["synced"] == 0.0
