"""Property tests for the paper's core mechanism (Algorithm 1):
sign-alignment relevance + selective aggregation invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import aggregation, alignment


def _tree(key, sizes):
    return {f"w{i}": jax.random.normal(jax.random.fold_in(key, i), (s,))
            for i, s in enumerate(sizes)}


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.lists(st.integers(1, 64),
                                             min_size=1, max_size=5))
def test_ratio_bounds(seed, sizes):
    key = jax.random.PRNGKey(seed)
    t = _tree(key, sizes)
    ref = alignment.tree_sign(_tree(jax.random.fold_in(key, 99), sizes))
    r = float(alignment.alignment_ratio(t, ref))
    assert 0.0 <= r <= 1.0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_self_alignment_is_one(seed):
    key = jax.random.PRNGKey(seed)
    t = _tree(key, [33, 17])
    # exclude exact zeros (measure-zero for continuous draws anyway)
    r = float(alignment.alignment_ratio(t, alignment.tree_sign(t)))
    assert r == 1.0


def test_negated_alignment_is_zero():
    t = {"w": jnp.array([1.0, -2.0, 3.0])}
    ref = alignment.tree_sign({"w": jnp.array([-1.0, 2.0, -3.0])})
    assert float(alignment.alignment_ratio(t, ref)) == 0.0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8))
def test_mask_monotone_in_theta(seed, C):
    key = jax.random.PRNGKey(seed)
    ratios = jax.random.uniform(key, (C,))
    prev = None
    for theta in (0.1, 0.3, 0.5, 0.7, 0.9):
        m = alignment.selection_mask(ratios, theta)
        if prev is not None:
            assert float((m <= prev).all()), "mask must shrink as theta grows"
        prev = m


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 6))
def test_all_ones_mask_equals_fedavg(seed, C):
    key = jax.random.PRNGKey(seed)
    stacked = {"w": jax.random.normal(key, (C, 13)),
               "b": jax.random.normal(jax.random.fold_in(key, 1), (C, 4, 3))}
    ones = jnp.ones((C,), jnp.float32)
    a = aggregation.masked_mean(stacked, ones)
    b = aggregation.fedavg(stacked)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


def test_masked_mean_excludes_filtered_clients():
    stacked = {"w": jnp.array([[1.0], [100.0], [3.0]])}
    mask = jnp.array([1.0, 0.0, 1.0])
    out = aggregation.masked_mean(stacked, mask)
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0], rtol=1e-6)


def test_empty_mask_returns_zero_update():
    stacked = {"w": jnp.ones((3, 5))}
    out = aggregation.masked_mean(stacked, jnp.zeros((3,)))
    assert float(jnp.abs(out["w"]).max()) < 1e-5


def test_per_client_matches_scalar_path():
    key = jax.random.PRNGKey(7)
    C = 5
    stacked = {"a": jax.random.normal(key, (C, 21)),
               "b": jax.random.normal(jax.random.fold_in(key, 1), (C, 3, 9))}
    ref = alignment.tree_sign(
        {"a": jax.random.normal(jax.random.fold_in(key, 2), (21,)),
         "b": jax.random.normal(jax.random.fold_in(key, 3), (3, 9))})
    vec = alignment.per_client_alignment(stacked, ref)
    for i in range(C):
        one = jax.tree.map(lambda x, i=i: x[i], stacked)
        np.testing.assert_allclose(
            float(vec[i]), float(alignment.alignment_ratio(one, ref)),
            rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.0, 50.0))
def test_staleness_weight_decreasing(tau):
    a0 = float(aggregation.staleness_weight(tau))
    a1 = float(aggregation.staleness_weight(tau + 1.0))
    assert a1 < a0 <= 0.6 + 1e-6
    assert a1 > 0.0


def test_async_update_convex_combination():
    g = {"w": jnp.zeros((4,))}
    c = {"w": jnp.ones((4,))}
    out = aggregation.apply_async_update(g, c, 0.25)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.25, rtol=1e-6)
