"""Loop-aware HLO analyzer: trip-count detection, dot flops, collectives."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline import hlo_census


def _compiled(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_flat_dot_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _compiled(lambda x, y: x @ y, a, b)
    res = hlo_census.analyze(c.as_text())
    assert res["flops"] == 2 * 64 * 128 * 32


def test_scan_multiplies_by_trip_count():
    L, D = 7, 32

    def f(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((4, D), jnp.float32)
    c = _compiled(f, ws, x)
    res = hlo_census.analyze(c.as_text())
    expected = L * 2 * 4 * D * D
    assert abs(res["flops"] - expected) / expected < 0.01, \
        (res["flops"], expected, res["while_trips"])
    assert L in res["while_trips"].values()
    # XLA's own cost analysis counts the body once -> analyzer must exceed it
    # (jax 0.4.x returns a one-dict list; 0.5+ returns the dict)
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    xla_flops = float(ca.get("flops", 0.0))
    assert res["flops"] > xla_flops


def test_nested_scan_trips_multiply():
    Lo, Li, D = 3, 5, 16

    def f(ws, x):
        def outer(h, w):
            def inner(hh, _):
                return jnp.tanh(hh @ w), None
            h2, _ = jax.lax.scan(inner, h, None, length=Li)
            return h2, None
        h, _ = jax.lax.scan(outer, x, ws)
        return h

    ws = jax.ShapeDtypeStruct((Lo, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((2, D), jnp.float32)
    c = _compiled(f, ws, x)
    res = hlo_census.analyze(c.as_text())
    expected = Lo * Li * 2 * 2 * D * D
    assert abs(res["flops"] - expected) / expected < 0.02, \
        (res["flops"], expected, res["while_trips"])


def test_traffic_positive_and_bounded():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = _compiled(lambda x: (x @ x).sum(), a)
    res = hlo_census.analyze(c.as_text())
    assert res["traffic_bytes"] >= 256 * 256 * 4       # at least the input
    assert res["traffic_bytes"] < 100 * 256 * 256 * 4  # sane upper bound


def test_shape_bytes_parser():
    from repro.roofline.hlo_census import _shape_elems_bytes
    e, b = _shape_elems_bytes("f32[128,1024]{1,0}")
    assert e == 128 * 1024 and b == 4 * e
    e, b = _shape_elems_bytes("(bf16[8,2], s32[])")
    assert b == 8 * 2 * 2 + 4
    e, b = _shape_elems_bytes("pred[]")
    assert e == 1 and b == 1
