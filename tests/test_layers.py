"""Layer-primitive properties: RoPE, norms, GQA, sliding windows, xent."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers as L


class _Cfg:
    rope_fraction = 1.0
    rope_theta = 10000.0
    num_heads = 4
    num_kv_heads = 2
    hd = 16
    num_layers = 2
    d_model = 32
    qkv_bias = False
    mlp_act = "swiglu"
    d_ff = 64


def test_rope_preserves_norm():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 4, 16))
    pos = jnp.arange(8)[None, :]
    out = L.apply_rope(x, pos, 1.0, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(out), axis=-1), rtol=1e-5)


def test_rope_relative_position_invariance():
    """q·k after RoPE depends only on relative distance."""
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 32))

    def dot_at(pq, pk):
        qr = L.apply_rope(q, jnp.array([[pq]]), 1.0, 1e4)
        kr = L.apply_rope(k, jnp.array([[pk]]), 1.0, 1e4)
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(3, 1) - dot_at(103, 101)) < 1e-3
    assert abs(dot_at(0, 0) - dot_at(50, 50)) < 1e-3


def test_partial_rope_leaves_tail_untouched():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (1, 4, 2, 16))
    out = L.apply_rope(x, jnp.arange(4)[None], 0.25, 1e4)
    np.testing.assert_array_equal(np.asarray(out[..., 4:]),
                                  np.asarray(x[..., 4:]))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_rmsnorm_unit_rms(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, 17)) * 7.0
    out = L.rmsnorm(x, jnp.ones((17,)))
    rms = np.sqrt(np.mean(np.asarray(out, np.float32) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_layernorm_moments():
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 33)) * 4 + 2
    out = np.asarray(L.layernorm(x, jnp.ones((33,)), jnp.zeros((33,))),
                     np.float32)
    np.testing.assert_allclose(out.mean(-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(out.std(-1), 1.0, rtol=2e-2)


def test_gqa_equals_repeated_kv_mha():
    """Grouped einsum must equal repeating KV heads into full MHA."""
    cfg = _Cfg()
    key = jax.random.PRNGKey(4)
    B, S = 2, 10
    q = jax.random.normal(key, (B, S, cfg.num_heads, cfg.hd))
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (B, S, cfg.num_kv_heads, cfg.hd))
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (B, S, cfg.num_kv_heads, cfg.hd))
    s_grouped = L._gqa_scores(q, k)                      # (B,K,G,S,S)
    G = cfg.num_heads // cfg.num_kv_heads
    k_rep = jnp.repeat(k, G, axis=2)
    s_full = jnp.einsum("bqhd,bshd->bhqs", q, k_rep) / math.sqrt(cfg.hd)
    np.testing.assert_allclose(
        np.asarray(s_grouped.reshape(B, cfg.num_heads, S, S)),
        np.asarray(s_full), rtol=1e-5, atol=1e-6)


def test_causal_mask_blocks_future():
    """Changing a future token must not change past logits."""
    cfg = _Cfg()
    key = jax.random.PRNGKey(5)
    p = L.attn_params(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 9), (1, 6, 32))
    out1, _ = L.full_attention(cfg, p, x)
    x2 = x.at[0, 5].set(99.0)
    out2, _ = L.full_attention(cfg, p, x2)
    np.testing.assert_allclose(np.asarray(out1[0, :5]),
                               np.asarray(out2[0, :5]), rtol=1e-4, atol=1e-5)


def test_sliding_window_blocks_distant_past():
    cfg = _Cfg()
    key = jax.random.PRNGKey(6)
    p = L.attn_params(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 8), (1, 12, 32))
    out1, _ = L.full_attention(cfg, p, x, sliding_window=3)
    x2 = x.at[0, 0].set(50.0)                # outside window of position 11
    out2, _ = L.full_attention(cfg, p, x2, sliding_window=3)
    np.testing.assert_allclose(np.asarray(out1[0, -1]),
                               np.asarray(out2[0, -1]), rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 40))
def test_xent_matches_gather_reference(seed, V):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (3, 7, V))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (3, 7), 0, V)
    got = float(L.softmax_xent(logits, labels))
    lf = np.asarray(logits, np.float64)
    lse = np.log(np.exp(lf - lf.max(-1, keepdims=True)).sum(-1)) \
        + lf.max(-1)
    gold = np.take_along_axis(lf, np.asarray(labels)[..., None], -1)[..., 0]
    want = float((lse - gold).mean())
    assert abs(got - want) < 1e-4


def test_xent_mask():
    logits = jnp.zeros((1, 4, 5))
    labels = jnp.zeros((1, 4), jnp.int32)
    mask = jnp.array([[1.0, 1.0, 0.0, 0.0]])
    full = float(L.softmax_xent(logits, labels))
    masked = float(L.softmax_xent(logits, labels, mask))
    np.testing.assert_allclose(full, masked, rtol=1e-6)  # uniform logits


def test_sinusoidal_position_at_matches_table():
    table = L.sinusoidal_positions(16, 32)
    for pos in (0, 3, 15):
        np.testing.assert_allclose(
            np.asarray(L.sinusoidal_position_at(pos, 32)),
            np.asarray(table[pos]), rtol=1e-5, atol=1e-6)