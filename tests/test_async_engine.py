"""Event-driven FL simulator invariants (§IV-B) + strategy behaviour."""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import anomaly_mlp
from repro.core import async_engine as ae
from repro.core import baselines
from repro.data import partition, synthetic

CFG = anomaly_mlp.CONFIG.replace(mlp_hidden=(32, 16), num_features=12,
                                 num_classes=3)


def _setup(n_clients=6, n=1200, seed=0):
    X, y = synthetic.make_unsw_like(seed, n, CFG.num_features, CFG.num_classes)
    parts = partition.dirichlet_partition(y, n_clients, alpha=0.7, seed=seed)
    clients = [{"x": X[p], "y": y[p]} for p in parts]
    Xe, ye = synthetic.make_unsw_like(seed + 1, 400, CFG.num_features,
                                      CFG.num_classes)
    return clients, {"x": Xe, "y": ye}


def _run(strategy, profiles, rounds=4, seed=0):
    clients, ev = _setup(len(profiles), seed=seed)
    sim = ae.FederatedSimulation(CFG, clients, ev, strategy, profiles,
                                 seed=seed)
    return sim.run(rounds)


def test_deterministic_given_seed():
    strat = baselines.ours(batch_size=32)
    h1 = _run(strat, ae.heterogeneous_profiles(4, seed=3, dropout_p=0.2))
    h2 = _run(copy.deepcopy(strat),
              ae.heterogeneous_profiles(4, seed=3, dropout_p=0.2))
    for a, b in zip(h1, h2):
        assert a.sim_time == b.sim_time
        assert a.accuracy == b.accuracy
        assert a.bytes_sent == b.bytes_sent


def test_async_equals_sync_under_uniform_conditions():
    """With equal speeds, no latency/dropout, full quorum, theta=None and
    alpha0 forced so the convex update reduces to FedAvg over 1..C arrivals
    this degenerates; instead we assert trajectory EQUALITY of sync FedAvg
    vs sync CMFL-with-theta=None (same engine, same path)."""
    profiles = ae.uniform_profiles(4)
    a = _run(baselines.fedavg(batch_size=32), profiles)
    b = _run(baselines.cmfl(batch_size=32, theta=None), profiles)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x.accuracy, y.accuracy, rtol=1e-6)
        np.testing.assert_allclose(x.loss, y.loss, rtol=1e-6)


def test_filtering_reduces_bytes():
    profiles = ae.uniform_profiles(6)
    full = _run(baselines.fedavg(batch_size=32), profiles, rounds=6)
    filt = _run(baselines.cmfl(batch_size=32, theta=0.6), profiles, rounds=6)
    assert filt[-1].bytes_sent <= full[-1].bytes_sent
    assert filt[-1].accept_rate <= 1.0


def test_sync_pays_straggler_barrier():
    """A 10x straggler must inflate sync wall clock above async's."""
    profiles = ae.uniform_profiles(5)
    profiles[0].speed = 0.1                       # straggler
    sync = _run(baselines.fedavg(batch_size=32), profiles, rounds=3)
    ours = _run(baselines.ours(batch_size=32, dynamic_batch=False),
                profiles, rounds=3)
    assert ours[-1].sim_time < sync[-1].sim_time
    assert sync[-1].idle_time > 0.0
    assert ours[-1].idle_time == 0.0


def test_dropout_without_checkpointing_loses_updates():
    profiles = ae.uniform_profiles(6, dropout_p=0.5)
    st_no = baselines.fedavg(batch_size=32)
    assert not st_no.checkpointing
    hist = _run(st_no, profiles, rounds=4, seed=5)
    # some rounds must have lost clients (accept_rate < 1)
    assert min(h.accept_rate for h in hist) < 1.0


def test_checkpointing_recovers_dropped_clients():
    profiles = ae.uniform_profiles(6, dropout_p=0.5)
    strat = baselines.ours(batch_size=32, theta=None, dynamic_batch=False)
    clients, ev = _setup(6, seed=7)
    sim = ae.FederatedSimulation(CFG, clients, ev, strat, profiles, seed=7)
    hist = sim.run(4)
    # every selected client still delivers (recovered via checkpoint)
    assert all(h.accept_rate == 1.0 for h in hist)
    assert len(sim.failure_log) > 0


def test_accuracy_improves_over_rounds():
    profiles = ae.uniform_profiles(6)
    hist = _run(baselines.ours(batch_size=32, dynamic_batch=False),
                profiles, rounds=8, seed=2)
    assert hist[-1].accuracy > hist[0].accuracy - 0.05
    assert hist[-1].accuracy > 0.4


def test_dynamic_batch_adjusts_loaders():
    profiles = ae.heterogeneous_profiles(5, seed=1, speed_sigma=1.0)
    clients, ev = _setup(5)
    strat = baselines.ours(batch_size=64, dynamic_batch=True)
    sim = ae.FederatedSimulation(CFG, clients, ev, strat, profiles, seed=0)
    sizes0 = [l.batch_size for l in sim.loaders]
    assert len(set(sizes0)) > 1, "heterogeneous capacity -> varied batches"
    sim.run(3)
    for l in sim.loaders:
        assert 1 <= l.batch_size <= 1024
