"""Cross-engine differential test harness.

One reusable matrix replaces the ad-hoc per-PR equivalence tests: any
``(ExperimentSpec, scenario)`` cell executes across every execution path

    loop       — engine="sim", the per-client reference loop
    megastep   — engine="sim", one compiled cohort dispatch per round
    scanned1/4 — engine="sim", device control plane, R rounds per lax.scan
    spmd       — engine="spmd" (where the spec is valid: sync schedule,
                 no dynamic_batch)

and the harness asserts

  * loop ≡ megastep       — same Generator draw order, so event
                            accounting is exact and fp trajectories
                            coincide within vmap-vs-loop reduction order
                            (the pinned tolerance contract of
                            tests/test_megastep.py);
  * scanned4 ≡ scanned1   — per-round keys fold from the absolute round
                            index, so dispatch grouping changes NOTHING
                            (bit-exact, accuracy at shared eval rounds);
  * host ≡ scanned        — on accounting-deterministic specs (no θ, no
                            dropout, full participation) the event
                            accounting (sim/comm/idle time, bytes,
                            updates) must agree across engine families
                            even though their batch RNGs differ;
  * invariants            — on EVERY path: monotone comm accounting,
                            accept_rate ∈ [0,1], and under churn the
                            mask-conservation bound updates_applied ≤
                            live-client count per round (the live roster
                            replayed from the scenario, independent of
                            any engine);
  * byzantine rejection   — with a θ strategy, sign-flipped clients'
                            pass-rate EMAs collapse below every honest
                            client's (the §IV-C filter provably rejects
                            them at the source).

Run the whole preset matrix standalone (the CI `scenario-matrix` step):

    PYTHONPATH=src REPRO_SMOKE=1 python -m tests.harness

tests/test_scenarios.py drives the same machinery property-based.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Sequence

import numpy as np

from repro.api import (DataSpec, ExperimentSession, ExperimentSpec,
                       ROUND_FIELDS, SpecError, StrategyConfig, WorldSpec,
                       run_experiment)
from repro.core import scenario as scenario_mod

PATHS = ("loop", "megastep", "scanned1", "scanned4", "spmd")
PRESETS = ("static", "drift", "churn", "flaky-links", "byzantine")


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def base_spec(scenario=None, *, rounds: int = 6, num_clients: int = 5,
              dropout_p: float = 0.0, theta: Optional[float] = 0.6,
              selection: bool = True, select_fraction: float = 1.0,
              mode: str = "sync", checkpointing: bool = True,
              n_samples: int = 1200, seed: int = 0,
              partition: str = "dirichlet",
              **strategy_overrides) -> ExperimentSpec:
    """A small differential cell: smoke model, heterogeneous world."""
    st = StrategyConfig(mode=mode, theta=theta, selection=selection,
                        select_fraction=select_fraction,
                        dynamic_batch=False, checkpointing=checkpointing,
                        batch_size=32, max_samples_per_round=64,
                        **strategy_overrides)
    return ExperimentSpec(
        model="anomaly-mlp-smoke",
        data=DataSpec(n_samples=n_samples, eval_samples=300,
                      partition=partition),
        world=WorldSpec(num_clients=num_clients, profile="heterogeneous",
                        dropout_p=dropout_p),
        strategy=st, scenario=scenario, rounds=rounds, seed=seed)


def path_spec(spec: ExperimentSpec, path: str) -> ExperimentSpec:
    """The spec that executes ``spec``'s cell on one execution path."""
    if path == "loop":
        return dataclasses.replace(spec, engine="sim", megastep=False,
                                   rounds_per_dispatch=None)
    if path == "megastep":
        return dataclasses.replace(spec, engine="sim", megastep=True,
                                   rounds_per_dispatch=None)
    if path in ("scanned1", "scanned4"):
        return dataclasses.replace(spec, engine="sim", megastep=True,
                                   rounds_per_dispatch=int(path[-1]))
    if path == "spmd":
        return dataclasses.replace(spec, engine="spmd", megastep=True,
                                   rounds_per_dispatch=None)
    raise ValueError(f"unknown path {path!r}; expected one of {PATHS}")


def spmd_valid(spec: ExperimentSpec) -> bool:
    """Whether the spmd column exists for this cell (sync schedule, no
    dynamic_batch — exactly spec._validate_spmd's contract)."""
    try:
        path_spec(spec, "spmd").validate()
        return True
    except SpecError:
        return False


def valid_paths(spec: ExperimentSpec,
                paths: Sequence[str] = PATHS) -> list:
    return [p for p in paths if p != "spmd" or spmd_valid(spec)]


def run_cell(spec: ExperimentSpec, path: str):
    return run_experiment(path_spec(spec, path))


# ---------------------------------------------------------------------------
# pairwise equivalence asserts
# ---------------------------------------------------------------------------

def assert_host_equivalent(loop_res, mega_res) -> None:
    """loop ≡ megastep: same RNG draw order -> identical event
    accounting; fp trajectories coincide up to vmap-vs-loop reduction
    order (the tests/test_megastep.py tolerance contract)."""
    assert len(loop_res.records) == len(mega_res.records)
    for a, b in zip(mega_res.records, loop_res.records):
        assert a.round == b.round
        assert a.updates_applied == b.updates_applied
        assert a.accept_rate == b.accept_rate
        assert a.bytes_sent == b.bytes_sent
        np.testing.assert_allclose(a.sim_time, b.sim_time, rtol=1e-9)
        np.testing.assert_allclose(a.comm_time, b.comm_time, rtol=1e-9)
        np.testing.assert_allclose(a.idle_time, b.idle_time,
                                   rtol=1e-9, atol=1e-12)
        np.testing.assert_allclose(a.accuracy, b.accuracy, atol=2e-3)
        np.testing.assert_allclose(a.loss, b.loss, rtol=2e-3)


def assert_scan_equivalent(grouped_res, single_res, R: int = 4) -> None:
    """scanned R>1 ≡ scanned R=1: bit-exact on every scan-computed
    field; accuracy compared where both groupings measured it."""
    assert len(grouped_res.records) == len(single_res.records)
    n = len(grouped_res.records)
    for i, (a, b) in enumerate(zip(grouped_res.records,
                                   single_res.records)):
        for f in ("round", "sim_time", "comm_time", "idle_time",
                  "bytes_sent", "updates_applied", "accept_rate", "loss"):
            assert getattr(a, f) == getattr(b, f), \
                f"scanned grouping changed {f} at round {i}"
        if (i + 1) % R == 0 or i == n - 1:
            assert a.accuracy == b.accuracy


def assert_candidate_frac_noop(spec: ExperimentSpec,
                               paths: Optional[Sequence[str]] = None,
                               shards: int = 4) -> None:
    """candidate_frac=1.0 must reproduce single-stage selection
    BIT-EXACTLY on every execution path: with quota == per-shard size
    the candidate union is the whole population, so stage 2 sees the
    identical masked scores (two_stage exactness contract). The cell
    must actually select (select_fraction < 1), else selection is
    inert and the assert proves nothing."""
    st = spec.resolve_strategy()
    assert st.selection and st.select_fraction < 1.0, \
        "cell must select a strict cohort for the noop check to bite"
    assert spec.candidate_frac is None, "pass the single-stage spec"
    two = dataclasses.replace(spec, candidate_frac=1.0,
                              candidate_shards=shards)
    for p in valid_paths(spec, paths if paths is not None else PATHS):
        a, b = run_cell(spec, p), run_cell(two, p)
        assert len(a.records) == len(b.records)
        for ra, rb in zip(a.records, b.records):
            for f in ROUND_FIELDS:
                va, vb = getattr(ra, f), getattr(rb, f)
                if va != va and vb != vb:
                    continue          # NaN == NaN (unmeasured accuracy)
                assert va == vb, \
                    (f"{p}: candidate_frac=1.0 changed {f} at round "
                     f"{ra.round}: {va!r} != {vb!r}")


def assert_topology_parity(spec: ExperimentSpec,
                           topology="two-tier-pods",
                           paths: Sequence[str] = ("loop", "megastep",
                                                   "scanned1", "scanned4")
                           ) -> Dict[str, dict]:
    """The hierarchical-topology matrix cell (repro.topology):

      * measurement-only — with a topology attached, every path's
        RoundRecord stream is BIT-EQUAL to the same cell without one
        (topology accumulates the deltas the flat aggregation already
        consumed; it never feeds back into training);
      * loop ≡ megastep ≡ scanned R=1/4 — sync cadence fires off the
        ABSOLUTE round index on every path, so sync/accept/veto counts
        and the per-tier byte accounting agree across all of them (pass
        a theta-free topology preset for exact counts: discrete veto
        decisions near the theta boundary may fp-flip between vmap and
        scan reduction orders);
      * scanned4 ≡ scanned1 — the full TopologyState carry is bit-exact
        under dispatch regrouping.

    Returns the per-path topology summaries."""
    topo_spec = dataclasses.replace(spec, topology=topology)
    summaries, states = {}, {}
    for p in paths:
        base_res = run_cell(spec, p)
        sess = ExperimentSession.open(path_spec(topo_spec, p))
        sess.run(topo_spec.rounds)
        res = sess.result()
        assert len(res.records) == len(base_res.records)
        for ra, rb in zip(res.records, base_res.records):
            for f in ROUND_FIELDS:
                va, vb = getattr(ra, f), getattr(rb, f)
                if va != va and vb != vb:
                    continue              # NaN (unmeasured accuracy)
                assert va == vb, \
                    (f"{p}: attaching a topology changed {f} at round "
                     f"{ra.round}: {va!r} != {vb!r}")
        summaries[p] = sess._driver.sim.topology_summary()
        states[p] = sess._driver.sim._topo_state
    ref_p = paths[0]
    ref = summaries[ref_p]
    for p in paths[1:]:
        s = summaries[p]
        assert s["syncs"] == ref["syncs"], \
            f"{p} vs {ref_p}: sync counts differ ({s['syncs']} vs " \
            f"{ref['syncs']})"
        for key in ("accepts", "vetoes", "tier_bytes", "tier_time"):
            np.testing.assert_allclose(
                s[key], ref[key], rtol=1e-6,
                err_msg=f"{p} vs {ref_p}: topology {key} differ")
    if "scanned1" in states and "scanned4" in states:
        import jax
        import jax.numpy as jnp
        for a, b in zip(jax.tree.leaves(states["scanned1"]),
                        jax.tree.leaves(states["scanned4"])):
            assert bool(jnp.array_equal(a, b)), \
                "scanned4 TopologyState not bit-exact vs scanned1"
    return summaries


def assert_fused_equivalent(spec: ExperimentSpec, *, R: int = 4,
                            tmpdir: Optional[str] = None) -> None:
    """Eval-in-carry parity: folding eval into the scanned lax.scan
    carry (``fused_eval=True``) must change NOTHING about the
    trajectory —

      * fused R=1 ≡ post-hoc R=1  — bit-equal on every scan-computed
        field; accuracy bit-equal at eval-cadence rounds (between them
        fused carries the last measurement forward while post-hoc
        leaves NaN, which is a reporting difference, not a trajectory
        one);
      * fused R ≡ fused R=1       — eval keys off the ABSOLUTE round
        index inside the scan, so dispatch grouping is invisible
        (bit-exact, every field including accuracy);
      * fused ≡ loop              — the cross-family contract: exact
        event accounting on accounting-deterministic cells
        (assert_accounting_close; the families draw different batch
        RNGs so accuracies only agree statistically — sanity band);
      * checkpoint boundary       — a fused run interrupted by
        checkpoint/restore mid-stream is bit-equal to the
        uninterrupted one (prev_acc re-seeds from persisted history).
        Accuracy is compared at eval-cadence rounds: ending a stream
        evaluates its final round (the documented ``stream()``
        eval_final contract, fused and unfused alike), so when the cut
        lands off-cadence the runs legitimately report different
        carry-forward values until the next cadence round — the
        trajectory itself (every other field) must stay bit-equal at
        EVERY round.
    """
    E = spec.eval_every
    n = spec.rounds
    fused = dataclasses.replace(spec, engine="sim", megastep=True,
                                fused_eval=True)
    f1 = run_experiment(dataclasses.replace(fused, rounds_per_dispatch=1))
    fR = run_experiment(dataclasses.replace(fused, rounds_per_dispatch=R))
    posthoc = run_cell(spec, "scanned1")
    loop = run_cell(spec, "loop")

    def eval_round(i):
        return i % E == 0 or i == n - 1

    assert len(f1.records) == len(posthoc.records) == n
    for i, (a, b) in enumerate(zip(f1.records, posthoc.records)):
        for f in ("round", "sim_time", "comm_time", "idle_time",
                  "bytes_sent", "updates_applied", "accept_rate", "loss"):
            assert getattr(a, f) == getattr(b, f), \
                f"fused eval changed {f} at round {i}"
        if eval_round(i):
            assert a.accuracy == b.accuracy, \
                f"fused accuracy diverged from post-hoc at round {i}"
    for i, (a, b) in enumerate(zip(fR.records, f1.records)):
        for f in ROUND_FIELDS:
            assert getattr(a, f) == getattr(b, f), \
                f"fused dispatch grouping changed {f} at round {i}"
    if accounting_deterministic(spec):
        assert_accounting_close(loop, f1)
    for i, (a, b) in enumerate(zip(f1.records, loop.records)):
        if eval_round(i):
            np.testing.assert_allclose(a.accuracy, b.accuracy, atol=5e-2)
    if tmpdir is not None:
        ckpt_spec = dataclasses.replace(fused, rounds_per_dispatch=R)
        cut = max(1, n // 2)
        s = ExperimentSession.open(ckpt_spec)
        s.run(cut)
        path = s.checkpoint(os.path.join(tmpdir, "fused.ckpt"))
        s2 = ExperimentSession.restore(path)
        s2.run(n - cut)
        resumed = s2.result()
        assert len(resumed.records) == n
        for i, (a, b) in enumerate(zip(resumed.records, fR.records)):
            for f in ROUND_FIELDS:
                if f == "accuracy" and not eval_round(i):
                    continue
                assert getattr(a, f) == getattr(b, f), \
                    (f"checkpoint/restore perturbed fused {f} at round "
                     f"{i}: {getattr(a, f)!r} != {getattr(b, f)!r}")


def accounting_deterministic(spec: ExperimentSpec) -> bool:
    """True when the cell's event accounting cannot depend on which
    samples were drawn: no θ decisions (every update transmits), no
    dropout draws, full participation, static batch shapes. On such
    cells the host and scanned families must agree on timing/bytes even
    though their batch RNGs differ."""
    st = spec.resolve_strategy()
    if st.theta is not None or st.dynamic_batch or st.quantize_updates:
        return False
    if st.grad_norm_selection or (st.selection and st.select_fraction < 1.0):
        return False
    if spec.world.dropout_p > 0:
        return False
    return True


def assert_accounting_close(host_res, scan_res) -> None:
    """Cross-family accounting parity (f32 scan arithmetic vs f64 host
    floats -> tolerance, not bits)."""
    assert len(host_res.records) == len(scan_res.records)
    for a, b in zip(host_res.records, scan_res.records):
        assert a.round == b.round
        assert a.updates_applied == b.updates_applied
        np.testing.assert_allclose(a.accept_rate, b.accept_rate, rtol=1e-6)
        np.testing.assert_allclose(a.bytes_sent, b.bytes_sent, rtol=1e-6)
        np.testing.assert_allclose(a.sim_time, b.sim_time, rtol=1e-3)
        np.testing.assert_allclose(a.comm_time, b.comm_time, rtol=1e-3)
        np.testing.assert_allclose(a.idle_time, b.idle_time,
                                   rtol=1e-3, atol=1e-6)


# ---------------------------------------------------------------------------
# invariants (every path, every scenario)
# ---------------------------------------------------------------------------

def check_invariants(result, spec: ExperimentSpec, label: str = "") -> None:
    recs = result.records
    scn = spec.resolve_scenario()
    n = spec.world.num_clients
    views = scenario_mod.replay(scn, n, len(recs))
    prev = None
    for rec, wv in zip(recs, views):
        # monotone comm accounting: cumulative counters never decrease
        for f in ("sim_time", "comm_time", "idle_time", "bytes_sent"):
            v = getattr(rec, f)
            assert np.isfinite(v), f"{label}: {f} not finite at {rec.round}"
            if prev is not None:
                assert v >= getattr(prev, f) - 1e-9, \
                    f"{label}: {f} decreased at round {rec.round}"
        assert -1e-6 <= rec.accept_rate <= 1.0 + 1e-6, \
            f"{label}: accept_rate out of [0,1] at round {rec.round}"
        # mask conservation under churn: the server can never apply more
        # client updates than clients live that round (live roster
        # replayed from the scenario itself, independent of the engine)
        live = int(wv["live"].sum()) if wv is not None else n
        assert 0 <= rec.updates_applied <= live, \
            (f"{label}: updates_applied={rec.updates_applied} exceeds "
             f"live={live} at round {rec.round}")
        prev = rec


# ---------------------------------------------------------------------------
# byzantine rejection (the θ-filter acceptance criterion)
# ---------------------------------------------------------------------------

def pass_rate_by_client(spec: ExperimentSpec, path: str) -> np.ndarray:
    """Run a cell and return the per-client θ pass-rate EMAs the server
    control plane learned — host selector records on the loop/megastep
    paths, the device ControlState on scanned/spmd (one public surface:
    ``ExperimentSession.client_pass_rates``). The spmd engine raises
    when its control plane is inactive — give the cell selection or
    dropout."""
    s = ExperimentSession.open(path_spec(spec, path))
    s.run(spec.rounds)
    return np.asarray(s.client_pass_rates())


def assert_byzantine_rejected(spec: ExperimentSpec, path: str) -> None:
    """Sign-flipped clients must be provably rejected by the θ-filter:
    their pass-rate EMAs collapse below 0.5 AND below every honest
    client's."""
    scn = spec.resolve_scenario()
    assert scn is not None and scn.byzantine is not None \
        and scn.byzantine.sign_flip, "cell has no sign-flip byzantines"
    assert spec.resolve_strategy().theta is not None, \
        "byzantine rejection needs a θ strategy"
    n_byz = scn.byzantine.n_byz
    rates = pass_rate_by_client(spec, path)
    byz, honest = rates[:n_byz], rates[n_byz:]
    assert byz.max() < 0.5, \
        f"{path}: byzantine pass-rate {byz} not rejected"
    assert byz.max() < honest.min(), \
        f"{path}: byzantine pass-rates {byz} not below honest {honest}"


# ---------------------------------------------------------------------------
# the differential runner
# ---------------------------------------------------------------------------

def differential(spec: ExperimentSpec,
                 paths: Optional[Sequence[str]] = None) -> Dict[str, object]:
    """Execute one (spec, scenario) cell across every requested path and
    assert the full parity + invariant contract. Returns the per-path
    ``ExperimentResult``s for further inspection."""
    spec.validate()
    paths = valid_paths(spec, paths if paths is not None else PATHS)
    results = {p: run_cell(spec, p) for p in paths}
    if "loop" in results and "megastep" in results:
        assert_host_equivalent(results["loop"], results["megastep"])
    if "scanned1" in results and "scanned4" in results:
        assert_scan_equivalent(results["scanned4"], results["scanned1"],
                               R=4)
    if accounting_deterministic(spec):
        host = results.get("megastep") or results.get("loop")
        scan = results.get("scanned1") or results.get("scanned4")
        if host is not None and scan is not None:
            assert_accounting_close(host, scan)
        if host is not None and "spmd" in results:
            assert_accounting_close(host, results["spmd"])
    for p, res in results.items():
        check_invariants(res, spec, label=p)
    return results


# ---------------------------------------------------------------------------
# standalone matrix run (the CI `scenario-matrix` smoke step)
# ---------------------------------------------------------------------------

def matrix_cell(preset: str, *, rounds: int, theta=0.6) -> ExperimentSpec:
    """The preset's differential cell. Churn/flaky presets get dropout
    so the fault model and the regime switch both engage; byzantine
    keeps θ (the rejection mechanism under test); static/drift also run
    an accounting-deterministic θ=None variant inside main()."""
    dropout = 0.2 if preset in ("flaky-links", "churn+flaky-links") else 0.0
    return base_spec(scenario=preset if preset != "static" else None,
                     rounds=rounds, dropout_p=dropout, theta=theta)


def main(argv=None) -> int:
    smoke = os.environ.get("REPRO_SMOKE") == "1"
    rounds = 4 if smoke else 8
    failures = []
    cells = []
    for preset in PRESETS:
        cells.append((preset + "/theta", matrix_cell(preset,
                                                     rounds=rounds)))
    # accounting-deterministic variants: host ≡ scanned ≡ spmd timing
    for preset in ("static", "drift", "churn"):
        cells.append((preset + "/no-theta",
                      matrix_cell(preset, rounds=rounds, theta=None)))
    # the async server family (sim-only column of the matrix)
    cells.append(("churn/async",
                  base_spec(scenario="churn", rounds=rounds, mode="async",
                            alpha0=1.0)))
    for name, spec in cells:
        paths = valid_paths(spec)
        try:
            differential(spec)
            print(f"# cell {name:<22} paths={','.join(paths)}  OK")
        except AssertionError as e:
            failures.append(name)
            print(f"# cell {name:<22} FAILED: {e}")
    # two-stage selection: candidate_frac=1.0 must be a bit-exact noop
    # on every path (selection must bite: strict select_fraction)
    noop = base_spec(rounds=rounds, num_clients=6, select_fraction=0.5)
    try:
        assert_candidate_frac_noop(noop)
        print("# candidate_frac=1.0 noop on "
              f"{','.join(valid_paths(noop))}  OK")
    except AssertionError as e:
        failures.append("candidate-frac-noop")
        print(f"# candidate_frac=1.0 noop FAILED: {e}")
    # hierarchical topology: attaching a tier tree must not perturb the
    # flat trajectory on ANY path, and its sync accounting must agree
    # across loop/megastep/scanned (theta-free tiers: exact counts)
    from repro.api import TierSpec, TopologySpec
    topo = TopologySpec(tiers=(
        TierSpec("edge", fanout=3),
        TierSpec("region", fanout=2, sync_every=2),
        TierSpec("global", sync_every=4)))
    topo_cell = base_spec(rounds=rounds, num_clients=8, theta=None)
    try:
        assert_topology_parity(topo_cell, topology=topo)
        print("# topology parity on loop,megastep,scanned1,scanned4  OK")
    except AssertionError as e:
        failures.append("topology-parity")
        print(f"# topology parity FAILED: {e}")
    # eval-in-carry fusion: folding eval into the scan carry must not
    # perturb the trajectory on any grouping, across a checkpoint
    # boundary included (eval_every=2 so carry-forward rounds exist)
    import tempfile
    fused_cell = dataclasses.replace(
        base_spec(rounds=rounds, theta=None), eval_every=2)
    try:
        with tempfile.TemporaryDirectory() as td:
            assert_fused_equivalent(fused_cell, tmpdir=td)
        print("# fused-eval parity (R1,R4,loop,checkpoint)  OK")
    except AssertionError as e:
        failures.append("fused-eval-parity")
        print(f"# fused-eval parity FAILED: {e}")
    # byzantine rejection on every path that can carry it — 8 rounds
    # even in smoke mode: the 0.8-EMA needs ~4 rejections to provably
    # cross below 0.5 (1 -> 0.8^k), and round 0 has no reference yet.
    # IID shards isolate the adversary: on the spmd path (raw per-round
    # gradients, no local SGD smoothing) extreme non-IID shards can make
    # HONEST minority clients θ-divergent too, which is a data property,
    # not the rejection mechanism under test
    byz = base_spec(scenario="byzantine", rounds=8,
                    dropout_p=0.05, theta=0.6, partition="iid")
    for path in valid_paths(byz):
        try:
            assert_byzantine_rejected(byz, path)
            print(f"# byzantine-rejected on {path:<10} OK")
        except AssertionError as e:
            failures.append(f"byzantine/{path}")
            print(f"# byzantine-rejected on {path:<10} FAILED: {e}")
    if failures:
        print(f"# scenario-matrix FAILURES: {failures}")
        return 1
    print(f"# scenario-matrix: {len(cells)} cells x paths all OK "
          f"({'smoke' if smoke else 'full'} mode)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
