"""Abstract tracing of every FULL-SIZE (arch × shape) step via
jax.eval_shape — no device allocation, no XLA compile. This is the fast
CI guard in front of the multi-pod dry-run: it catches shape/dtype bugs at
production scale in seconds. The actual lowering+compile proof lives in
repro.launch.dryrun (deliverable e)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.configs.shapes import SHAPES
from repro.core import fl_step
from repro.models import api
from repro.optim import adamw as optim_mod

COMBOS = [(a, s) for a in registry.ASSIGNED_ARCHS for s in SHAPES
          if not (s == "long_500k" and a in registry.LONG_CTX_SKIP)]


@pytest.mark.parametrize("arch,shape_name", COMBOS,
                         ids=[f"{a}-{s}" for a, s in COMBOS])
def test_full_config_step_traces(arch, shape_name):
    cfg = registry.config_for_shape(arch, shape_name)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        opt = optim_mod.for_config(cfg)
        specs = api.input_specs(cfg, shape, num_clients=16)
        state = jax.eval_shape(
            lambda: fl_step.init_state(jax.random.PRNGKey(0), cfg, opt))
        step = fl_step.make_raw_step(cfg, opt, theta=0.65)
        out_state, metrics = jax.eval_shape(step, state, specs["batch"])
        assert metrics["loss"].dtype == jnp.float32
        # state structure is preserved round-trip (donation-compatible)
        assert jax.tree_util.tree_structure(out_state) \
            == jax.tree_util.tree_structure(state)
        for a, b in zip(jax.tree.leaves(out_state), jax.tree.leaves(state)):
            assert a.shape == b.shape and a.dtype == b.dtype
    elif shape.kind == "prefill":
        specs = api.input_specs(cfg, shape)
        params = jax.eval_shape(
            lambda: api.init_params(jax.random.PRNGKey(0), cfg))
        logits, cache = jax.eval_shape(
            lambda p, b: api.prefill(p, b, cfg), params, specs["batch"])
        toks = specs["batch"]["tokens"].shape[-1]
        expect = toks + (cfg.num_patches if cfg.family == "vlm" else 0)
        assert logits.shape[:2] == (shape.global_batch, expect)
        assert logits.shape[-1] == cfg.padded_vocab
    else:
        specs = api.input_specs(cfg, shape)
        params = jax.eval_shape(
            lambda: api.init_params(jax.random.PRNGKey(0), cfg))
        logits, new_cache = jax.eval_shape(
            lambda p, c, b: api.decode_step(p, c, b, cfg),
            params, specs["cache"], specs["batch"])
        assert logits.shape == (shape.global_batch, 1, cfg.padded_vocab)
        # steady-state serving: cache shapes must be invariant
        for a, b in zip(jax.tree.leaves(new_cache),
                        jax.tree.leaves(specs["cache"])):
            assert a.shape == b.shape, (a.shape, b.shape)


def test_long_500k_caches_are_subquadratic():
    """No cache leaf may scale with the 512k context for windowed archs."""
    for arch in registry.ASSIGNED_ARCHS:
        if arch in registry.LONG_CTX_SKIP:
            continue
        cfg = registry.config_for_shape(arch, "long_500k")
        shape = SHAPES["long_500k"]
        specs = api.input_specs(cfg, shape)
        total = sum(l.size * jnp.dtype(l.dtype).itemsize
                    for l in jax.tree.leaves(specs["cache"])
                    if hasattr(l, "size"))
        # must be far below a full 512k KV cache for the same arch
        full_kv = (cfg.num_layers * shape.global_batch * shape.seq_len
                   * max(cfg.num_kv_heads, 1) * max(cfg.hd, 64) * 2 * 2)
        assert total < full_kv / 10 or cfg.family in ("ssm", "hybrid"), arch