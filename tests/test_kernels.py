"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs the
ref.py pure-jnp oracles (interpret mode executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import aggregation, alignment
from repro.kernels import gather as ga
from repro.kernels import masked_agg as ma
from repro.kernels import ops, ref
from repro.kernels import quantize as qz
from repro.kernels import sign_align as sa

SHAPES = [(8, ops.LANE), (16, ops.LANE), (40, ops.LANE)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sign_align_counts(shape, dtype):
    key = jax.random.PRNGKey(0)
    g = _rand(key, shape, dtype)
    r = jnp.sign(_rand(jax.random.fold_in(key, 1), shape, jnp.float32)) \
        .astype(jnp.int8)
    np.testing.assert_allclose(
        np.asarray(sa.sign_align_counts(g, r)),
        np.asarray(ref.sign_align_counts(g, r)), rtol=1e-6)


@pytest.mark.parametrize("C", [1, 4, 16])
@pytest.mark.parametrize("dtype", DTYPES)
def test_per_client_sign_align(C, dtype):
    key = jax.random.PRNGKey(1)
    u = _rand(key, (C, 16, ops.LANE), dtype)
    r = jnp.sign(_rand(jax.random.fold_in(key, 2), (16, ops.LANE),
                       jnp.float32)).astype(jnp.int8)
    np.testing.assert_allclose(
        np.asarray(sa.per_client_sign_align(u, r)),
        np.asarray(ref.per_client_sign_align(u, r)), rtol=1e-6)


@pytest.mark.parametrize("C", [2, 8])
@pytest.mark.parametrize("shape", SHAPES)
def test_masked_agg(C, shape):
    key = jax.random.PRNGKey(2)
    u = _rand(key, (C,) + shape, jnp.float32)
    w = jax.nn.softmax(_rand(jax.random.fold_in(key, 3), (C,), jnp.float32))
    np.testing.assert_allclose(np.asarray(ma.masked_agg(u, w)),
                               np.asarray(ref.masked_agg(u, w)),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("N,K", [(4, 2), (8, 8), (6, 1)])
def test_onehot_cohort_gather(N, K):
    """One-hot matmul gather == jnp.take oracle (exact: single 1.0
    coefficient per output row) — the scanned control plane's cohort
    fetch (kernels/gather.py)."""
    key = jax.random.PRNGKey(5)
    src = _rand(key, (N, 8, ops.LANE), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (K,), 0, N)
    onehot = (idx[:, None] == jnp.arange(N)[None, :]).astype(jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ga.onehot_gather(src, onehot)),
        np.asarray(ref.cohort_gather(src, idx)))


@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_update(dtype):
    key = jax.random.PRNGKey(3)
    p = _rand(key, (16, ops.LANE), dtype)
    u = _rand(jax.random.fold_in(key, 4), (4, 16, ops.LANE), jnp.float32)
    w = jnp.array([0.3, 0.0, 0.5, 0.2]) * 0.01
    got = ma.fused_update(p, u, w)
    want = ref.fused_update(p, u, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_quantize_roundtrip(shape):
    key = jax.random.PRNGKey(4)
    x = _rand(key, shape, jnp.float32) * 3.0
    q, s = qz.quantize_q8(x)
    q2, s2 = ref.quantize_q8(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-6)
    back = qz.dequantize_q8(q, s)
    # quantization error bounded by scale/2 per element
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert np.all(err <= np.asarray(s) * 0.51 + 1e-9)


def test_quantize_zero_row_safe():
    x = jnp.zeros((8, ops.LANE), jnp.float32)
    q, s = qz.quantize_q8(x)
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.isfinite(np.asarray(s)))


# ---------------------------------------------------------------------------
# tree-level ops vs the pure-jnp core (hypothesis property sweeps)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(1, 400), st.integers(0, 2 ** 31 - 1))
def test_ops_ratio_matches_core(n_leaves, leaf_size, seed):
    key = jax.random.PRNGKey(seed)
    tree = {f"p{i}": jax.random.normal(jax.random.fold_in(key, i),
                                       (leaf_size + i,))
            for i in range(n_leaves)}
    refsign = alignment.tree_sign(
        jax.tree.map(lambda x: x * 0.7 + 0.05, tree))
    np.testing.assert_allclose(
        np.asarray(ops.sign_align_ratio(tree, refsign)),
        np.asarray(alignment.alignment_ratio(tree, refsign)), rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_ops_masked_agg_matches_core(C, seed):
    key = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(key, (37,)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (5, 11))}
    stacked = jax.tree.map(
        lambda x: jnp.stack([x * (i + 1) for i in range(C)]), tree)
    mask = (jax.random.uniform(jax.random.fold_in(key, 2), (C,)) > 0.4) \
        .astype(jnp.float32)
    if float(mask.sum()) == 0:
        mask = mask.at[0].set(1.0)
    got = ops.masked_aggregate(stacked, mask)
    want = aggregation.masked_mean(stacked, mask)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)
