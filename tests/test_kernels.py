"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs the
ref.py pure-jnp oracles (interpret mode executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import aggregation, alignment
from repro.kernels import arena
from repro.kernels import backend as kbackend
from repro.kernels import gather as ga
from repro.kernels import gpu
from repro.kernels import masked_agg as ma
from repro.kernels import ops, ref
from repro.kernels import quantize as qz
from repro.kernels import sign_align as sa

SHAPES = [(8, ops.LANE), (16, ops.LANE), (40, ops.LANE)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sign_align_counts(shape, dtype):
    key = jax.random.PRNGKey(0)
    g = _rand(key, shape, dtype)
    r = jnp.sign(_rand(jax.random.fold_in(key, 1), shape, jnp.float32)) \
        .astype(jnp.int8)
    np.testing.assert_allclose(
        np.asarray(sa.sign_align_counts(g, r)),
        np.asarray(ref.sign_align_counts(g, r)), rtol=1e-6)


@pytest.mark.parametrize("C", [1, 4, 16])
@pytest.mark.parametrize("dtype", DTYPES)
def test_per_client_sign_align(C, dtype):
    key = jax.random.PRNGKey(1)
    u = _rand(key, (C, 16, ops.LANE), dtype)
    r = jnp.sign(_rand(jax.random.fold_in(key, 2), (16, ops.LANE),
                       jnp.float32)).astype(jnp.int8)
    np.testing.assert_allclose(
        np.asarray(sa.per_client_sign_align(u, r)),
        np.asarray(ref.per_client_sign_align(u, r)), rtol=1e-6)


@pytest.mark.parametrize("C", [2, 8])
@pytest.mark.parametrize("shape", SHAPES)
def test_masked_agg(C, shape):
    key = jax.random.PRNGKey(2)
    u = _rand(key, (C,) + shape, jnp.float32)
    w = jax.nn.softmax(_rand(jax.random.fold_in(key, 3), (C,), jnp.float32))
    np.testing.assert_allclose(np.asarray(ma.masked_agg(u, w)),
                               np.asarray(ref.masked_agg(u, w)),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("N,K", [(4, 2), (8, 8), (6, 1)])
def test_onehot_cohort_gather(N, K):
    """One-hot matmul gather == jnp.take oracle (exact: single 1.0
    coefficient per output row) — the scanned control plane's cohort
    fetch (kernels/gather.py)."""
    key = jax.random.PRNGKey(5)
    src = _rand(key, (N, 8, ops.LANE), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (K,), 0, N)
    onehot = (idx[:, None] == jnp.arange(N)[None, :]).astype(jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ga.onehot_gather(src, onehot)),
        np.asarray(ref.cohort_gather(src, idx)))


@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_update(dtype):
    key = jax.random.PRNGKey(3)
    p = _rand(key, (16, ops.LANE), dtype)
    u = _rand(jax.random.fold_in(key, 4), (4, 16, ops.LANE), jnp.float32)
    w = jnp.array([0.3, 0.0, 0.5, 0.2]) * 0.01
    got = ma.fused_update(p, u, w)
    want = ref.fused_update(p, u, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_quantize_roundtrip(shape):
    key = jax.random.PRNGKey(4)
    x = _rand(key, shape, jnp.float32) * 3.0
    q, s = qz.quantize_q8(x)
    q2, s2 = ref.quantize_q8(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-6)
    back = qz.dequantize_q8(q, s)
    # quantization error bounded by scale/2 per element
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert np.all(err <= np.asarray(s) * 0.51 + 1e-9)


def test_quantize_zero_row_safe():
    x = jnp.zeros((8, ops.LANE), jnp.float32)
    q, s = qz.quantize_q8(x)
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.isfinite(np.asarray(s)))


# ---------------------------------------------------------------------------
# tree-level ops vs the pure-jnp core (hypothesis property sweeps)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(1, 400), st.integers(0, 2 ** 31 - 1))
def test_ops_ratio_matches_core(n_leaves, leaf_size, seed):
    key = jax.random.PRNGKey(seed)
    tree = {f"p{i}": jax.random.normal(jax.random.fold_in(key, i),
                                       (leaf_size + i,))
            for i in range(n_leaves)}
    refsign = alignment.tree_sign(
        jax.tree.map(lambda x: x * 0.7 + 0.05, tree))
    np.testing.assert_allclose(
        np.asarray(ops.sign_align_ratio(tree, refsign)),
        np.asarray(alignment.alignment_ratio(tree, refsign)), rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_ops_masked_agg_matches_core(C, seed):
    key = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(key, (37,)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (5, 11))}
    stacked = jax.tree.map(
        lambda x: jnp.stack([x * (i + 1) for i in range(C)]), tree)
    mask = (jax.random.uniform(jax.random.fold_in(key, 2), (C,)) > 0.4) \
        .astype(jnp.float32)
    if float(mask.sum()) == 0:
        mask = mask.at[0].set(1.0)
    got = ops.masked_aggregate(stacked, mask)
    want = aggregation.masked_mean(stacked, mask)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# GPU Triton-Pallas variants vs the same oracles
#
# Interpret mode executes the Triton-constrained kernel bodies (pow2
# block padding, broadcast-multiply reductions) on any backend; the
# compiled variant requires an actual GPU and SKIPS with an explicit
# reason elsewhere — never a silent fallback.
# ---------------------------------------------------------------------------

_GPU_MODES = [
    pytest.param(True, id="interpret"),
    pytest.param(False, id="compiled", marks=pytest.mark.skipif(
        jax.default_backend() != "gpu",
        reason="Triton lowering requires jax.default_backend() == 'gpu' "
               f"(got {jax.default_backend()!r}); interpret-mode variant "
               "covers the kernel bodies here")),
]
# deliberately non-power-of-2 client/population sizes to exercise padding
_GPU_CS = [1, 3, 5, 8]


@pytest.mark.parametrize("interpret", _GPU_MODES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_gpu_sign_align_counts(interpret, dtype):
    key = jax.random.PRNGKey(10)
    g = _rand(key, (13, ops.LANE), dtype)   # 13 rows: exercises R padding
    r = jnp.sign(_rand(jax.random.fold_in(key, 1), (13, ops.LANE),
                       jnp.float32)).astype(jnp.int8)
    np.testing.assert_allclose(
        np.asarray(gpu.sign_align_counts(g, r, interpret=interpret)),
        np.asarray(ref.sign_align_counts(g, r)), rtol=1e-6)


@pytest.mark.parametrize("interpret", _GPU_MODES)
@pytest.mark.parametrize("C", _GPU_CS)
def test_gpu_per_client_sign_align(interpret, C):
    key = jax.random.PRNGKey(11)
    u = _rand(key, (C, 16, ops.LANE), jnp.float32)
    r = jnp.sign(_rand(jax.random.fold_in(key, 2), (16, ops.LANE),
                       jnp.float32)).astype(jnp.int8)
    np.testing.assert_allclose(
        np.asarray(gpu.per_client_sign_align(u, r, interpret=interpret)),
        np.asarray(ref.per_client_sign_align(u, r)), rtol=1e-6)


@pytest.mark.parametrize("interpret", _GPU_MODES)
@pytest.mark.parametrize("C", _GPU_CS)
def test_gpu_masked_agg(interpret, C):
    key = jax.random.PRNGKey(12)
    u = _rand(key, (C, 16, ops.LANE), jnp.float32)
    w = jax.nn.softmax(_rand(jax.random.fold_in(key, 3), (C,), jnp.float32))
    np.testing.assert_allclose(
        np.asarray(gpu.masked_agg(u, w, interpret=interpret)),
        np.asarray(ref.masked_agg(u, w)), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("interpret", _GPU_MODES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_gpu_fused_update(interpret, dtype):
    key = jax.random.PRNGKey(13)
    p = _rand(key, (16, ops.LANE), dtype)
    u = _rand(jax.random.fold_in(key, 4), (3, 16, ops.LANE), jnp.float32)
    w = jnp.array([0.3, 0.5, 0.2]) * 0.01
    got = gpu.fused_update(p, u, w, interpret=interpret)
    want = ref.fused_update(p, u, w)
    assert got.dtype == p.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("interpret", _GPU_MODES)
@pytest.mark.parametrize("N,K", [(4, 2), (6, 1), (10, 7)])
def test_gpu_onehot_gather(interpret, N, K):
    key = jax.random.PRNGKey(14)
    src = _rand(key, (N, 13, ops.LANE), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (K,), 0, N)
    onehot = (idx[:, None] == jnp.arange(N)[None, :]).astype(jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(gpu.onehot_gather(src, onehot, interpret=interpret)),
        np.asarray(ref.cohort_gather(src, idx)))


@pytest.mark.parametrize("interpret", _GPU_MODES)
def test_gpu_quantize_roundtrip(interpret):
    key = jax.random.PRNGKey(15)
    x = _rand(key, (13, ops.LANE), jnp.float32) * 3.0
    q, s = gpu.quantize_q8(x, interpret=interpret)
    q2, s2 = ref.quantize_q8(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(gpu.dequantize_q8(q, s, interpret=interpret)),
        np.asarray(ref.dequantize_q8(q2, s2)), rtol=1e-6)


# ---------------------------------------------------------------------------
# backend selector: REPRO_KERNEL_BACKEND override semantics
# ---------------------------------------------------------------------------

def test_backend_auto_matches_platform(monkeypatch):
    monkeypatch.delenv(kbackend.ENV_VAR, raising=False)
    expected = {"tpu": "tpu-pallas", "gpu": "gpu-pallas"}.get(
        jax.default_backend(), "oracle")
    assert kbackend.resolve() == expected


def test_backend_forced_oracle(monkeypatch):
    monkeypatch.setenv(kbackend.ENV_VAR, "oracle")
    assert kbackend.resolve() == "oracle"
    assert not arena.use_pallas()
    assert ops.default_interpret()


def test_backend_unknown_forced_value_errors(monkeypatch):
    """An unknown override must raise, not degrade to a default."""
    monkeypatch.setenv(kbackend.ENV_VAR, "tensor-cores")
    with pytest.raises(ValueError, match="REPRO_KERNEL_BACKEND"):
        kbackend.resolve()


def test_backend_forced_pallas_requires_lowering(monkeypatch):
    """Forcing pallas on a platform without a Pallas lowering is an
    error (the silent-fallback bug this selector replaces)."""
    monkeypatch.setenv(kbackend.ENV_VAR, "pallas")
    if jax.default_backend() in ("tpu", "gpu"):
        assert kbackend.resolve().endswith("-pallas")
    else:
        with pytest.raises(RuntimeError, match="no Pallas lowering"):
            kbackend.resolve()


def test_backend_announces_once(monkeypatch, caplog):
    monkeypatch.setenv(kbackend.ENV_VAR, "oracle")
    monkeypatch.setattr(kbackend, "_announced", set())
    with caplog.at_level("INFO", logger="repro.kernels"):
        kbackend.resolve()
        kbackend.resolve()
    hits = [r for r in caplog.records
            if "active kernel backend" in r.getMessage()]
    assert len(hits) == 1
    assert "oracle" in hits[0].getMessage()


def test_ops_route_through_selector(monkeypatch):
    """Forced-oracle and auto must agree numerically on the pytree ops
    (interpret-mode kernels and jnp oracles are bit-matching)."""
    key = jax.random.PRNGKey(16)
    tree = {"w": jax.random.normal(key, (300,)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (7,))}
    refsign = alignment.tree_sign(tree)
    monkeypatch.setenv(kbackend.ENV_VAR, "oracle")
    forced = np.asarray(ops.sign_align_ratio(tree, refsign))
    monkeypatch.delenv(kbackend.ENV_VAR)
    auto = np.asarray(ops.sign_align_ratio(tree, refsign))
    np.testing.assert_allclose(forced, auto, rtol=1e-6)
