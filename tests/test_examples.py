"""CI smoke run of every script in examples/ (<=2 rounds each).

Each example honors ``REPRO_SMOKE=1`` by shrinking to a miniature
configuration; this test executes them as real subprocesses (the same
way a user would) so the entry points can never silently rot.
"""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
EXAMPLES = sorted(p.name for p in (ROOT / "examples").glob("*.py"))


def test_every_example_is_covered():
    """New example scripts must register here (parametrize catches them
    automatically — this guards against an empty glob)."""
    assert len(EXAMPLES) >= 5, EXAMPLES


@pytest.mark.parametrize("script", EXAMPLES)
def test_example_smoke(script):
    env = dict(os.environ)
    env["REPRO_SMOKE"] = "1"
    env["PYTHONPATH"] = (str(ROOT / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, str(ROOT / "examples" / script)],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"{script} failed\n--- stdout ---\n{proc.stdout[-2000:]}"
        f"\n--- stderr ---\n{proc.stderr[-2000:]}")
    assert proc.stdout.strip(), f"{script} produced no output"
