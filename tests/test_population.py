"""Million-client population plane (core/population.py + the lazy
world): shard-local control transitions pinned bitwise to the global
rules, two-stage selection exactness, non-resident cohort determinism,
and the population mesh/sharding helpers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import DataSpec, ExperimentSpec, SpecError, WorldSpec
from repro.core import control, population
from repro.core.selection import candidate_mask_np, candidate_quota
from repro.data.loader import ArrayLoader, LoaderPool
from repro.data.partition import LazyPartition, client_seed
from repro.launch import mesh as mesh_mod
from repro.launch import sharding
from tests import harness


def _state(n, seed=0):
    rng = np.random.default_rng(seed)
    st = control.init_control(n)
    return st._replace(
        avail=jnp.asarray(rng.uniform(0.2, 1.0, n).astype(np.float32)),
        pass_rate=jnp.asarray(rng.uniform(0.5, 1.0, n).astype(np.float32)),
        round_time=jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32)))


def _obs(k, seed):
    rng = np.random.default_rng(seed)
    failed = rng.random(k) < 0.2
    active = ~failed
    passed = (rng.random(k) < 0.8) & active
    return dict(failed=jnp.asarray(failed), active=jnp.asarray(active),
                passed=jnp.asarray(passed),
                round_time=jnp.asarray(
                    rng.uniform(0.2, 3.0, k).astype(np.float32)),
                sent=jnp.asarray(active),
                norms=jnp.asarray(
                    rng.uniform(0.05, 2.5, k).astype(np.float32)))


# ---------------------------------------------------------------------------
# two-stage candidate selection: np == jnp, exactness, liveness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,frac,shards", [
    (10, 3, 0.5, 4),        # padded last shard
    (16, 4, 0.25, 4),
    (16, 7, 0.1, 8),        # quota floored by (k+pad)/shards
    (33, 5, 0.3, 8),
    (64, 64, 0.02, 8),      # k == n
])
def test_candidate_mask_np_matches_device(n, k, frac, shards):
    rng = np.random.default_rng(1)
    scores = rng.normal(size=n).astype(np.float32)
    host = candidate_mask_np(scores, k, frac, shards)
    dev = np.asarray(control.candidate_mask(
        jnp.asarray(scores), k, frac, shards))
    np.testing.assert_array_equal(host, dev)
    assert host.sum() >= k                # union always admits a cohort


@pytest.mark.parametrize("n,k,shards", [(10, 7, 8), (12, 12, 5), (9, 9, 4)])
def test_quota_guarantees_k_real_candidates(n, k, shards):
    # padding-partial last shard: each pad position displaces at most
    # one real candidate, the (k+pad)/shards floor absorbs that
    quota = candidate_quota(n, k, 0.01, shards)
    per = -(-n // shards)
    assert quota <= per
    scores = np.arange(n, dtype=np.float32)
    assert candidate_mask_np(scores, k, 0.01, shards).sum() >= k


def test_two_stage_frac1_bitexact_single_stage():
    scores = control.score(_state(50, seed=3))
    single = np.asarray(control.select_topk_epsilon(scores, 7))
    for shards in (1, 4, 8):
        two = np.asarray(control.two_stage_select(
            scores, 7, candidate_frac=1.0, candidate_shards=shards))
        np.testing.assert_array_equal(single, two)


def test_two_stage_exact_when_quota_covers_k():
    # with frac high enough that every shard's quota >= k, the union
    # contains the global top-k, so stage 2 recovers it exactly
    scores = control.score(_state(40, seed=5))
    exact = np.asarray(control.select_topk_epsilon(scores, 5))
    two = np.asarray(control.two_stage_select(
        scores, 5, candidate_frac=0.9, candidate_shards=4))
    np.testing.assert_array_equal(exact, two)


def test_two_stage_respects_live_and_candidates():
    # contract: the CALLER masks dead scores to -inf (exactly what the
    # engine selection sites do); `live` only restricts the ε-pool
    n, k = 32, 6
    raw = control.score(_state(n, seed=9))
    rng = np.random.default_rng(2)
    live = jnp.asarray(rng.random(n) > 0.4)
    scores = jnp.where(live, raw, -jnp.inf)
    for frac in (0.25, 0.5, 1.0):
        cohort = np.asarray(control.two_stage_select(
            scores, k, candidate_frac=frac, candidate_shards=4, live=live))
        cands = candidate_mask_np(np.asarray(scores), k, frac, 4)
        assert np.asarray(live)[cohort].all(), "selected a dead client"
        assert cands[cohort].all(), "selected outside the candidate union"


def test_topk_from_candidates_matches_stable_order():
    # ties must resolve to the lower global id (stable argsort order)
    v = jnp.asarray([1.0, 3.0, 3.0, 0.5, 3.0])
    i = jnp.asarray([40, 7, 3, 1, 11])
    got = np.asarray(population.topk_from_candidates(v, i, 3))
    np.testing.assert_array_equal(got, [3, 7, 11])


# ---------------------------------------------------------------------------
# shard-local round kernel == global transition rules (bitwise)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,shards", [(24, 4), (24, 1), (40, 8),
                                      (1000, 8),   # the CI scale cell
                                      (1000, 7),   # ragged: 6 pad rows
                                      (10, 8)])    # ragged: n close to shards
def test_round_update_logical_bitwise(n, shards):
    glob, shrd = _state(n, seed=11), _state(n, seed=11)
    rng = np.random.default_rng(0)
    for r in range(6):
        k = int(rng.integers(2, min(n, 12)))
        cohort = jnp.asarray(
            rng.choice(n, size=k, replace=False).astype(np.int32))
        obs = _obs(k, seed=100 + r)
        glob = population.round_update(glob, cohort, **obs)
        shrd = population.round_update_logical(shrd, cohort,
                                               shards=shards, **obs)
        for f in population._FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(glob, f)),
                np.asarray(getattr(shrd, f)), err_msg=f"{f} round {r}")


def test_round_update_sharded_bitwise():
    mesh = mesh_mod.make_population_mesh()
    ndev = mesh.shape["data"]
    n = 16 * ndev
    glob, shrd = _state(n, seed=13), _state(n, seed=13)
    rng = np.random.default_rng(1)
    for r in range(4):
        cohort = jnp.asarray(
            rng.choice(n, size=6, replace=False).astype(np.int32))
        obs = _obs(6, seed=200 + r)
        glob = population.round_update(glob, cohort, **obs)
        shrd = population.round_update_sharded(shrd, cohort, mesh=mesh,
                                               **obs)
        for f in population._FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(glob, f)),
                np.asarray(getattr(shrd, f)), err_msg=f"{f} round {r}")


def test_round_update_sharded_bitwise_ragged():
    # 1000 clients on however many host devices CI forces (8 in the
    # topology-smoke step): the population no longer needs to divide
    # the "data" axis — dummy pad rows are inert and sliced off
    mesh = mesh_mod.make_population_mesh()
    ndev = mesh.shape["data"]
    n = 1000 if 1000 % ndev else 1001     # force raggedness at any ndev
    glob, shrd = _state(n, seed=23), _state(n, seed=23)
    rng = np.random.default_rng(3)
    for r in range(3):
        cohort = jnp.asarray(
            rng.choice(n, size=9, replace=False).astype(np.int32))
        obs = _obs(9, seed=300 + r)
        glob = population.round_update(glob, cohort, **obs)
        shrd = population.round_update_sharded(shrd, cohort, mesh=mesh,
                                               **obs)
        for f in population._FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(glob, f)),
                np.asarray(getattr(shrd, f)), err_msg=f"{f} round {r}")


def test_sharded_candidates_match_logical():
    mesh = mesh_mod.make_population_mesh()
    ndev = mesh.shape["data"]
    k = 6
    # 32·ndev divides evenly; +5 exercises the -inf ragged padding
    for n in (32 * ndev, 32 * ndev + 5):
        scores = control.score(_state(n, seed=17))
        lv, li = population.logical_candidates(scores, k, 0.2, ndev)
        sv, si = population.sharded_candidates(scores, k, 0.2, mesh=mesh)
        np.testing.assert_array_equal(
            np.sort(np.asarray(li)), np.sort(np.asarray(si)))
        sel = np.asarray(population.topk_from_candidates(lv, li, k))
        np.testing.assert_array_equal(
            sel, np.asarray(population.topk_from_candidates(sv, si, k)))
        assert (sel < n).all()            # pad ids never selected


def test_build_population_round_scan_matches_python_loop():
    n, k, rounds = 48, 8, 5
    fn = population.build_population_round(n, k, candidate_frac=0.25,
                                           candidate_shards=4)
    jfn = jax.jit(fn)                     # compiled-vs-compiled: eager
    st_loop = _state(n, seed=21)          # op-by-op float fusion differs
    cohorts = []
    for r in range(rounds):
        st_loop, c = jfn(st_loop, jnp.int32(r))
        cohorts.append(np.asarray(c))

    def body(st, r):
        st, c = fn(st, r)
        return st, c

    st_scan, scanned = jax.lax.scan(body, _state(n, seed=21),
                                    jnp.arange(rounds, dtype=jnp.int32))
    np.testing.assert_array_equal(np.stack(cohorts), np.asarray(scanned))
    for f in population._FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(st_loop, f)),
                                      np.asarray(getattr(st_scan, f)))


# ---------------------------------------------------------------------------
# engine-level: candidate_frac across the four execution paths
# ---------------------------------------------------------------------------

def test_candidate_frac_noop_all_paths():
    spec = harness.base_spec(rounds=3, num_clients=6, select_fraction=0.5)
    harness.assert_candidate_frac_noop(spec)


def test_candidate_frac_differential_parity():
    # at frac < 1 the same two-stage union must drive every path: the
    # full cross-engine parity contract holds unchanged
    spec = dataclasses.replace(
        harness.base_spec(rounds=4, num_clients=8, select_fraction=0.5),
        candidate_frac=0.5, candidate_shards=2)
    harness.differential(spec)


# ---------------------------------------------------------------------------
# non-resident worlds: seeding, memory bound, resume
# ---------------------------------------------------------------------------

def test_client_seed_decorrelates():
    seen = {client_seed(s, c) for s in range(4) for c in range(64)}
    assert len(seen) == 4 * 64            # no (seed, cid) collisions
    assert client_seed(0, 1) != client_seed(1, 0)


def test_lazy_partition_constant_memory():
    p = LazyPartition(1_000_000, 256, seed=3)
    assert len(p) == 1_000_000
    assert p.shard(42) == (client_seed(3, 42), 256)
    with pytest.raises(IndexError):
        p.shard(1_000_000)


def _lazy_spec(n=12, resident=False, **kw):
    return ExperimentSpec(
        model="anomaly-mlp-smoke",
        data=DataSpec(samples_per_client=96, eval_samples=64),
        world=WorldSpec(num_clients=n, profile="heterogeneous",
                        resident=resident),
        rounds=2, seed=0, **kw)


def test_lazy_world_cohort_independent_draws():
    w = _lazy_spec().validate().build_world()
    assert w.lazy
    a = {k: np.array(v) for k, v in w.client_arrays[7].items()}
    # touching other cohorts (and evicting 7) must not perturb 7's draws
    for cid in range(12):
        w.client_arrays[cid]
    b = {k: np.array(v) for k, v in w.client_arrays[7].items()}
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_lazy_spec_validation():
    with pytest.raises(SpecError):
        # non-resident requires samples_per_client
        ExperimentSpec(world=WorldSpec(num_clients=4, resident=False),
                       rounds=1).validate()
    with pytest.raises(SpecError):
        _lazy_spec(engine="spmd").validate()
    with pytest.raises(SpecError):
        _lazy_spec(rounds_per_dispatch=2).validate()


def test_loader_pool_eviction_preserves_streams():
    w = _lazy_spec().validate().build_world()
    big = LoaderPool(w.client_arrays, lambda cid: 16, seed=5, capacity=64)
    small = LoaderPool(w.client_arrays, lambda cid: 16, seed=5, capacity=2)
    order = [0, 1, 0, 2, 3, 4, 0, 1, 2]    # forces evictions in `small`
    for cid in order:
        xa, ya = big[cid].sample()
        xb, yb = small[cid].sample()
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
        np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))
    assert small.resident <= 2


def test_loader_pool_state_roundtrip():
    w = _lazy_spec().validate().build_world()
    pool = LoaderPool(w.client_arrays, lambda cid: 16, seed=5, capacity=4)
    for cid in (0, 1, 2):
        pool[cid].sample()
    state = pool.state_dict()
    assert state["lazy"] is True
    fresh = LoaderPool(w.client_arrays, lambda cid: 16, seed=5, capacity=4)
    fresh.load_state_dict(state)
    for cid in (0, 1, 2, 3):              # 3 never sampled: fresh stream
        xa, _ = pool[cid].sample()
        xb, _ = fresh[cid].sample()
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def test_lazy_engine_loop_matches_megastep():
    spec = _lazy_spec(n=6).validate()
    loop = harness.run_cell(spec, "loop")
    mega = harness.run_cell(spec, "megastep")
    harness.assert_host_equivalent(loop, mega)


# ---------------------------------------------------------------------------
# population mesh + pspec rules
# ---------------------------------------------------------------------------

def test_fold_mesh_shape():
    for n in (1, 2, 3, 6, 8, 12, 48, 512):
        shape = mesh_mod.fold_mesh_shape(n)
        assert int(np.prod(shape)) == n
        model = shape[-1]
        assert model & (model - 1) == 0 and model <= 16
    pod = mesh_mod.fold_mesh_shape(8, multi_pod=True)
    assert pod[0] == 2 and int(np.prod(pod)) == 8
    with pytest.raises(RuntimeError):
        mesh_mod.fold_mesh_shape(7, multi_pod=True)


def test_make_population_mesh_covers_all_devices():
    mesh = mesh_mod.make_population_mesh()
    assert mesh.shape["data"] == len(jax.devices())
    assert mesh.shape["model"] == 1


def test_population_pspecs_shard_client_axes_only():
    from jax.sharding import PartitionSpec as P
    mesh = mesh_mod.make_population_mesh()
    n = 16 * mesh.shape["data"]
    tree = {"per_client": jnp.zeros((n,)),
            "per_client2d": jnp.zeros((n, 3)),
            "scalar": jnp.float32(0.0),
            "small": jnp.zeros((4,))}
    specs = sharding.population_pspecs(tree, mesh, n)
    # a size-1 "data" axis replicates (semantically identical, _maybe)
    d = "data" if mesh.shape["data"] > 1 else None
    assert specs["per_client"] == P(d)
    assert specs["per_client2d"] == P(d, None)
    assert specs["scalar"] == P()
    assert specs["small"] == P(None)
    placed = sharding.shard_population(tree, mesh, n)
    np.testing.assert_array_equal(np.asarray(placed["per_client"]),
                                  np.asarray(tree["per_client"]))
