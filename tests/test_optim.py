"""Optimizers, schedules, dynamic loss scaler (paper's GradScaler analog)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw as optim_mod
from repro.optim import scaler as sc
from repro.optim import schedule


@pytest.mark.parametrize("make", [
    lambda: optim_mod.adamw(1e-1),
    lambda: optim_mod.adafactor(5e-1),
    lambda: optim_mod.sgd(1e-1),
])
def test_optimizer_descends_quadratic(make):
    opt = make()
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss(params)) < 0.1 * l0


def test_adamw_master_weights_bf16():
    opt = optim_mod.adamw(1e-2, keep_master=True)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["master"]["w"].dtype == jnp.float32
    grads = {"w": jnp.full((4,), 1e-4, jnp.float32)}
    p2, s2 = opt.update(grads, state, params)
    assert p2["w"].dtype == jnp.bfloat16
    # master accumulates sub-bf16 steps even when bf16 params round
    assert float(jnp.abs(s2["master"]["w"] - 1.0).max()) > 0


def test_adafactor_memory_shapes():
    opt = optim_mod.adafactor(1e-2)
    params = {"m": jnp.ones((8, 16)), "v": jnp.ones((5,))}
    state = opt.init(params)
    assert state["stats"]["m"]["r"].shape == (8,)
    assert state["stats"]["m"]["c"].shape == (16,)
    assert state["stats"]["v"]["v"].shape == (5,)


def test_schedules():
    fn = schedule.cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(fn(0)) == 0.0
    assert abs(float(fn(10)) - 1.0) < 0.11
    assert float(fn(100)) <= 0.11
    sd = schedule.step_decay(1.0, decay_every=10, gamma=0.5)
    assert float(sd(25)) == 0.25


class TestScaler:
    def test_overflow_skips_and_halves(self):
        state = sc.init_scaler(1024.0)
        grads = {"w": jnp.array([jnp.inf, 1.0])}
        finite = sc.grads_finite(grads)
        assert not bool(finite)
        ns = sc.next_state(state, finite)
        assert float(ns.scale) == 512.0
        params = {"w": jnp.zeros(2)}
        new_params = {"w": jnp.ones(2)}
        kept, _ = sc.apply_or_skip(finite, new_params, params, {}, {})
        np.testing.assert_allclose(np.asarray(kept["w"]), 0.0)

    def test_growth_after_interval(self):
        state = sc.init_scaler(8.0)
        fin = jnp.bool_(True)
        for _ in range(200):
            state = sc.next_state(state, fin, growth_interval=200)
        assert float(state.scale) == 16.0
        assert int(state.good_steps) == 0

    def test_scale_unscale_roundtrip(self):
        state = sc.init_scaler(2.0 ** 10)
        loss = jnp.float32(3.5)
        grads = {"w": jnp.array([2.0 ** 10 * 4.0])}
        assert float(sc.scale_loss(loss, state)) == 3.5 * 2 ** 10
        un = sc.unscale_grads(grads, state)
        np.testing.assert_allclose(np.asarray(un["w"]), 4.0)


def test_fp16_training_with_scaler_end_to_end():
    """fp16-parity path: scaled loss, unscale, skip-on-overflow."""
    opt = optim_mod.sgd(1e-1)
    params = {"w": jnp.array([2.0, -1.0], jnp.float16)}
    state = opt.init(params)
    s = sc.init_scaler(2.0 ** 8)

    def loss(p):
        w = p["w"].astype(jnp.float32)
        return jnp.sum(w * w)

    for _ in range(30):
        g = jax.grad(lambda p: sc.scale_loss(loss(p), s))(params)
        g = sc.unscale_grads(g, s)
        fin = sc.grads_finite(g)
        new_p, new_st = opt.update(g, state, params)
        params, state = sc.apply_or_skip(fin, new_p, params, new_st, state)
        s = sc.next_state(s, fin)
    assert float(loss(params)) < 0.5
