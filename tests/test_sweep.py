"""run_sweep + api.stats: declarative cross-products, the vectorized
(vmapped seed-stacked) spmd multi-seed path, and the Mann-Whitney U
implementation (pinned to scipy's asymptotic method)."""
import dataclasses

import numpy as np
import pytest

from repro.api import (DataSpec, ExperimentSpec, StrategyConfig, WorldSpec,
                       mann_whitney_u, run_experiment, run_spmd_seed_batch,
                       run_sweep, seed_vectorizable)
from repro.api import stats

SMALL = dict(model="anomaly-mlp-smoke",
             data=DataSpec(n_samples=1500, eval_samples=300),
             rounds=3, seed=0)


def _degenerate(bs=32, **kw):
    return StrategyConfig(mode="sync", theta=None, selection=False,
                          dynamic_batch=False, checkpointing=False,
                          batch_size=bs, lr=3e-2, local_epochs=1,
                          max_samples_per_round=2 * bs, **kw)


def _spmd_spec(**kw):
    base = dict(SMALL, engine="spmd", strategy=_degenerate(),
                world=WorldSpec(num_clients=4, profile="heterogeneous"))
    return ExperimentSpec(**{**base, **kw})


# ---------------------------------------------------------------------------
# stats: Mann-Whitney U pinned to scipy, summaries
# ---------------------------------------------------------------------------

def test_mann_whitney_matches_scipy_asymptotic():
    scipy_stats = pytest.importorskip("scipy.stats")
    rng = np.random.default_rng(0)
    for _ in range(25):
        n1, n2 = rng.integers(3, 16, 2)
        a = rng.normal(0.0, 1.0, n1).round(1)      # rounding forces ties
        b = rng.normal(0.3, 1.0, n2).round(1)
        for alt in ("two-sided", "greater", "less"):
            ours = mann_whitney_u(a, b, alternative=alt)
            ref = scipy_stats.mannwhitneyu(a, b, alternative=alt,
                                           method="asymptotic")
            np.testing.assert_allclose(ours.u, ref.statistic, atol=1e-12)
            np.testing.assert_allclose(ours.p_value, ref.pvalue,
                                       atol=1e-12)


def test_mann_whitney_direction_and_validation():
    lo, hi = [0.1, 0.2, 0.3, 0.25, 0.15], [0.8, 0.9, 0.85, 0.95, 0.7]
    assert mann_whitney_u(hi, lo, "greater").significant(0.05)
    assert not mann_whitney_u(lo, hi, "greater").significant(0.05)
    with pytest.raises(ValueError, match="alternative"):
        mann_whitney_u(lo, hi, "sideways")
    with pytest.raises(ValueError, match="samples"):
        mann_whitney_u([], hi)


def test_rankdata_average_ties():
    np.testing.assert_allclose(stats.rankdata([10.0, 20.0, 20.0, 30.0]),
                               [1.0, 2.5, 2.5, 4.0])


def test_median_iqr():
    med, q1, q3 = stats.median_iqr(range(1, 10))
    assert med == 5.0 and q1 == 3.0 and q3 == 7.0


# ---------------------------------------------------------------------------
# vectorized multi-seed spmd execution
# ---------------------------------------------------------------------------

def test_seed_batch_matches_serial_runs():
    """ONE vmapped seed-stacked state must reproduce the serial per-seed
    loop: exact event accounting, fp trajectories to vmap tolerance."""
    spec = _spmd_spec()
    seeds = [0, 1, 2]
    batch = run_spmd_seed_batch(spec, seeds)
    for s, res in zip(seeds, batch):
        serial = run_experiment(dataclasses.replace(spec, seed=s))
        assert res.seed == s and len(res.records) == len(serial.records)
        for a, b in zip(res.records, serial.records):
            assert a.round == b.round
            assert a.updates_applied == b.updates_applied
            np.testing.assert_allclose(a.sim_time, b.sim_time, rtol=1e-9)
            np.testing.assert_allclose(a.bytes_sent, b.bytes_sent,
                                       rtol=1e-9)
            np.testing.assert_allclose(a.accuracy, b.accuracy, atol=1e-5)
            np.testing.assert_allclose(a.loss, b.loss, rtol=1e-4)


def test_seed_batch_rejects_active_control_plane():
    spec = _spmd_spec(strategy=dataclasses.replace(
        _degenerate(), selection=True, select_fraction=0.5))
    assert not seed_vectorizable(spec)
    with pytest.raises(ValueError, match="vectoriz"):
        run_spmd_seed_batch(spec, [0, 1])


# ---------------------------------------------------------------------------
# run_sweep
# ---------------------------------------------------------------------------

def test_sweep_vectorizes_spmd_seed_groups():
    sweep = run_sweep(_spmd_spec(), axes={"seed": range(3)})
    assert sweep.vectorized_groups == 1
    assert all(p.vectorized for p in sweep.points)
    assert len(sweep.values("accuracy")) == 3


def test_sweep_five_seeds_ours_vs_fedavg_has_p_value():
    """The acceptance shape: >=5 seeds of ours vs fedavg on the sim
    engine -> a Mann-Whitney p-value + a comparison report."""
    spec = ExperimentSpec(**SMALL, strategy="ours",
                          strategy_kwargs=dict(batch_size=32),
                          world=WorldSpec(num_clients=4,
                                          profile="heterogeneous"))
    sweep = run_sweep(spec, axes={"strategy": ["ours", "fedavg"],
                                  "seed": range(5)})
    assert len(sweep.points) == 10
    r = sweep.mann_whitney_u("strategy", "ours", "fedavg",
                             metric="accuracy", alternative="greater")
    assert r.n_a == r.n_b == 5
    assert 0.0 <= r.p_value <= 1.0
    report = sweep.report("accuracy", baseline="fedavg")
    assert "strategy=ours" in report and "p_vs_fedavg" in report
    # bytes comparison too (the overhead-reduction claim's metric)
    assert len(sweep.values("bytes_sent", strategy="ours")) == 5


def test_sweep_dotted_axes_and_filter():
    spec = ExperimentSpec(**SMALL, strategy=_degenerate(),
                          world=WorldSpec(num_clients=4, profile="uniform"))
    sweep = run_sweep(spec, axes={"data.alpha": [0.1, 1.0],
                                  "seed": [0, 1]})
    assert len(sweep.points) == 4
    pts = sweep.filter(**{"data.alpha": 0.1})
    assert len(pts) == 2
    assert all(p.spec.data.alpha == 0.1 for p in pts)


def test_sweep_validates_points_up_front():
    from repro.api import SpecError
    with pytest.raises(SpecError):
        run_sweep(_spmd_spec(), axes={"engine": ["sim", "ray"],
                                      "seed": [0]})


def test_sweep_requires_axes():
    with pytest.raises(ValueError, match="axes"):
        run_sweep(_spmd_spec(), axes={})
