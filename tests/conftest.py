import importlib.util
import pathlib
import sys

import numpy as np
import pytest

# the container image has no `hypothesis` and may not pip install; load
# the deterministic stub (tests/_hypothesis_stub.py) in its place BEFORE
# the property-test modules are collected. A real install wins.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).with_name("_hypothesis_stub.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

import jax  # noqa: E402


@pytest.fixture(scope="session", autouse=True)
def _jax_cpu_determinism():
    # smoke tests and benches must see the single real CPU device
    # (the dry-run forces 512 host devices in its own process only)
    assert jax.default_backend() == "cpu"
    np.random.seed(0)
    yield
