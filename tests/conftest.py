import numpy as np
import pytest

import jax


@pytest.fixture(scope="session", autouse=True)
def _jax_cpu_determinism():
    # smoke tests and benches must see the single real CPU device
    # (the dry-run forces 512 host devices in its own process only)
    assert jax.default_backend() == "cpu"
    np.random.seed(0)
    yield
